"""Fused RMSNorm — Pallas kernel.

Memory-bound epilogue op: unfused, XLA reads x twice (square-mean, then
normalize) and writes the normalized intermediate before the scale
multiply.  Fusing keeps the (block_t, D) tile resident in VMEM for the
whole read→reduce→scale pipeline: one HBM read + one HBM write per
element, i.e. the op runs at streaming bandwidth.

Also covers OLMo's *non-parametric* LayerNorm (scale=None → pure
normalization, no learned affine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    y = y * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _rmsnorm_kernel_noscale(x_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_t", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array | None = None,
            eps: float = 1e-6, block_t: int = 256,
            interpret: bool = True) -> jax.Array:
    """x: (..., D); scale: (D,) or None (non-parametric)."""
    orig_shape = x.shape
    D = orig_shape[-1]
    xr = x.reshape(-1, D)
    T = xr.shape[0]
    bt = min(block_t, T)
    if T % bt != 0:  # pad rows to a block multiple; rows are independent
        pad = bt - T % bt
        xr = jnp.concatenate([xr, jnp.zeros((pad, D), xr.dtype)], axis=0)
    Tp = xr.shape[0]
    if scale is not None:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=(Tp // bt,),
            in_specs=[pl.BlockSpec((bt, D), lambda t: (t, 0)),
                      pl.BlockSpec((1, D), lambda t: (0, 0))],
            out_specs=pl.BlockSpec((bt, D), lambda t: (t, 0)),
            out_shape=jax.ShapeDtypeStruct((Tp, D), x.dtype),
            interpret=interpret,
        )(xr, scale.reshape(1, D))
    else:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_kernel_noscale, eps=eps),
            grid=(Tp // bt,),
            in_specs=[pl.BlockSpec((bt, D), lambda t: (t, 0))],
            out_specs=pl.BlockSpec((bt, D), lambda t: (t, 0)),
            out_shape=jax.ShapeDtypeStruct((Tp, D), x.dtype),
            interpret=interpret,
        )(xr)
    return out[:T].reshape(orig_shape)
