"""Blocked causal flash attention (GQA) — Pallas TPU kernel.

The serving/training hot spot of every assigned LM architecture.  Online-
softmax streaming over key blocks (FlashAttention-2 schedule adapted to the
TPU grid model):

  grid = (B·Hq, Sq/block_q, Skv/block_k)   — k innermost, sequential, so the
  running (m, l, acc) state lives in VMEM scratch and is revisited across
  the k dimension; the final normalized tile is written once at the last
  k step.  Block shapes are MXU-aligned (block_q, block_k multiples of 128
  on real hardware; the tests sweep smaller interpret-mode tiles).

TPU adaptation notes (vs the CUDA formulation):
  * no warp-level reductions — rowmax/rowsum are VPU ops over the (8,128)
    register tiles, which XLA/Mosaic handles; we keep reductions on the
    last axis so they stay in-lane.
  * masking uses a large *finite* negative (−1e30) instead of −inf: −inf
    arithmetic (−inf − −inf) produces NaNs in f32 on both MXU paths and
    interpret mode; with the causal structure every row has ≥1 valid key,
    so the finite mask is exact after normalization.
  * GQA is expressed in the index_map (query head → kv head), so no
    repeated KV materialization in HBM: the same kv block is streamed to
    all heads of a group.
  * the causal upper-triangle blocks are skipped with ``pl.when`` — work
    saving visible in the cost model, not just latency.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  seq_off: int):
    """One (q-block, k-block) step.  Refs: q (block_q, D), k/v (block_k, D),
    o (block_q, D); scratch m/l (block_q, 1) and acc (block_q, D) in VMEM."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block pruning: skip blocks strictly above the diagonal
    q_last = (qi + 1) * block_q - 1 + seq_off
    k_first = ki * block_k
    run = (not causal) or (k_first <= q_last)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + seq_off
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]                       # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)           # finite: NEG_INF - NEG_INF = 0
        p = jnp.exp(s - m_new)                    # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Returns (B, Hq, Sq, D).

    Sq may be shorter than Skv (chunked prefill): queries are the last Sq
    positions of the context.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = float(sm_scale) if sm_scale is not None else float(1.0 / np.sqrt(D))
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    seq_off = Skv - Sq

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b = bh // Hq
        h = bh % Hq
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, causal=causal,
        block_q=bq, block_k=bk, seq_off=seq_off)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, Sq // bq, Skv // bk),
        in_specs=[
            pl.BlockSpec((None, bq, D), q_map),
            pl.BlockSpec((None, bk, D), kv_map),
            pl.BlockSpec((None, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
