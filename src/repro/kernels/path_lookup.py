"""Batched path-hash lookup (the paper's Q1/GET, TPU-native) — Pallas kernel.

The WikiKV point lookup — ``GET(H(π))`` over the sorted 64-bit digest
table — becomes a *batched* device op: the serving tier resolves a whole
navigation batch (thousands of concurrent GET/LS steps) in one launch.

Two-level search, designed around the TPU memory hierarchy instead of the
LSM pread of the paper:

  level 1 (fences): every ``TILE``-th key is a fence.  The fence column
    (N/TILE pairs) lives in VMEM; each query finds its tile with a
    *branch-free broadcast compare* — a (block_q × F) lexicographic
    ``key < q`` matrix reduced by row-sum.  No gather, pure VPU lanework.
  level 2 (tiles): each query's candidate tile (TILE consecutive keys) is
    brought in with one dynamic slice from the HBM-resident key table and
    compared exactly; the row id (or −1) is emitted.

This replaces the per-query binary search (log₂N dependent HBM loads,
latency-bound) with one VMEM-resident compare + exactly one dynamic slice
per query — the O(1) storage-round-trip contract of §IV, realized as
"O(1) HBM touches per query".

  level 0 (pinned): the engine stages the wiki's pinned hot set ("/" +
    every dimension — the paper's L1 cache tier) as a VMEM-resident
    sub-table of (hi, lo, sorted-table position) triples.  Every query is
    broadcast-compared against it *first*; a pinned hit emits its
    position directly and skips the HBM tile slice entirely, so the hot
    rows that dominate navigation traffic (every chain starts at "/" and
    a dimension) cost zero HBM touches.  ``pinned=None`` degrades to a
    sentinel table that can never hit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


#: pinned sub-table allocation granule (lane-friendly; tiny either way)
PIN_TILE = 8


def _lookup_kernel(phi_ref, plo_ref, ppos_ref, fhi_ref, flo_ref,
                   khi_ref, klo_ref, qhi_ref, qlo_ref,
                   out_ref, *, n_keys: int, n_fences: int, block_q: int):
    """Refs: pinned p{hi,lo,pos} (P,) VMEM; fences f{hi,lo} (F,) VMEM;
    full keys k{hi,lo} (N,) ANY/HBM; queries q{hi,lo} (block_q,) VMEM;
    out (block_q,) int32."""
    qhi = qhi_ref[...]
    qlo = qlo_ref[...]
    fhi = fhi_ref[...]
    flo = flo_ref[...]
    # level 0: broadcast-compare against the VMEM pinned hot set.  Pinned
    # keys are unique, so the masked row-sum selects the hit position.
    phi = phi_ref[...]
    plo = plo_ref[...]
    ppos = ppos_ref[...]
    pin_eq = (phi[None, :] == qhi[:, None]) & (plo[None, :] == qlo[:, None])
    pin_hit = jnp.any(pin_eq, axis=1)                      # (block_q,)
    pin_pos = jnp.sum(jnp.where(pin_eq, ppos[None, :], 0),
                      axis=1).astype(jnp.int32)
    # level 1: tile id = (# fences <= q) - 1, lexicographic on uint32 pairs
    le = (fhi[None, :] < qhi[:, None]) | (
        (fhi[None, :] == qhi[:, None]) & (flo[None, :] <= qlo[:, None]))
    tile_idx = jnp.sum(le.astype(jnp.int32), axis=1) - 1   # (block_q,)
    tile_idx = jnp.clip(tile_idx, 0, n_fences - 1)

    # level 2: one dynamic slice per query (serial fori over the block —
    # each iteration is a TILE-wide vector compare, fully in-lane); a
    # pinned hit skips the HBM slice entirely
    def body(i, _):
        @pl.when(pin_hit[i])
        def _pinned():
            out_ref[i] = pin_pos[i]

        @pl.when(~pin_hit[i])
        def _hbm():
            start = tile_idx[i] * TILE
            start = jnp.minimum(start, n_keys - TILE)
            khi = khi_ref[pl.ds(start, TILE)]
            klo = klo_ref[pl.ds(start, TILE)]
            hit = (khi == qhi[i]) & (klo == qlo[i])
            pos = jnp.arange(TILE, dtype=jnp.int32)
            row = jnp.min(jnp.where(hit, start + pos, jnp.int32(2**31 - 1)))
            out_ref[i] = jnp.where(jnp.any(hit), row, -1)

        return 0

    jax.lax.fori_loop(0, block_q, body, 0)


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def path_lookup(keys_hi: jax.Array, keys_lo: jax.Array,
                q_hi: jax.Array, q_lo: jax.Array, *,
                pinned: tuple[jax.Array, jax.Array, jax.Array] | None = None,
                block_q: int = 256, interpret: bool = True) -> jax.Array:
    """keys_{hi,lo}: (N,) uint32 sorted pairs; q_{hi,lo}: (Q,) uint32.
    Returns (Q,) int32 row ids, −1 on miss.  N is padded to a TILE multiple
    with max-key sentinels by the caller (ops.pad_keys).

    ``pinned`` is the optional VMEM hot-set staging: (pin_hi, pin_lo,
    pin_pos) where pin_pos[j] is the *sorted-table position* of the pinned
    key pair — the value the HBM search would have produced.  Free slots
    hold 0xFFFFFFFF key sentinels (match-proof; see pad_keys)."""
    n = keys_hi.shape[0]
    assert n % TILE == 0, f"key table must be padded to {TILE}: {n}"
    Q = q_hi.shape[0]
    bq = min(block_q, Q)
    if Q % bq != 0:
        pad = bq - Q % bq
        q_hi = jnp.concatenate([q_hi, jnp.zeros((pad,), q_hi.dtype)])
        q_lo = jnp.concatenate([q_lo, jnp.zeros((pad,), q_lo.dtype)])
    Qp = q_hi.shape[0]
    fences_hi = keys_hi[::TILE]
    fences_lo = keys_lo[::TILE]
    n_fences = fences_hi.shape[0]
    if pinned is None:
        pin_hi = jnp.full((PIN_TILE,), 0xFFFFFFFF, jnp.uint32)
        pin_lo = pin_hi
        pin_pos = jnp.zeros((PIN_TILE,), jnp.int32)
    else:
        pin_hi, pin_lo, pin_pos = pinned
    n_pin = pin_hi.shape[0]

    kernel = functools.partial(
        _lookup_kernel, n_keys=n, n_fences=n_fences, block_q=bq)
    out = pl.pallas_call(
        kernel,
        grid=(Qp // bq,),
        in_specs=[
            pl.BlockSpec((n_pin,), lambda qb: (0,)),
            pl.BlockSpec((n_pin,), lambda qb: (0,)),
            pl.BlockSpec((n_pin,), lambda qb: (0,)),
            pl.BlockSpec((n_fences,), lambda qb: (0,)),
            pl.BlockSpec((n_fences,), lambda qb: (0,)),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((bq,), lambda qb: (qb,)),
            pl.BlockSpec((bq,), lambda qb: (qb,)),
        ],
        out_specs=pl.BlockSpec((bq,), lambda qb: (qb,)),
        out_shape=jax.ShapeDtypeStruct((Qp,), jnp.int32),
        interpret=interpret,
    )(pin_hi, pin_lo, pin_pos, fences_hi, fences_lo,
      keys_hi, keys_lo, q_hi, q_lo)
    return out[:Q]


def pad_pinned(pin_hi: np.ndarray, pin_lo: np.ndarray, pin_pos: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a pinned staging triple to the PIN_TILE granule with
    0xFFFFFFFF key sentinels (position 0 — never selected)."""
    n = pin_hi.shape[0]
    pad = (-n) % PIN_TILE if n else PIN_TILE
    if pad == 0:
        return pin_hi, pin_lo, pin_pos
    fill = np.full((pad,), 0xFFFFFFFF, dtype=np.uint32)
    return (np.concatenate([pin_hi, fill]),
            np.concatenate([pin_lo, fill]),
            np.concatenate([pin_pos, np.zeros((pad,), np.int32)]))


def pad_keys(keys_hi: np.ndarray, keys_lo: np.ndarray
             ) -> tuple[np.ndarray, np.ndarray]:
    """Pad the sorted key table to a TILE multiple with 0xFFFFFFFF
    sentinels (greater than every real key, so search order is preserved;
    collisions with a real all-ones key are impossible because FNV of a
    non-empty path never yields 2^64−1 — asserted at freeze time)."""
    n = keys_hi.shape[0]
    pad = (-n) % TILE
    if pad == 0:
        return keys_hi, keys_lo
    fill = np.full((pad,), 0xFFFFFFFF, dtype=np.uint32)
    return (np.concatenate([keys_hi, fill]),
            np.concatenate([keys_lo, fill]))
