"""Batched prefix search (the paper's Q4/SEARCH, TPU-native) — Pallas kernel.

SEARCH(p) over the packed path-token matrix: a pure streaming op — each
grid step pulls one (block_n, L) uint8 tile of paths into VMEM, compares
it against the query prefix (broadcast across rows), applies the segment-
boundary rule ("/a" must not match "/ab"), and emits a (block_n,) bitmap.

This is the bandwidth-roofline member of the kernel set: arithmetic
intensity ≈ 1 compare/byte, so the dry-run's memory term is the honest
cost model.  The LSM iterator of the paper becomes a dense scan that the
VPU eats at HBM speed; for N = 10⁷ paths × 96 B ≈ 1 GB, one pass is
~1.2 ms at 819 GB/s — amortized across every query in the routing batch,
since the tile is compared against *all* pending prefixes while resident
(the multi-query variant below).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prefix_kernel(tok_ref, pref_ref, plen_ref, out_ref):
    """Refs: tokens (block_n, L) uint8; prefix (Q, L) uint8; plen (Q,) i32;
    out (block_n, Q) bool."""
    toks = tok_ref[...]
    prefs = pref_ref[...]
    plens = plen_ref[...]
    L = toks.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, prefs.shape, 1)  # (Q, L)
    within = pos < plens[:, None]
    # (block_n, Q, L) compare — block_n×Q×L uint8 ops in VMEM
    eq = (toks[:, None, :] == prefs[None, :, :]) | ~within[None, :, :]
    starts = jnp.all(eq, axis=2)                               # (block_n, Q)
    # segment boundary: byte after the prefix must be 0 or '/'
    # (unless the prefix itself ends in '/')
    plen_c = jnp.minimum(plens, L - 1)
    nxt = jnp.take_along_axis(
        jnp.broadcast_to(toks[:, None, :], (toks.shape[0], prefs.shape[0], L)),
        plen_c[None, :, None].astype(jnp.int32), axis=2)[..., 0]
    last = jnp.take_along_axis(
        prefs, jnp.maximum(plens - 1, 0)[:, None].astype(jnp.int32),
        axis=1)[:, 0]
    boundary_ok = (last[None, :] == ord("/")) | (nxt == 0) | (nxt == ord("/"))
    fits = (plens < L)[None, :]
    out_ref[...] = starts & jnp.where(fits, boundary_ok, True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def prefix_search(tokens: jax.Array, prefixes: jax.Array,
                  prefix_lens: jax.Array, *, block_n: int = 1024,
                  interpret: bool = True) -> jax.Array:
    """tokens: (N, L) uint8; prefixes: (Q, L) uint8; prefix_lens: (Q,) int32.
    Returns (N, Q) bool match bitmap.  N padded to block_n internally."""
    N, L = tokens.shape
    Q = prefixes.shape[0]
    bn = min(block_n, N)
    if N % bn != 0:
        pad = bn - N % bn
        tokens = jnp.concatenate(
            [tokens, jnp.full((pad, L), 255, jnp.uint8)], axis=0)
    Np = tokens.shape[0]
    out = pl.pallas_call(
        _prefix_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, L), lambda nb: (nb, 0)),
            pl.BlockSpec((Q, L), lambda nb: (0, 0)),
            pl.BlockSpec((Q,), lambda nb: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, Q), lambda nb: (nb, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, Q), jnp.bool_),
        interpret=interpret,
    )(tokens, prefixes, prefix_lens)
    return out[:N]
