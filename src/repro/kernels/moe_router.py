"""Fused MoE router: softmax + top-k gate — Pallas kernel.

Hot on dbrx (16e top-4), kimi-k2 (384e top-8) and jamba (16e top-2):
the gate runs on *every token* of every MoE layer, and the unfused
softmax→top_k→renorm chain materializes (T, E) probabilities three times
in HBM.  This kernel keeps the (block_t, E) tile in VMEM and performs the
iterative arg-max selection in registers, emitting only the (block_t, k)
weights/indices.

TPU adaptation: GPU implementations use warp ballot/shuffle for the
top-k; here selection is k rounds of a full-width VPU max + one-hot
masking — O(k·E) lanework, branch-free, no data-dependent shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _router_kernel(logits_ref, w_ref, idx_ref, *, k: int, renormalize: bool):
    """Refs: logits (block_t, E) → w (block_t, k) f32, idx (block_t, k) i32."""
    x = logits_ref[...].astype(jnp.float32)
    bt, E = x.shape
    m = x.max(axis=1, keepdims=True)
    p = jnp.exp(x - m)
    p = p / p.sum(axis=1, keepdims=True)

    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    remaining = p
    ws = []
    ids = []
    for _ in range(k):  # k is small and static — unrolled selection rounds
        w = remaining.max(axis=1)                        # (bt,)
        # first-match index (ties broken toward lower expert id, matching
        # jax.lax.top_k's stable ordering)
        is_max = remaining == w[:, None]
        idx = jnp.min(jnp.where(is_max, cols, E), axis=1).astype(jnp.int32)
        ws.append(w)
        ids.append(idx)
        remaining = jnp.where(cols == idx[:, None], NEG_INF, remaining)
    w_out = jnp.stack(ws, axis=1)
    if renormalize:
        w_out = w_out / w_out.sum(axis=1, keepdims=True)
    w_ref[...] = w_out
    idx_ref[...] = jnp.stack(ids, axis=1)


@functools.partial(
    jax.jit, static_argnames=("k", "renormalize", "block_t", "interpret"))
def moe_router(logits: jax.Array, k: int, *, renormalize: bool = True,
               block_t: int = 256, interpret: bool = True
               ) -> tuple[jax.Array, jax.Array]:
    """logits: (T, E) → (weights (T, k) f32, indices (T, k) i32)."""
    T, E = logits.shape
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)
    kernel = functools.partial(_router_kernel, k=k, renormalize=renormalize)
    w, idx = pl.pallas_call(
        kernel,
        grid=(T // bt,),
        in_specs=[pl.BlockSpec((bt, E), lambda t: (t, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda t: (t, 0)),
            pl.BlockSpec((bt, k), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return w, idx
