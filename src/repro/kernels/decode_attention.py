"""Single-token decode attention against a padded KV cache — Pallas kernel.

The serving engine's inner loop (``decode_32k`` / ``long_500k`` shapes):
one new query token per sequence attends over a long cached context.
FlashDecoding-style split-KV: the kv sequence is the innermost grid
dimension, partial (m, l, acc) state accumulates in VMEM scratch, and
positions beyond the live ``length`` of each sequence are masked.

TPU adaptation: the split-KV *reduction tree* of the GPU formulation
(separate combine kernel over SM partial results) is unnecessary — the
sequential TPU grid revisits scratch across k blocks, so the combine is
fused for free.  What we keep from the paper^W GPU idea is the split of
the KV stream into VMEM-sized tiles so a 512k-token cache never has to
fit on-chip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, sm_scale: float, block_k: int):
    """Refs: q (Hg, D) — the query-head group attending one kv head;
    k/v (block_k, D); o (Hg, D); scalar-prefetch len (1,) in SMEM."""
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    # skip kv blocks entirely beyond the live prefix
    @pl.when(ki * block_k < length)
    def _body():
        q = q_ref[...].astype(jnp.float32)           # (Hg, D)
        k = k_ref[...].astype(jnp.float32)           # (bk, D)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (Hg, bk)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_k", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, sm_scale: float | None = None,
                     block_k: int = 256, interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); k_cache/v_cache: (B, Hkv, S, D); lengths: (B,) int32.

    Grid = (B·Hkv, S/block_k): one program row per (sequence, kv head),
    carrying the whole query-head *group* (Hq/Hkv rows) so the MXU matmul
    has a real M dimension even at batch-of-one decode.
    """
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = float(sm_scale) if sm_scale is not None else float(1.0 / np.sqrt(D))
    bk = min(block_k, S)
    assert S % bk == 0

    # (B, Hkv, group, D): group-major query layout per kv head
    qr = q.reshape(B, Hkv, group, D).reshape(B * Hkv, group, D)
    kr = k_cache.reshape(B * Hkv, S, D)
    vr = v_cache.reshape(B * Hkv, S, D)
    lens = jnp.repeat(lengths.astype(jnp.int32), Hkv)

    def q_map(bh, ki):
        return (bh, 0, 0)

    def kv_map(bh, ki):
        return (bh, ki, 0)

    kernel = functools.partial(_decode_kernel, sm_scale=scale, block_k=bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(B * Hkv, S // bk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM, block_shape=(1,),
                         index_map=lambda bh, ki: (bh,)),
            pl.BlockSpec((None, group, D), q_map),
            pl.BlockSpec((None, bk, D), kv_map),
            pl.BlockSpec((None, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, group, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, group, D), q.dtype),
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(B, Hkv, group, D).reshape(B, Hq, D)
