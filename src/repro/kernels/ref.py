"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is validated
against (tests/test_kernels.py sweeps shapes/dtypes and asserts
allclose in interpret mode).  These are also the dispatch fallbacks in
ops.py for shapes where a kernel launch is not warranted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30  # finite mask value — see flash_attention.py for why


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, sm_scale: float | None = None) -> jax.Array:
    """Full softmax attention with GQA.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q.dtype (accumulation in f32).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    Skv = k.shape[2]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        # queries are the *last* Sq positions of the Skv context
        q_pos = jnp.arange(Sq) + (Skv - Sq)
        k_pos = jnp.arange(Skv)
        mask = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return o.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, *,
                         sm_scale: float | None = None) -> jax.Array:
    """Single-token decode attention against a (padded) KV cache.

    q: (B, Hq, D); caches: (B, Hkv, S, D); lengths: (B,) valid prefix sizes.
    Returns (B, Hq, D).

    GQA-aware: contracts the query-head group against the *un-repeated*
    cache (repeating a 32k-token cache group-fold in f32 was the dominant
    decode collective: GSPMD all-gathered the materialized copy per layer
    — §Perf hillclimb B)."""
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Hkv, group, D)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p.astype(q.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, D).astype(q.dtype)


def chunked_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool = True,
                          sm_scale: float | None = None,
                          chunk: int = 1024) -> jax.Array:
    """Flash-style attention in pure jnp: ``lax.scan`` over KV chunks with
    a running (m, l, acc) online softmax — the XLA-HLO twin of the Pallas
    kernel, used on the compiled (dry-run / CPU SPMD) path so peak
    activation memory is O(Sq·chunk) instead of O(Sq·Skv).

    GQA-aware (§Perf hillclimb C): queries fold to (B, Hkv, G, Sq, D) and
    contract against the *un-repeated* KV chunk — the previous
    ``jnp.repeat(kv, group)`` materialized group-copies of every chunk in
    f32 (measured 1.4 TB/step of traffic + a same-sized all-gather on
    kimi).  Scores/probabilities accumulate in f32 via
    ``preferred_element_type`` with operands kept in the input dtype, so
    bf16 models stream bf16 bytes through the MXU.

    Matches attention_ref to float tolerance (tests/test_kernels.py)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(D)
    c = min(chunk, Skv)
    assert Skv % c == 0, (Skv, c)
    n_chunks = Skv // c
    seq_off = Skv - Sq
    f32 = jnp.float32

    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, Hkv, group, Sq, D)
    kc = k.reshape(B, Hkv, n_chunks, c, D)
    vc = v.reshape(B, Hkv, n_chunks, c, D)
    q_pos = jnp.arange(Sq, dtype=jnp.int32) + seq_off

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs                      # (B, Hkv, c, D) ×2, scalar
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kb,
                       preferred_element_type=f32)
        if causal:
            k_pos = ci * c + jnp.arange(c, dtype=jnp.int32)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(q.dtype), vb,
            preferred_element_type=f32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, group, Sq), NEG_INF, f32)
    l0 = jnp.zeros((B, Hkv, group, Sq), f32)
    a0 = jnp.zeros((B, Hkv, group, Sq, D), f32)
    xs = (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
          jnp.arange(n_chunks, dtype=jnp.int32))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MoE router
# ---------------------------------------------------------------------------
def moe_router_ref(logits: jax.Array, k: int, *,
                   renormalize: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused softmax + top-k gate.

    logits: (T, E).  Returns (weights (T, k) f32, indices (T, k) i32).
    Weights are the softmax probabilities of the selected experts,
    renormalized to sum to 1 when ``renormalize``.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    if renormalize:
        w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, scale: jax.Array | None,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# WikiKV storage operators (the paper's Q1/Q4 on device)
# ---------------------------------------------------------------------------
def path_lookup_ref(keys_hi: jax.Array, keys_lo: jax.Array,
                    q_hi: jax.Array, q_lo: jax.Array) -> jax.Array:
    """Batched GET over the sorted 64-bit digest table (row id or −1).
    Mirrors core.tensorstore.lookup_ref (kept independent so the kernel
    test oracle has no dependency on core)."""
    n = keys_hi.shape[0]
    lo = jnp.zeros(q_hi.shape, dtype=jnp.int32)
    hi = jnp.full(q_hi.shape, n, dtype=jnp.int32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, n - 1)
        khi = keys_hi[mid_c]
        klo = keys_lo[mid_c]
        lt = (khi < q_hi) | ((khi == q_hi) & (klo < q_lo))
        return (jnp.where(lt, mid + 1, lo), jnp.where(lt, hi, mid))

    steps = int(np.ceil(np.log2(max(int(n), 2)))) + 1
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    idx = jnp.clip(lo, 0, n - 1)
    hit = (keys_hi[idx] == q_hi) & (keys_lo[idx] == q_lo)
    return jnp.where(hit, idx, -1)


def path_lookup_pinned_ref(keys_hi: jax.Array, keys_lo: jax.Array,
                           q_hi: jax.Array, q_lo: jax.Array,
                           pin_hi: jax.Array, pin_lo: jax.Array,
                           pin_pos: jax.Array) -> jax.Array:
    """Oracle for the pinned-probe kernel path: a query matching the
    pinned sub-table resolves to its staged sorted-table position; the
    rest fall through to the binary search.  When the staging is
    consistent (pin_pos[j] == position of (pin_hi, pin_lo)[j] in the
    sorted table), this equals plain ``path_lookup_ref``."""
    base = path_lookup_ref(keys_hi, keys_lo, q_hi, q_lo)
    eq = (pin_hi[None, :] == q_hi[:, None]) & (pin_lo[None, :] == q_lo[:, None])
    hit = jnp.any(eq, axis=1)
    pos = jnp.sum(jnp.where(eq, pin_pos[None, :], 0), axis=1).astype(jnp.int32)
    return jnp.where(hit, pos, base)


def prefix_search_ref(tokens: jax.Array, prefix: jax.Array,
                      prefix_len: jax.Array) -> jax.Array:
    """Bitmap of rows whose packed path starts with ``prefix`` (segment-
    aware).  tokens: (N, L) uint8; prefix: (L,) uint8; prefix_len: int32."""
    L = tokens.shape[1]
    pos = jnp.arange(L, dtype=jnp.int32)
    within = pos < prefix_len
    eq = (tokens == prefix[None, :]) | ~within[None, :]
    starts = jnp.all(eq, axis=1)
    nxt = tokens[:, jnp.minimum(prefix_len, L - 1)]
    last = prefix[jnp.maximum(prefix_len - 1, 0)]
    boundary_ok = (last == ord("/")) | (nxt == 0) | (nxt == ord("/"))
    exact_fits = prefix_len < L
    return starts & jnp.where(exact_fits, boundary_ok, True)
