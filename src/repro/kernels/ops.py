"""Jit'd dispatch wrappers over the Pallas kernels.

Public entry points used by models/ and core/tensorstore.  Each op
dispatches to the Pallas kernel when the platform + shape warrant it and
to the pure-jnp reference otherwise:

  * On TPU (the target), kernels run compiled (``interpret=False``).
  * On CPU (this container), kernels run in interpret mode only inside
    the test suite; production paths (model forward, dry-run lowering)
    use the references so that XLA sees fusible HLO — interpret-mode
    pallas inside a 256-device SPMD lowering would be both meaningless
    and slow.  Set ``REPRO_FORCE_PALLAS=1`` to force kernels everywhere.

The dispatch decision is deliberately centralized here so the hillclimb
loop can flip implementations per-op and re-lower.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from . import ref
from .decode_attention import decode_attention as _decode_pallas
from .flash_attention import flash_attention as _flash_pallas
from .moe_router import moe_router as _router_pallas
from .path_lookup import pad_keys, pad_pinned, path_lookup as _lookup_pallas
from .prefix_search import prefix_search as _prefix_pallas
from .rmsnorm import rmsnorm as _rmsnorm_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover - uninitialized backend
        return False


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    if os.environ.get("REPRO_DISABLE_PALLAS") == "1":
        return False
    return _on_tpu()


# ---------------------------------------------------------------------------
def attention(q, k, v, *, causal: bool = True, sm_scale: float | None = None,
              block_q: int = 128, block_k: int = 128):
    """(B, Hq, Sq, D) × (B, Hkv, Skv, D)² → (B, Hq, Sq, D)."""
    if _use_pallas():
        return _flash_pallas(q, k, v, causal=causal, sm_scale=sm_scale,
                             block_q=block_q, block_k=block_k,
                             interpret=not _on_tpu())
    skv = k.shape[2]
    if skv > 1024 and skv % 1024 == 0:
        # chunked online-softmax path: O(Sq·chunk) peak memory — the form
        # the dry-run lowers so 32k prefill fits HBM
        return ref.chunked_attention_ref(q, k, v, causal=causal,
                                         sm_scale=sm_scale, chunk=1024)
    return ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     sm_scale: float | None = None, block_k: int = 256):
    """(B, Hq, D) × (B, Hkv, S, D)² × (B,) → (B, Hq, D)."""
    if _use_pallas():
        return _decode_pallas(q, k_cache, v_cache, lengths,
                              sm_scale=sm_scale, block_k=block_k,
                              interpret=not _on_tpu())
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                    sm_scale=sm_scale)


def moe_router(logits, k: int, *, renormalize: bool = True,
               block_t: int = 256):
    """(T, E) → (weights (T,k) f32, indices (T,k) i32)."""
    if _use_pallas():
        return _router_pallas(logits, k, renormalize=renormalize,
                              block_t=block_t, interpret=not _on_tpu())
    return ref.moe_router_ref(logits, k, renormalize=renormalize)


def rmsnorm(x, scale=None, eps: float = 1e-6, block_t: int = 256):
    if _use_pallas():
        return _rmsnorm_pallas(x, scale, eps=eps, block_t=block_t,
                               interpret=not _on_tpu())
    return ref.rmsnorm_ref(x, scale, eps=eps)


def path_lookup(keys_hi, keys_lo, q_hi, q_lo, *, pinned=None,
                block_q: int = 256):
    """Sorted-table batched GET.  Keys must be pre-padded via pad_keys for
    the kernel path; the reference accepts any length.  ``pinned`` is the
    optional VMEM hot-set staging triple (hi, lo, sorted-table position) —
    the kernel probes it before touching the HBM table; the reference
    oracle applies the same short-circuit.  The fallback is jitted here —
    the batched QueryEngine calls this once per engine round trip, so an
    eagerly-traced fori_loop would dominate the call."""
    if _use_pallas() and keys_hi.shape[0] % 128 == 0:
        return _lookup_pallas(keys_hi, keys_lo, q_hi, q_lo, pinned=pinned,
                              block_q=block_q, interpret=not _on_tpu())
    if pinned is not None:
        return _path_lookup_pinned_ref_jit(keys_hi, keys_lo, q_hi, q_lo,
                                           *pinned)
    return _path_lookup_ref_jit(keys_hi, keys_lo, q_hi, q_lo)


_path_lookup_ref_jit = jax.jit(ref.path_lookup_ref)
_path_lookup_pinned_ref_jit = jax.jit(ref.path_lookup_pinned_ref)


def prefix_search(tokens, prefixes, prefix_lens, *, block_n: int = 1024):
    """(N, L) × (Q, L) → (N, Q) bitmap.

    The batched QueryEngine path sends whole prefix batches here, so the
    fallback vmaps the single-prefix reference over the query axis — one
    XLA call per batch, matching the kernel's launch granularity."""
    if _use_pallas():
        return _prefix_pallas(tokens, prefixes, prefix_lens,
                              block_n=block_n, interpret=not _on_tpu())
    return _prefix_ref_batched(tokens, prefixes, prefix_lens)


@jax.jit
def _prefix_ref_batched(tokens, prefixes, prefix_lens):
    cols = jax.vmap(lambda p, n: ref.prefix_search_ref(tokens, p, n))(
        prefixes, prefix_lens)                       # (Q, N)
    return cols.T                                    # (N, Q)


__all__ = ["attention", "decode_attention", "moe_router", "rmsnorm",
           "path_lookup", "prefix_search", "pad_keys", "pad_pinned"]
