from .manager import CheckpointManager, restore_elastic  # noqa: F401
