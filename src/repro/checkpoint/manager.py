"""Checkpoint save/restore with elastic remesh (fault tolerance).

Layout: one directory per step —
    <root>/step_<N>/
        meta.json            — step, config digest, tree structure, shapes
        data.npz             — flat leaf arrays (host-gathered)
        pipeline.json        — data-pipeline position (epoch/index/seed)

Design choices for the 1000+-node story (documented trade-offs):
  * Leaves are saved *unsharded* (host-gathered) so a restore can target
    ANY device count / mesh shape — elastic rescale is a pure re-shard at
    load ("restore_elastic").  At true 1T scale one would write per-shard
    files + a resharding index (Orbax-style); the npz single-writer form
    keeps the same restore semantics at repo scale and is what the tests
    exercise.
  * Atomicity: writes go to ``step_N.tmp`` then ``os.replace`` — a crash
    mid-save never corrupts the latest checkpoint (restart-safety test).
  * Retention: ``keep`` newest checkpoints are retained; older ones are
    deleted only after the new save committed.
  * Async: ``save(..., blocking=False)`` hands the host-transfer to a
    worker thread — the train loop overlaps the next step with the write
    (the compute/IO overlap trick at the scale this repo can express).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, pipeline_state: dict | None = None,
             blocking: bool = True) -> Path:
        self.wait()
        host_leaves = [np.asarray(x) for x in jax.tree.leaves(tree)]
        treedef = jax.tree.structure(tree)
        if blocking:
            return self._write(step, host_leaves, treedef, pipeline_state)
        out = self.root / f"step_{step}"

        def work():
            self._write(step, host_leaves, treedef, pipeline_state)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return out

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step, host_leaves, treedef, pipeline_state) -> Path:
        final = self.root / f"step_{step}"
        tmp = self.root / f"step_{step}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "data.npz",
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(a.shape) for a in host_leaves],
            "dtypes": [str(a.dtype) for a in host_leaves],
        }
        (tmp / "meta.json").write_text(json.dumps(meta))
        if pipeline_state is not None:
            (tmp / "pipeline.json").write_text(json.dumps(pipeline_state))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None,
                shardings=None) -> tuple[int, object, dict | None]:
        """Restore into the structure of ``like_tree``; with ``shardings``
        the leaves are device_put with the target sharding (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step}"
        data = np.load(d / "data.npz")
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        treedef = jax.tree.structure(like_tree)
        if treedef.num_leaves != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, tree expects "
                f"{treedef.num_leaves}")
        like_leaves = jax.tree.leaves(like_tree)
        cast = [np.asarray(a, dtype=l.dtype) if hasattr(l, "dtype") else a
                for a, l in zip(leaves, like_leaves)]
        if shardings is not None:
            shard_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda s: hasattr(s, "spec"))
            cast = [jax.device_put(a, s) for a, s in zip(cast, shard_leaves)]
        tree = jax.tree.unflatten(treedef, cast)
        pipeline = None
        pf = d / "pipeline.json"
        if pf.exists():
            pipeline = json.loads(pf.read_text())
        return step, tree, pipeline


def restore_elastic(manager: CheckpointManager, like_tree, mesh, pspecs,
                    step: int | None = None):
    """Elastic restore: re-shard a checkpoint onto a (possibly different)
    mesh — device count changes are transparent because leaves are stored
    unsharded."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda s: isinstance(s, P))
    return manager.restore(like_tree, step=step, shardings=shardings)
