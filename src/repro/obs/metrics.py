"""Mergeable metrics primitives: log-bucket histograms, counters, gauges.

The histogram is the load-bearing piece (ISSUE 8): every latency number
the system reports — engine per-op percentiles, WAL commit/fsync times,
refresh patch-vs-rebuild durations, the benchmark tables — flows through
ONE implementation with FIXED bucket boundaries, so

* per-shard / per-engine / per-process histograms **merge exactly**:
  ``merge(h(A), h(B)) ≡ h(A ∪ B)`` bucket-for-bucket (property-tested in
  tests/test_obs.py), which is what a sharded or multi-process deployment
  needs to report fleet-wide p99 without shipping raw samples; and
* ``ServingEngine.stats_snapshot()`` and the benchmark tables read
  percentiles out of the same logic — identical samples give identical
  p50/p99 by construction, not by coincidence.

Bucketing: value ``v > 0`` lands in bucket ``floor(log2(v) * SUB)`` with
``SUB = 16`` sub-buckets per octave — ~4.4% relative bucket width, so a
reported percentile is within ~2.2% of the exact sample percentile
(nearest-rank).  Buckets are sparse (dict), value-domain agnostic (the
repo convention is milliseconds for latency histograms), and the exact
``min``/``max``/``sum`` are tracked alongside, so ``max`` (and p100) are
never quantized.  Non-positive values count in a dedicated zero bucket.

Everything here is dependency-free stdlib.  The histogram/counter write
paths take a per-metric lock: spans record from shard-executor, commit-
sequencer, and background-compaction worker threads (ISSUE 10), and an
unlocked ``self.n += 1`` read-modify-write drops increments under that
concurrency.  Gauges stay lock-free — a single reference assignment is
atomic and last-write-wins is their contract anyway.
"""
from __future__ import annotations

import math
import threading
from typing import Iterable

#: sub-buckets per power of two — fixed FOREVER at the format level:
#: changing it would silently break merges between old and new snapshots
SUB = 16
_INV_LOG2 = SUB / math.log(2.0)


def bucket_of(v: float) -> int:
    """Fixed global bucket index for ``v > 0``."""
    return math.floor(math.log(v) * _INV_LOG2)


def bucket_value(idx: int) -> float:
    """Representative (geometric midpoint) value of bucket ``idx``."""
    return 2.0 ** ((idx + 0.5) / SUB)


class Histogram:
    """Sparse log-bucket histogram with exact-merge semantics."""

    __slots__ = ("counts", "n", "total", "vmin", "vmax", "zeros", "_lock")

    def __init__(self, samples: Iterable[float] = ()):
        self.counts: dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.zeros = 0
        self._lock = threading.Lock()
        for v in samples:
            self.record(v)

    # -- write path ---------------------------------------------------------
    def record(self, v: float) -> None:
        """O(1), allocation-free (dict slot reuse after first touch)."""
        with self._lock:
            self.n += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if v <= 0.0:
                self.zeros += 1
                return
            b = math.floor(math.log(v) * _INV_LOG2)
            self.counts[b] = self.counts.get(b, 0) + 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into ``self`` (exact: fixed shared boundaries).
        Returns ``self`` for chaining."""
        with self._lock:
            for b, c in other.counts.items():
                self.counts[b] = self.counts.get(b, 0) + c
            self.n += other.n
            self.total += other.total
            self.zeros += other.zeros
            self.vmin = min(self.vmin, other.vmin)
            self.vmax = max(self.vmax, other.vmax)
            return self

    # -- read path ----------------------------------------------------------
    def percentile(self, q: float) -> float:
        """Nearest-rank percentile ``q`` ∈ [0, 100] over the recorded
        distribution; bucket geometric midpoints, exact at the extremes
        (p0 → true min, p100 → true max).  0.0 on an empty histogram."""
        with self._lock:
            if self.n == 0:
                return 0.0
            if q <= 0:
                return self.vmin
            if q >= 100:
                return self.vmax
            rank = max(1, math.ceil(q / 100.0 * self.n))
            if rank <= self.zeros:
                return 0.0
            seen = self.zeros
            for b in sorted(self.counts):
                seen += self.counts[b]
                if seen >= rank:
                    # clamp into the true observed range so a one-bucket
                    # histogram reports its real sample, not the midpoint
                    return min(max(bucket_value(b), self.vmin), self.vmax)
            return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def summary(self) -> dict:
        """JSON-able fixed-schema summary (the snapshot row format)."""
        empty = self.n == 0
        return {
            "count": self.n,
            "mean": round(self.mean, 6),
            "p50": round(self.percentile(50), 6),
            "p90": round(self.percentile(90), 6),
            "p99": round(self.percentile(99), 6),
            "max": 0.0 if empty else round(self.vmax, 6),
            "min": 0.0 if empty else round(self.vmin, 6),
        }


class Counter:
    """Monotone counter (wire format: one int)."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class _NullMetric:
    """Shared no-op stand-in handed out by a disabled registry: every
    recording call is a constant-time no-op with ZERO allocations — the
    "telemetry is free when off" half of the ISSUE 8 acceptance."""

    __slots__ = ()

    def record(self, v: float) -> None:
        pass

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass


NULL_METRIC = _NullMetric()
