"""``repro.obs`` — dependency-free tracing + metrics for every tier.

One :class:`Registry` holds the three metric kinds (mergeable log-bucket
:class:`~repro.obs.metrics.Histogram`, ``Counter``, ``Gauge``), the
bounded span ring, and the correlation context (wave / epoch / session
ids).  The serving stack, engines, planner, and durable tier all talk to
the **process-global registry** through the module-level helpers below —
``obs.span("wal.commit")``, ``obs.histogram("serving.request_nav_ms")``
— so one export covers the whole stack.

Switched by ``REPRO_TRACE`` (default ``0``): when disabled, ``span()``
returns a no-op singleton and the metric accessors return a shared null
metric — no clock reads, no dict churn, zero allocations on every hot
path (the bench gate runs with tracing off and must see no regression).
``configure(enabled=True)`` flips the live registry at runtime (tests,
``examples/quickstart.py``); ``REPRO_STATS_EVERY`` and
``REPRO_TRACE_RING`` tune the serving stats-log cadence and the ring
size (see docs/OBSERVABILITY.md for the contracts and the snapshot
schema).
"""
from __future__ import annotations

import os
import time
from collections import deque

from .metrics import NULL_METRIC, Counter, Gauge, Histogram
from .trace import NULL_SPAN, Span, export_events, load_events, validate_events

#: master switch: "1"/"true"/"on" enable tracing + metric recording
TRACE_ENV = "REPRO_TRACE"
#: span ring capacity (events retained for export); default 65536
RING_ENV = "REPRO_TRACE_RING"
#: serving stats-log cadence in waves; 0 (default) disables the log line
STATS_EVERY_ENV = "REPRO_STATS_EVERY"

_TRUTHY = ("1", "true", "on", "yes")


def _env_enabled() -> bool:
    return os.environ.get(TRACE_ENV, "0").strip().lower() in _TRUTHY


def stats_every() -> int:
    """Resolved ``REPRO_STATS_EVERY`` (0 ⇒ periodic stats log off)."""
    try:
        return max(0, int(os.environ.get(STATS_EVERY_ENV, "0")))
    except ValueError:
        return 0


class Registry:
    """Metrics + trace ring + correlation context, one per process (the
    module-global default) or per test (instantiate directly)."""

    def __init__(self, enabled: bool | None = None,
                 ring_size: int | None = None):
        self.enabled = _env_enabled() if enabled is None else bool(enabled)
        if ring_size is None:
            ring_size = int(os.environ.get(RING_ENV, str(64 * 1024)))
        self.ring: deque = deque(maxlen=max(16, ring_size))
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.ctx: dict[str, object] = {}
        self.t0 = time.perf_counter()
        self.pid = os.getpid()

    # -- metric accessors (null objects when disabled) ----------------------
    # setdefault is a single atomic dict op, so two worker threads racing
    # to create the same metric get the same object (a stray loser
    # Histogram() is garbage, never a dropped-sample sink)
    def histogram(self, name: str):
        if not self.enabled:
            return NULL_METRIC
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms.setdefault(name, Histogram())
        return h

    def counter(self, name: str):
        if not self.enabled:
            return NULL_METRIC
        c = self.counters.get(name)
        if c is None:
            c = self.counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str):
        if not self.enabled:
            return NULL_METRIC
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges.setdefault(name, Gauge())
        return g

    # -- spans --------------------------------------------------------------
    def span(self, name: str, **tags):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, tags or None)

    def set_context(self, **ids) -> None:
        """Update correlation ids (wave/epoch/session) captured into the
        args of every subsequently recorded span."""
        if self.enabled:
            self.ctx.update(ids)

    # -- export / snapshot --------------------------------------------------
    def export_trace(self, path: str) -> int:
        """Write the ring as Chrome trace-event / Perfetto JSON; returns
        the number of events exported."""
        return export_events(list(self.ring), path)

    def metrics_snapshot(self) -> dict:
        """JSON-able state of every metric (fixed schema; empty dicts
        when disabled — the schema never changes shape)."""
        return {
            "latency_ms": {name: h.summary()
                           for name, h in sorted(self.histograms.items())},
            "counters": {name: c.value
                         for name, c in sorted(self.counters.items())},
            "gauges": {name: g.value
                       for name, g in sorted(self.gauges.items())},
        }

    def reset(self) -> None:
        """Drop all recorded state (metrics, ring, context); keeps the
        enabled flag and the clock origin."""
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()
        self.ring.clear()
        self.ctx.clear()


_registry = Registry()


def registry() -> Registry:
    return _registry


def configure(enabled: bool | None = None,
              ring_size: int | None = None) -> Registry:
    """Replace the global registry (runtime enable/disable for tests and
    examples); returns the new registry."""
    global _registry
    _registry = Registry(enabled=enabled, ring_size=ring_size)
    return _registry


def enabled() -> bool:
    return _registry.enabled


def span(name: str, **tags):
    return _registry.span(name, **tags)


def histogram(name: str):
    return _registry.histogram(name)


def counter(name: str):
    return _registry.counter(name)


def gauge(name: str):
    return _registry.gauge(name)


def set_context(**ids) -> None:
    _registry.set_context(**ids)


def export_trace(path: str) -> int:
    return _registry.export_trace(path)


# ---------------------------------------------------------------------------
# the live stats surface (ServingEngine.stats_snapshot + quickstart)
# ---------------------------------------------------------------------------
#: read-op dedup ratio keys (logical ops served / unique keys executed)
_READ_OPS = ("q1_get", "q2_ls", "q3_navigate", "q4_search", "q4_contains")


def build_snapshot(engine=None, planner=None, extra: dict | None = None) -> dict:
    """Assemble the JSON-able live stats snapshot: engine op accounting,
    planner wave/dedup state, refresh + durable-tier telemetry, and every
    registry metric.  The TOP-LEVEL KEY SET is a stable contract
    (tests/test_obs.py pins it) — new fields nest under existing keys."""
    reg = _registry
    snap: dict = {
        "trace_enabled": reg.enabled,
        "epoch": 0,
        "waves": 0,
        "ops": {},
        "dedup_ratio": {},
        "refresh": {},
        "durable": {},
        "pending": {},
    }
    snap.update(reg.metrics_snapshot())
    if engine is not None:
        sync = getattr(engine, "sync_durable_stats", None)
        if sync is not None:
            sync()
        st = engine.stats
        snap["epoch"] = engine.epoch
        snap["ops"] = {"calls": dict(st.calls), "ops": dict(st.ops),
                       "served": dict(st.served),
                       "max_batch": dict(st.max_batch),
                       "max_served": dict(st.max_served)}
        snap["dedup_ratio"] = {
            op: round(st.served[op] / st.ops[op], 4)
            for op in _READ_OPS
            if st.ops.get(op) and st.served.get(op) is not None}
        snap["refresh"] = {
            "commits": st.calls.get("refresh", 0),
            "rows": st.ops.get("refresh", 0),
            "patch": st.calls.get("refresh_patch", 0),
            "rebuild": st.calls.get("refresh_rebuild", 0),
            "last_kind": getattr(engine, "last_refresh_kind", None),
            "deferred_waves": getattr(engine, "_deferred_waves", 0),
        }
        bloom_neg = st.ops.get("d_bloom_neg", 0)
        hit = st.ops.get("d_cache_hit", 0)
        miss = st.ops.get("d_cache_miss", 0)
        debt = st.ops.get("d_compact_debt", 0)
        snap["durable"] = {
            "bloom_neg": bloom_neg, "cache_hit": hit, "cache_miss": miss,
            "cache_hit_rate": round(hit / (hit + miss), 4) if hit + miss else 0.0,
            "seg_probe": st.ops.get("d_seg_probe", 0),
            # compaction backpressure: outstanding merge bytes (gauge)
            # and whether the serving tier should expect throttled waves
            "compact_debt": debt,
            "backpressure": bool(debt),
            # pipelined group commit: sealed-but-not-durable waves (0/1)
            "commit_pipeline_depth": st.ops.get("d_commit_pipeline_depth", 0),
        }
    if planner is not None:
        snap["waves"] = planner.flushes
        snap["pending"]["planner_ops"] = planner.pending_ops()
        snap["pending"]["planner_writes"] = planner.pending_writes()
    if extra:
        snap.update(extra)
    return snap


def format_snapshot(snap: dict) -> str:
    """Human-readable summary table of a :func:`build_snapshot` dict (the
    quickstart's exit print)."""
    lines = [f"telemetry (trace_enabled={snap['trace_enabled']}, "
             f"epoch={snap['epoch']}, waves={snap['waves']})"]
    lat = snap.get("latency_ms", {})
    if lat:
        lines.append(f"  {'span':32s} {'count':>7s} {'p50ms':>9s} "
                     f"{'p90ms':>9s} {'p99ms':>9s} {'maxms':>9s}")
        for name, s in lat.items():
            lines.append(f"  {name:32s} {s['count']:7d} {s['p50']:9.3f} "
                         f"{s['p90']:9.3f} {s['p99']:9.3f} {s['max']:9.3f}")
    calls = snap.get("ops", {}).get("calls", {})
    if calls:
        ops = snap["ops"]["ops"]
        lines.append("  engine calls: " + "  ".join(
            f"{op}={n}({ops.get(op, 0)} keys)"
            for op, n in sorted(calls.items())))
    if snap.get("dedup_ratio"):
        lines.append("  dedup (served/keys): " + "  ".join(
            f"{op}={r:.2f}" for op, r in sorted(snap["dedup_ratio"].items())))
    dur = snap.get("durable", {})
    if any(dur.get(k) for k in ("bloom_neg", "cache_hit", "cache_miss",
                                "seg_probe")):
        lines.append(f"  durable: bloom_neg={dur['bloom_neg']} "
                     f"cache_hit_rate={dur['cache_hit_rate']:.2f} "
                     f"seg_probe={dur.get('seg_probe', 0)}")
    if dur.get("compact_debt"):
        lines.append(f"  compaction backpressure: "
                     f"debt={dur['compact_debt']}B")
    return "\n".join(lines)


__all__ = ["Registry", "Histogram", "Counter", "Gauge", "Span",
           "NULL_SPAN", "NULL_METRIC",
           "registry", "configure", "enabled", "span", "histogram",
           "counter", "gauge", "set_context", "export_trace",
           "build_snapshot", "format_snapshot", "stats_every",
           "load_events", "validate_events", "export_events",
           "TRACE_ENV", "RING_ENV", "STATS_EVERY_ENV"]
