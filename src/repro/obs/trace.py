"""Wave-scoped spans, the bounded trace ring, and the Perfetto exporter.

A :class:`Span` measures one timed region on the monotonic clock
(``time.perf_counter``) and, at exit, (1) appends one Chrome
trace-event-format ``"ph": "X"`` (complete) event to the registry's
bounded ring buffer and (2) records its duration into the registry
histogram of the same name — so every span is simultaneously a trace
line (open the export in ``chrome://tracing`` / Perfetto) and a latency
sample (read percentiles out of ``stats_snapshot()``).

Correlation: every span captures the registry's current **context ids**
(wave / epoch / session — set by the planner and serving loop at wave
boundaries) into its ``args``, so a WAL commit deep in the storage tier
carries the planner wave that caused it.  Nesting is positional, the
Chrome way: spans on one thread close LIFO (context managers), so any
two events on a ``tid`` are either disjoint or properly contained —
``validate_events`` checks exactly that invariant plus clock
monotonicity, and ``scripts/check_trace.py`` runs it in CI against the
trace the smoke serving wave exports.

When tracing is disabled (``REPRO_TRACE=0``, the default) ``span()``
returns the :data:`NULL_SPAN` singleton: enter/exit are no-ops, nothing
is timed, nothing is allocated.
"""
from __future__ import annotations

import json
import os
import threading
import time

# Chrome trace-event keys — see the Trace Event Format spec (Perfetto
# loads this JSON directly)
_PH_COMPLETE = "X"


class Span:
    """One timed region; use as a context manager.  ``set(**tags)`` adds
    args after entry (e.g. a result kind known only at the end)."""

    __slots__ = ("_reg", "name", "args", "_t0", "dur_ms")

    def __init__(self, reg, name: str, args: dict | None):
        self._reg = reg
        self.name = name
        self.args = args
        self._t0 = 0.0
        self.dur_ms = 0.0

    def set(self, **tags) -> "Span":
        if self.args is None:
            self.args = tags
        else:
            self.args.update(tags)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        self.dur_ms = (t1 - self._t0) * 1e3
        reg = self._reg
        args = dict(reg.ctx)
        if self.args:
            args.update(self.args)
        reg.ring.append({
            "name": self.name,
            "ph": _PH_COMPLETE,
            "ts": (self._t0 - reg.t0) * 1e6,      # µs since registry birth
            "dur": (t1 - self._t0) * 1e6,
            "pid": reg.pid,
            "tid": threading.get_ident(),
            "args": args,
        })
        reg.histogram(self.name).record(self.dur_ms)
        return False


class _NullSpan:
    """The disabled-mode singleton: no clock reads, no ring append, no
    histogram, no allocations."""

    __slots__ = ()
    name = ""
    dur_ms = 0.0

    def set(self, **tags) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NULL_SPAN = _NullSpan()


# ---------------------------------------------------------------------------
# export + validation
# ---------------------------------------------------------------------------
def export_events(events: list[dict], path: str) -> int:
    """Write ``events`` as a Chrome trace-event / Perfetto JSON object
    (``{"traceEvents": [...]}``); returns the event count."""
    doc = {"traceEvents": list(events), "displayTimeUnit": "ms"}
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return len(doc["traceEvents"])


def load_events(path: str) -> list[dict]:
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    events = doc.get("traceEvents") if isinstance(doc, dict) else None
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a Chrome trace-event document "
                         "(no traceEvents list)")
    return events


def validate_events(events: list[dict],
                    require: tuple[str, ...] = ()) -> list[str]:
    """Structural validity of a span trace; returns problem strings
    (empty ⇒ valid).  Checks:

    * every event is a complete ("X") span with numeric ``ts``/``dur``
      ≥ 0 and a ``tid``;
    * per-``tid`` spans are **well-nested**: sorted by start (ties: the
      longer span opens first — the enclosing context manager entered
      first), every span either starts after the enclosing span ends or
      ends within it (with a float-µs tolerance for clock granularity);
    * per-``tid`` start times are monotone in that sort — a span never
      starts before trace time 0;
    * every name in ``require`` appears at least once (the smoke gate's
      planner-wave → engine-op → device-refresh → WAL-commit coverage).
    """
    problems: list[str] = []
    by_tid: dict[object, list[tuple[float, float, str]]] = {}
    seen: set[str] = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        name = ev.get("name")
        ts, dur = ev.get("ts"), ev.get("dur")
        if ev.get("ph") != _PH_COMPLETE:
            problems.append(f"event {i} ({name}): ph != 'X'")
            continue
        if not isinstance(ts, (int, float)) or not isinstance(dur, (int, float)):
            problems.append(f"event {i} ({name}): non-numeric ts/dur")
            continue
        if ts < 0 or dur < 0:
            problems.append(f"event {i} ({name}): negative ts/dur")
            continue
        seen.add(str(name))
        by_tid.setdefault(ev.get("tid"), []).append(
            (float(ts), float(dur), str(name)))
    eps = 1.5  # µs of tolerance: ring append happens after the clock read
    for tid, spans in by_tid.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple[float, float, str]] = []
        for ts, dur, name in spans:
            while stack and ts >= stack[-1][0] + stack[-1][1] - eps:
                stack.pop()
            if stack and ts + dur > stack[-1][0] + stack[-1][1] + eps:
                outer = stack[-1]
                problems.append(
                    f"tid {tid}: span {name!r} [{ts:.1f}, {ts + dur:.1f}] "
                    f"overlaps {outer[2]!r} "
                    f"[{outer[0]:.1f}, {outer[0] + outer[1]:.1f}] "
                    "without nesting")
            stack.append((ts, dur, name))
    for name in require:
        if name not in seen:
            problems.append(f"required span {name!r} absent from trace")
    return problems
