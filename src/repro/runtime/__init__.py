from .train_loop import TrainLoop, TrainLoopConfig  # noqa: F401
from .straggler import StragglerPolicy  # noqa: F401
from .serving import ServingEngine, Request  # noqa: F401
