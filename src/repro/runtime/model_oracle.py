"""ModelOracle: a zoo LM standing behind the Oracle interface.

Replaces the paper's DeepSeek-V4-Flash with any architecture from the
registry (greedy decode, deterministic).  The lexical fallbacks of
HeuristicOracle remain the *semantic* layer — the LM supplies
classification/coverage signals from its logits where that is meaningful
at repo scale (the router LM trained by examples/train_router.py).

Division of labor:
  classify_query — LM-logit route scoring over {ENUMERATE, LOOKUP,
                   AGGREGATE} prompts (falls back to regex fast path
                   first, exactly like the paper's hybrid router)
  needs_deeper   — perplexity-of-query-given-content proxy: mean NLL of
                   the query tokens conditioned on the page prefix;
                   high NLL ⇒ page does not cover the query.
  everything else delegates to the heuristic layer (schema induction
  stays intent-anchored and deterministic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.oracle import HeuristicOracle, ROUTE_ENUMERATE
from ..data.tokenizer import HashTokenizer
from ..models import model as M
from ..models import transformer as T
from ..models.config import ModelConfig


class ModelOracle(HeuristicOracle):
    def __init__(self, cfg: ModelConfig, params, tokenizer: HashTokenizer,
                 mesh=None, seed: int = 0):
        super().__init__(seed=seed)
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self._loss = jax.jit(
            lambda p, b: T.loss_fn(p, b, cfg, mesh))

    def _nll(self, prefix: str, target: str) -> float:
        ids = self.tok.encode(f"{prefix} {target}")
        tgt_len = len(self.tok.encode(target, add_special=False))
        toks = jnp.asarray(ids[:-1], jnp.int32)[None, :]
        labels = np.full((len(ids) - 1,), -1, np.int32)
        labels[-tgt_len:] = ids[-tgt_len:]
        batch = {"tokens": toks, "labels": jnp.asarray(labels)[None, :]}
        return float(self._loss(self.params, batch))

    def classify_query(self, q):
        self.calls["classify_query"] += 1
        # regex fast path (paper: <5 ms layer) …
        cls = super().classify_query(q)
        if cls == ROUTE_ENUMERATE:
            return cls
        # … then the distilled-classifier path: lowest continuation NLL
        candidates = {
            "LOOKUP": "this asks about one specific page",
            "AGGREGATE": "this asks to combine several pages",
        }
        scores = {k: self._nll(q, v) for k, v in candidates.items()}
        return min(scores, key=scores.get)

    def needs_deeper(self, q, content, theta: float = 0.34) -> bool:
        self.calls["needs_deeper"] += 1
        if not content.strip():
            return True
        # coverage ∝ −NLL(query | page prefix); calibrate against the
        # unconditional NLL so theta keeps the paper's [0,1] semantics
        cond = self._nll(content[:512], q)
        uncond = self._nll("", q)
        coverage = max(0.0, min(1.0, (uncond - cond) / max(uncond, 1e-6) + 0.5))
        return coverage < theta
