"""Straggler mitigation for synchronous data-parallel training.

At pod scale, synchronous SGD waits for the slowest participant.  The
policy here is *deadline-based contribution skipping*: a step has a
deadline D = μ + k·σ over a rolling window of recent step times; a worker
(or microbatch shard) that would exceed the deadline contributes a zero
gradient for the step and the surviving gradients are rescaled by
``world / survivors`` — an unbiased estimator under random stragglers
(the Backup-Workers recipe of Chen et al., adapted to deterministic
deadlines instead of replica redundancy).

This module is deliberately *host-side logic over measurements* (the
decision layer); the gradient rescale itself is one multiply inside the
train step.  Tests drive it with synthetic timing traces; the real-signal
integration point is ``TrainLoop.step()``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class StragglerPolicy:
    window: int = 50
    k_sigma: float = 3.0
    min_survivors_frac: float = 0.75
    _times: deque = field(default_factory=lambda: deque(maxlen=50))
    skipped_total: int = 0

    def observe(self, step_time_s: float) -> None:
        self._times.append(step_time_s)

    def deadline(self) -> float | None:
        if len(self._times) < max(8, self._times.maxlen // 5):
            return None
        xs = list(self._times)
        mu = sum(xs) / len(xs)
        var = sum((x - mu) ** 2 for x in xs) / len(xs)
        return mu + self.k_sigma * (var ** 0.5)

    def decide(self, worker_times: list[float]) -> tuple[list[bool], float]:
        """Given per-worker projected step times, return (keep mask,
        gradient rescale).  Never drops below min_survivors_frac — beyond
        that the step must wait (correctness over latency)."""
        d = self.deadline()
        n = len(worker_times)
        if d is None:
            return [True] * n, 1.0
        keep = [t <= d for t in worker_times]
        survivors = sum(keep)
        min_surv = max(int(n * self.min_survivors_frac), 1)
        if survivors < min_surv:
            # keep the fastest min_surv workers instead
            order = sorted(range(n), key=lambda i: worker_times[i])
            keep = [False] * n
            for i in order[:min_surv]:
                keep[i] = True
            survivors = min_surv
        self.skipped_total += n - survivors
        return keep, n / survivors
