"""Training loop: jit'd step + checkpointing + fault tolerance glue.

Composes: model train_step (grad + AdamW), data pipeline (resumable),
checkpoint manager (async, atomic), straggler policy, optional gradient
compression on the DP reduce.  ``run()`` is crash-restartable: on start
it restores the latest checkpoint (params, opt state, data position) if
one exists.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..data.pipeline import DataPipeline
from ..models import model as M
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig, adamw_init
from .straggler import StragglerPolicy


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep: int = 3
    async_checkpoint: bool = True
    log_every: int = 10


@dataclass
class TrainMetrics:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


class TrainLoop:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 loop_cfg: TrainLoopConfig, pipeline: DataPipeline,
                 mesh=None, seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.loop_cfg = loop_cfg
        self.pipeline = pipeline
        self.mesh = mesh
        self.ckpt = CheckpointManager(loop_cfg.checkpoint_dir,
                                      keep=loop_cfg.keep)
        self.straggler = StragglerPolicy()
        self.metrics = TrainMetrics()

        self.params = M.init_params(cfg, seed=seed)
        self.opt_state = adamw_init(self.params, opt_cfg)
        step_fn = M.make_train_step(cfg, opt_cfg, mesh,
                                    total_steps=loop_cfg.total_steps)
        self._step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.step_no = 0

    # ------------------------------------------------------------------
    def maybe_restore(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        step, tree, pipe = self.ckpt.restore(state)
        self.params, self.opt_state = tree["params"], tree["opt"]
        if pipe is not None:
            self.pipeline.restore(pipe)
        self.step_no = step
        return True

    def run(self, n_steps: int | None = None) -> TrainMetrics:
        self.maybe_restore()
        target = (self.step_no + n_steps if n_steps is not None
                  else self.loop_cfg.total_steps)
        while self.step_no < target:
            t0 = time.perf_counter()
            batch = self.pipeline.next_batch()
            self.params, self.opt_state, aux = self._step(
                self.params, self.opt_state, batch)
            loss = float(aux["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(dt)
            self.step_no += 1
            self.metrics.steps.append(self.step_no)
            self.metrics.losses.append(loss)
            self.metrics.step_times.append(dt)
            if self.step_no % self.loop_cfg.checkpoint_every == 0:
                self.save()
            if self.step_no % self.loop_cfg.log_every == 0:
                print(f"step {self.step_no:5d} loss {loss:.4f} "
                      f"({dt*1000:.0f} ms)", flush=True)
        self.ckpt.wait()
        return self.metrics

    def save(self) -> None:
        self.ckpt.save(self.step_no,
                       {"params": self.params, "opt": self.opt_state},
                       pipeline_state=self.pipeline.snapshot(),
                       blocking=not self.loop_cfg.async_checkpoint)
