"""Serving engine: continuous-batching decode over the WikiKV substrate.

The online tier of the paper, composed end-to-end:
  request → NAV(q,B) over the (tensorized) wiki → evidence → generation
  via the zoo LM's decode loop (continuous batching: new requests join
  the batch at any step, finished ones retire and free their slot).

The engine demonstrates the serving-side integration of the storage layer
— the LM reads *paths + payloads surfaced by NAV*, and every per-query
trace (tool calls, pages read) feeds the Table V metrics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cache import TieredCache
from ..core.navigate import Navigator, UnitBudget, WallClockBudget
from ..core.oracle import Oracle
from ..core.store import PathStore
from ..data.tokenizer import HashTokenizer, EOS
from ..models import model as M
from ..models import transformer as T
from ..models.config import ModelConfig


@dataclass
class Request:
    rid: str
    query: str
    budget_units: int = 400
    max_new_tokens: int = 32
    # filled by the engine:
    answer: str = ""
    nav_results: list = field(default_factory=list)
    trace: object = None
    latency_s: float = 0.0
    done: bool = False


class ServingEngine:
    """Slots-based continuous batching: ``batch_size`` decode lanes; each
    lane holds one active request's token state."""

    def __init__(self, cfg: ModelConfig, params, tokenizer: HashTokenizer,
                 store: PathStore, oracle: Oracle,
                 cache: TieredCache | None = None,
                 batch_size: int = 4, max_len: int = 512, mesh=None):
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.nav = Navigator(store, oracle, cache=cache)
        self.oracle = oracle
        self.batch_size = batch_size
        self.max_len = max_len
        self._serve = jax.jit(M.make_serve_step(cfg, mesh))
        self.state = T.init_decode_state(cfg, batch_size, max_len)
        self.lengths = jnp.zeros((batch_size,), jnp.int32)
        self.tokens = jnp.zeros((batch_size,), jnp.int32)
        self.slots: list[Optional[Request]] = [None] * batch_size
        self._remaining = [0] * batch_size
        self._gen: list[list[int]] = [[] for _ in range(batch_size)]

    # ------------------------------------------------------------------
    def _retrieve(self, req: Request) -> str:
        t0 = time.perf_counter()
        results, trace = self.nav.nav(req.query, UnitBudget(req.budget_units))
        req.nav_results = results
        req.trace = trace
        req.latency_s = time.perf_counter() - t0
        evidence = [r.text for r in results if r.text]
        return self.oracle.answer(req.query, evidence)

    def _admit(self, req: Request, slot: int) -> None:
        """Prefill the lane with the evidence-conditioned prompt."""
        answer_seed = self._retrieve(req)
        req.answer = answer_seed
        prompt = f"question: {req.query} evidence: {answer_seed}"
        ids = self.tok.encode(prompt)[: self.max_len - req.max_new_tokens - 1]
        # sequential prefill through the decode path (single-lane writes)
        self.lengths = self.lengths.at[slot].set(0)
        for t in ids:
            toks = self.tokens.at[slot].set(t)
            nxt, _, self.state = self._serve(
                self.params, self.state,
                {"tokens": toks, "lengths": self.lengths})
            self.lengths = self.lengths.at[slot].add(1)
        self.tokens = self.tokens.at[slot].set(int(ids[-1]) if ids else 1)
        self.slots[slot] = req
        self._remaining[slot] = req.max_new_tokens
        self._gen[slot] = []

    def submit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self._admit(req, i)
                return True
        return False

    def step(self) -> list[Request]:
        """One decode step for every active lane; returns retired requests."""
        if not any(s is not None for s in self.slots):
            return []
        nxt, logits, self.state = self._serve(
            self.params, self.state,
            {"tokens": self.tokens, "lengths": self.lengths})
        self.tokens = nxt
        self.lengths = self.lengths + jnp.asarray(
            [1 if s is not None else 0 for s in self.slots], jnp.int32)
        done: list[Request] = []
        nxt_host = np.asarray(nxt)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._gen[i].append(int(nxt_host[i]))
            self._remaining[i] -= 1
            if (self._remaining[i] <= 0 or int(nxt_host[i]) == EOS
                    or int(self.lengths[i]) >= self.max_len - 1):
                gen_text = self.tok.decode(self._gen[i])
                # generation refines the evidence answer; the evidence
                # answer itself stays authoritative for AC scoring
                req.answer = (req.answer + " " + gen_text).strip()
                req.done = True
                done.append(req)
                self.slots[i] = None
        return done

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive a queue through the continuous-batching loop."""
        pending = list(requests)
        finished: list[Request] = []
        while pending or any(s is not None for s in self.slots):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            finished.extend(self.step())
        return finished
