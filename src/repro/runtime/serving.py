"""Serving engine: continuous-batching decode over the WikiKV substrate.

The online tier of the paper, composed end-to-end:
  request → NAV(q,B) over the (tensorized) wiki → evidence → generation
  via the zoo LM's decode loop (continuous batching: new requests join
  the batch at any step, finished ones retire and free their slot).

Storage operations batch exactly like tokens do: every admitted request
runs its navigation as a *session generator* against the shared
``BatchPlanner`` (core/engine.py), and ``step()`` drains ONE planner
batch per decode step — all in-flight sessions' pending Q1–Q4 operations
execute as one engine call per operator, then every lane with decided
tokens advances.  The storage substrate is pluggable: a host
``PathStore``/``ShardedPathStore`` or the device ``QueryEngine`` whose
Q1/Q4 run in the Pallas kernels.

Every per-query trace (tool calls, pages read) feeds the Table V metrics.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.cache import TieredCache
from ..core.engine import BatchPlanner, HostEngine, QueryEngine
from ..core.navigate import Navigator, UnitBudget
from ..core.oracle import Oracle
from ..data.tokenizer import HashTokenizer, EOS
from ..models import model as M
from ..models import transformer as T
from ..models.config import ModelConfig


@dataclass
class Request:
    rid: str
    query: str
    budget_units: int = 400
    max_new_tokens: int = 32
    # filled by the engine:
    answer: str = ""
    nav_results: list = field(default_factory=list)
    trace: object = None
    latency_s: float = 0.0
    done: bool = False


class ServingEngine:
    """Slots-based continuous batching: ``batch_size`` decode lanes; each
    lane holds one active request.  A lane's lifecycle is
    navigating → decoding → retired: while navigating, the lane's session
    contributes storage ops to the per-step planner batch; once its
    navigation completes it prefills and joins token decoding."""

    def __init__(self, cfg: ModelConfig, params, tokenizer: HashTokenizer,
                 store, oracle: Oracle,
                 cache: TieredCache | None = None,
                 batch_size: int = 4, max_len: int = 512, mesh=None,
                 write_batch: int = 8):
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        if isinstance(store, QueryEngine):
            self.engine = store
        else:
            self.engine = HostEngine(store)
        self.planner = BatchPlanner(self.engine)
        self.nav = Navigator(self.planner, oracle, cache=cache)
        self.oracle = oracle
        self.batch_size = batch_size
        self.max_len = max_len
        # online write path: queued admissions/unlinks drain into the
        # planner at most ``write_batch`` per decode step, so writes batch
        # at token cadence and never starve the read wave
        self.write_batch = write_batch
        self._write_q: deque[tuple[str, str, object]] = deque()
        self._serve = jax.jit(M.make_serve_step(cfg, mesh))
        self.state = T.init_decode_state(cfg, batch_size, max_len)
        self.lengths = jnp.zeros((batch_size,), jnp.int32)
        self.tokens = jnp.zeros((batch_size,), jnp.int32)
        self.slots: list[Optional[Request]] = [None] * batch_size
        self._remaining = [0] * batch_size
        self._gen: list[list[int]] = [[] for _ in range(batch_size)]
        # storage phase state per lane: (session generator, t0) or None
        self._nav: list = [None] * batch_size
        self._decoding = [False] * batch_size

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit a request into a free lane.  Navigation starts on the
        next ``step()``; the lane joins decoding when its session ends."""
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self._nav[i] = (self.nav.session(req.query,
                                                 UnitBudget(req.budget_units)),
                                time.perf_counter())
                self._decoding[i] = False
                # correlation id: the most recently admitted session (the
                # ctx is global; per-lane attribution rides span args)
                obs.set_context(session=req.rid)
                return True
        return False

    # ------------------------------------------------------------------
    def _finish_nav(self, slot: int, value, t0: float) -> None:
        """Session ended: score evidence, prefill the lane, arm decode."""
        req = self.slots[slot]
        results, trace = value
        req.nav_results = results
        req.trace = trace
        req.latency_s = time.perf_counter() - t0
        # fold the request's navigation latency into the shared histogram
        # (stats_snapshot percentiles; trace off ⇒ no-op)
        obs.histogram("serving.request_nav_ms").record(req.latency_s * 1e3)
        obs.counter("serving.requests_nav_done").inc()
        evidence = [r.text for r in results if r.text]
        req.answer = self.oracle.answer(req.query, evidence)
        self._prefill(slot, req)

    def _prefill(self, slot: int, req: Request) -> None:
        """Prefill the lane with the evidence-conditioned prompt."""
        prompt = f"question: {req.query} evidence: {req.answer}"
        ids = self.tok.encode(prompt)[: self.max_len - req.max_new_tokens - 1]
        # sequential prefill through the decode path (single-lane writes)
        self.lengths = self.lengths.at[slot].set(0)
        for t in ids:
            toks = self.tokens.at[slot].set(t)
            nxt, _, self.state = self._serve(
                self.params, self.state,
                {"tokens": toks, "lengths": self.lengths})
            self.lengths = self.lengths.at[slot].add(1)
        self.tokens = self.tokens.at[slot].set(int(ids[-1]) if ids else 1)
        self._remaining[slot] = req.max_new_tokens
        self._gen[slot] = []
        self._decoding[slot] = True

    # ------------------------------------------------------------------
    # online writes: enqueue now, ride the next step's planner wave
    # ------------------------------------------------------------------
    def submit_admit(self, path: str, rec) -> None:
        """Queue a §IV-C admission; applied ≤ write_batch per step."""
        self._write_q.append(("admit", path, rec))

    def submit_unlink(self, path: str) -> None:
        """Queue a reverse-order unlink; applied ≤ write_batch per step."""
        self._write_q.append(("unlink", path, None))

    def pending_writes(self) -> int:
        return len(self._write_q) + self.planner.pending_writes()

    def _enqueue_write_batch(self) -> None:
        """Move one write batch from the queue into the planner so it
        executes in this step's flush (after the step's reads — the wave
        ordering that keeps reads pinned to the step-start epoch)."""
        for _ in range(min(self.write_batch, len(self._write_q))):
            kind, path, rec = self._write_q.popleft()
            if kind == "admit":
                self.planner.admit(path, rec)
            else:
                self.planner.unlink(path)

    # ------------------------------------------------------------------
    def _step_storage(self) -> None:
        """Advance every navigating lane to its next storage dependency,
        then drain ONE planner batch — reads plus one write batch — for
        all of them together.  The closing ``refresh()`` commits this
        step's writes to the read view, so a decode step is one wave:
        epoch staleness is bounded by Δ = 1 step."""
        with obs.span("serving.wave",
                      lanes=sum(1 for s in self._nav if s is not None)):
            self._enqueue_write_batch()
            finished: list[tuple[int, object, float]] = []
            for i, nav_state in enumerate(self._nav):
                if nav_state is None:
                    continue
                gen, t0 = nav_state
                try:
                    next(gen)
                except StopIteration as e:
                    finished.append((i, e.value, t0))
                    self._nav[i] = None
            self.planner.flush()
            self.engine.refresh()
            for slot, value, t0 in finished:
                self._finish_nav(slot, value, t0)
        if obs.enabled():
            # waves the device view lags behind the write log (0 when the
            # refresh cadence is every-wave)
            obs.gauge("serving.epoch_lag").set(
                getattr(self.engine, "_deferred_waves", 0))
            every = obs.stats_every()
            if every and self.planner.flushes % every == 0:
                self._stats_log()

    def step(self) -> list[Request]:
        """One serving step: one storage batch (reads + one write batch)
        + one decode step for every decoding lane; returns retired
        requests."""
        if (not any(s is not None for s in self.slots)
                and not self.pending_writes()):
            return []
        self._step_storage()
        if not any(self._decoding):
            return []
        nxt, logits, self.state = self._serve(
            self.params, self.state,
            {"tokens": self.tokens, "lengths": self.lengths})
        self.tokens = nxt
        self.lengths = self.lengths + jnp.asarray(
            [1 if self._decoding[i] else 0 for i in range(self.batch_size)],
            jnp.int32)
        done: list[Request] = []
        nxt_host = np.asarray(nxt)
        for i, req in enumerate(self.slots):
            if req is None or not self._decoding[i]:
                continue
            self._gen[i].append(int(nxt_host[i]))
            self._remaining[i] -= 1
            if (self._remaining[i] <= 0 or int(nxt_host[i]) == EOS
                    or int(self.lengths[i]) >= self.max_len - 1):
                gen_text = self.tok.decode(self._gen[i])
                # generation refines the evidence answer; the evidence
                # answer itself stays authoritative for AC scoring
                req.answer = (req.answer + " " + gen_text).strip()
                req.done = True
                done.append(req)
                self.slots[i] = None
                self._decoding[i] = False
        return done

    # ------------------------------------------------------------------
    # live stats surface (ISSUE 8)
    # ------------------------------------------------------------------
    def stats_snapshot(self) -> dict:
        """JSON-able live telemetry: per-op latency percentiles out of the
        shared histograms, planner queue depth + dedup ratios, refresh
        patch-vs-rebuild accounting, durable bloom/cache rates, and the
        serving write queue.  Top-level keys are a stable schema (see
        docs/OBSERVABILITY.md); cheap enough to call every wave."""
        return obs.build_snapshot(
            self.engine, self.planner,
            extra={"pending_writes": self.pending_writes(),
                   "lanes_active": sum(1 for s in self.slots
                                       if s is not None)})

    def _stats_log(self) -> None:
        """Periodic structured stats line (``REPRO_STATS_EVERY`` waves)."""
        import json
        import logging
        snap = self.stats_snapshot()
        logging.getLogger("repro.serving").info(
            "stats wave=%d %s", snap["waves"], json.dumps(snap))

    # ------------------------------------------------------------------
    # durable snapshot / reopen (ISSUE 3)
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Quiesce the write path and commit the durable tier: drain the
        queued write batches through planner waves, refresh (commits the
        epoch), then flush the store so every committed record is in the
        WAL/segments.  After this returns, the store directory can be
        reopened — ``ServingEngine.reopen_store`` — with zero
        re-ingestion and the same epoch.  On a volatile store this is
        just a planner drain (flush/commit no-op)."""
        while self.pending_writes():
            self._enqueue_write_batch()
            self.planner.flush()
            self.engine.refresh(force=True)
        self.planner.flush()
        # force=True overrides a device refresh cadence > 1: the snapshot
        # must observe every drained write, not eventual k-wave visibility
        self.engine.refresh(force=True)
        store = getattr(self.engine, "store", None)
        if store is not None and hasattr(store, "flush"):
            store.flush()
        return {"epoch": self.engine.epoch,
                "paths": store.count() if store is not None else 0}

    @staticmethod
    def reopen_store(root: str, n_shards: int | None = None, **kw):
        """Reopen a durable store directory written by a previous
        process (crash recovery included): recovers manifest + segments,
        replays the WAL's committed waves, and returns a
        ``PathStore``/``ShardedPathStore`` ready to hand to
        ``ServingEngine`` (the engine then restores the committed
        epoch)."""
        from ..storage import open_durable_store
        return open_durable_store(root, n_shards=n_shards, **kw)

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive a queue through the continuous-batching loop; also
        drains any queued online writes before returning, so accepted
        admissions are never silently left uncommitted."""
        pending = list(requests)
        finished: list[Request] = []
        while (pending or any(s is not None for s in self.slots)
                or self.pending_writes()):
            while pending and self.submit(pending[0]):
                pending.pop(0)
            finished.extend(self.step())
        return finished
