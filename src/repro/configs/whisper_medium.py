"""whisper-medium [audio] — enc-dec 24L d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 (arXiv:2212.04356).

The conv frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S, d_model) as encoder input.  24 encoder + 24 decoder
layers; decoder blocks add cross-attention over the encoder output.
Decoder is full attention ⇒ long_500k SKIPPED; decode_32k runs with a
32k encoder context (out-of-spec for real Whisper's 1.5k frames but
exercised as assigned).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab=51865,
        is_encdec=True,
        n_enc_layers=24,
        frontend="audio_stub",
        tie_embeddings=True,
    )
