"""Assigned-architecture registry: ``get_config(arch_id)``.

Every module defines ``config() -> ModelConfig`` with the exact assigned
numbers, plus the paper's own ``wikikv_router`` (the distilled
routing/navigation LM of §V-B).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "xlstm_350m",
    "qwen3_1_7b",
    "codeqwen1_5_7b",
    "granite_8b",
    "olmo_1b",
    "internvl2_1b",
    "dbrx_132b",
    "kimi_k2_1t_a32b",
    "jamba_v0_1_52b",
    "whisper_medium",
]

#: canonical dashed ids (CLI --arch) → module names
ALIASES = {
    "xlstm-350m": "xlstm_350m",
    "qwen3-1.7b": "qwen3_1_7b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "granite-8b": "granite_8b",
    "olmo-1b": "olmo_1b",
    "internvl2-1b": "internvl2_1b",
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-medium": "whisper_medium",
    "wikikv-router": "wikikv_router",
}


def get_config(arch: str):
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


def all_arch_ids() -> list[str]:
    return [a for a in ALIASES if a != "wikikv-router"]
