"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840; MoE 384 experts top-8 + 1 shared, first layer dense
(arXiv:2501.kimi2, paper-table config).

Trillion-parameter: the config that stresses EP×TP×FSDP sharding and the
int8-quantized optimizer states (runtime/train default for this arch —
f32 moments alone would be 8 TB; see EXPERIMENTS.md §Dry-run memory).
Dense prefix FFN width = top_k × d_ff_expert (activated-width-matched).
"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=16384,           # dense prefix layer (top_k × d_ff_expert)
        vocab=163840,
        d_head=112,
        block_pattern=("attn",),
        moe_every=1,
        n_dense_prefix=1,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1),
        tie_embeddings=False,
    )
