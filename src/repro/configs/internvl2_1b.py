"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 (arXiv:2404.16821).

The InternViT frontend is a STUB: input_specs() provides precomputed
patch embeddings (B, 256, d_model) prepended to the text sequence; loss
masks the image prefix.  The backbone (Qwen2-0.5B-shape) is fully real.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab=151655,
        d_head=64,
        frontend="vision_stub",
        n_prefix_embeds=256,
        tie_embeddings=True,
    )
