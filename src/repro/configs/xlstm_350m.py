"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304.

sLSTM + mLSTM blocks (arXiv:2405.04517): periods of 7 mLSTM + 1 sLSTM
(the paper's sparse-sLSTM placement), 3 periods = 24 layers.  d_ff=0 —
blocks carry their own up/down projections.  Recurrent decode state ⇒
sub-quadratic, runs the long_500k cell.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        xlstm_heads=4,
        block_pattern=("mlstm",) * 7 + ("slstm",),
        sub_quadratic=True,
        tie_embeddings=True,
    )
