"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba+attention 1:7 interleave, MoE 16 experts top-2 on
every second layer (arXiv:2403.19887).

Period of 8: [mamba, moe-mamba, mamba, attn(moe), mamba, moe-mamba,
mamba, moe-mamba] — attention at slot 3, MoE at odd slots; 4 periods.
Sub-quadratic: only 4/32 layers carry a KV cache, Mamba state is O(1)
⇒ runs the long_500k cell.
"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=65536,
        block_pattern=("mamba", "mamba", "mamba", "attn",
                       "mamba", "mamba", "mamba", "mamba"),
        moe_every=2,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
        d_state=16,
        d_conv=4,
        ssm_expand=2,
        sub_quadratic=True,
        tie_embeddings=False,
    )
