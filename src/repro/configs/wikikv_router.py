"""wikikv-router — the paper's own LM: the distilled CLASSIFY/NEEDSDEEPER
router of §V-B plus the navigation summarizer.  Small enough to train in
examples/train_router.py on CPU and to serve as the ModelOracle."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="wikikv-router",
        family="dense",
        n_layers=4,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=768,
        vocab=8192,
        d_head=64,
        qk_norm=True,
        tie_embeddings=True,
        dtype="float32",
        param_dtype="float32",
    )
