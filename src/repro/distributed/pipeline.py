"""Pipeline parallelism over the "pod" axis (GPipe-style schedule).

The multi-pod mesh's "pod" axis defaults to data parallelism (DESIGN §5);
this module provides the alternative: split the layer stack into
``n_stages`` contiguous stages, one per pod, and stream ``n_micro``
microbatches through with the cross-stage hop expressed as
``jax.lax.ppermute`` over the pod axis — the collective XLA maps onto the
inter-pod links.

Implementation shape (single-program SPMD, shard_map over "pod"):
every pod holds its stage's parameters (stacked stage axis sharded over
"pod"); the schedule is the standard rotation — at step t, pod p runs
microbatch (t − p) through its stage and ppermutes its activation to
p+1.  Bubble fraction = (S−1)/(M+S−1); the EXPERIMENTS.md §Perf entry
compares this against pod-DP on collective bytes.

This is a *self-contained* reference used by tests (tiny configs) and by
the dry-run's alternative lowering (--pp flag in launch/train.py); the
main train path keeps pod-DP by default.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..jax_compat import shard_map


@dataclass(frozen=True)
class PipelineSchedule:
    n_stages: int
    n_micro: int
    axis: str = "pod"

    @property
    def bubble_fraction(self) -> float:
        return (self.n_stages - 1) / (self.n_micro + self.n_stages - 1)


def pipeline_apply(stage_fn, stage_params, x_micro, sched: PipelineSchedule,
                   mesh):
    """Run microbatches through pipeline stages.

    stage_fn(params, x) -> x            — one stage's computation
    stage_params: pytree with leading (n_stages,) axis, sharded over pod
    x_micro: (n_micro, mb, ...) microbatched input (replicated)

    Returns (n_micro, mb, ...) outputs.  Total ticks = n_micro+n_stages−1;
    each tick every pod computes (or idles in the bubble) and activations
    rotate one hop — the 1-hop ppermute is the only inter-pod traffic.
    """
    S, M = sched.n_stages, sched.n_micro
    axis = sched.axis

    def body(params_stage, xs):
        # params_stage: this pod's stage slice — shard_map keeps the
        # (now size-1) stage axis; squeeze it.  xs: (M, mb, ...) replicated.
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        p = jax.lax.axis_index(axis)
        ticks = M + S - 1
        mb_shape = xs.shape[1:]
        carry_in = jnp.zeros(mb_shape, xs.dtype)   # activation arriving
        outs = jnp.zeros_like(xs)

        def tick(state, t):
            carry, outs = state
            mb_idx = t - p                          # microbatch at this pod
            active = (mb_idx >= 0) & (mb_idx < M)
            # stage 0 reads from the input stream; others from the carry
            x_in = jnp.where(p == 0,
                             xs[jnp.clip(mb_idx, 0, M - 1)], carry)
            y = stage_fn(params_stage, x_in)
            y = jnp.where(active, y, carry)
            # last stage writes the finished microbatch
            outs = jax.lax.cond(
                active & (p == S - 1),
                lambda o: o.at[jnp.clip(mb_idx, 0, M - 1)].set(y),
                lambda o: o, outs)
            # rotate activations forward one stage
            carry_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            return (carry_next, outs), None

        (carry, outs), _ = jax.lax.scan(tick, (carry_in, outs),
                                        jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them pod-wide
        outs = jax.lax.psum(
            jnp.where(p == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = P(axis)
    other = tuple(a for a in mesh.axis_names if a != axis)
    del other
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x_micro)
