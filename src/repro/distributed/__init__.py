from .pipeline import PipelineSchedule, pipeline_apply  # noqa: F401
