"""Synthetic AUTHTRACE-like corpus generator (DESIGN.md §3).

The real AUTHTRACE [20] is not available offline; this generator
reproduces its *protocol*: thematically dense single-author corpora with
quoted evidence, exact fan-in annotations per question, and the three
fan-in buckets (single-doc / low multi-doc = 2 / high multi-doc ≥ 3).

Every fact is a (subject entity, key, value) triple embedded in exactly
the documents its question's fan-in demands, with the convention that a
fan-in-k question requires the k *shards* of its answer that are spread
across k documents ("the estrangement began in <year>" + "…in <city>" +
"…over <reason>").  Answer correctness is then mechanically checkable:
an answer is correct iff every shard token appears (pack-level AC).

Determinism: everything derives from (seed, author) via hashlib — runs
are byte-stable across processes, which the ablation tables rely on.
"""
from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass, field

TOPICS = [
    "relationships", "writing_style", "polemics", "translations",
    "medicine", "education", "politics", "folklore",
]

ENTITIES = {
    "relationships": ["zhou_zuoren", "xu_guangping", "zhu_an", "mentors"],
    "writing_style": ["vernacular", "satire", "essays", "diaries"],
    "polemics": ["chen_xiying", "liang_shiqiu", "critics", "debates"],
    "translations": ["gogol", "verne", "soviet_fiction", "fairy_tales"],
    "medicine": ["sendai", "anatomy", "abandonment", "teachers"],
    "education": ["lectures", "students", "beijing_university", "reform"],
    "politics": ["league", "censorship", "exile", "manifestos"],
    "folklore": ["mountain_spirits", "new_year", "opera", "customs"],
}

_KEYS = ["year", "city", "reason", "outcome", "count", "companion"]
_VALUES = {
    "year": ["1902", "1906", "1918", "1923", "1927", "1930", "1936"],
    "city": ["beijing", "shanghai", "sendai", "tokyo", "guangzhou", "xiamen"],
    "reason": ["estrangement", "illness", "censorship", "poverty", "ideals"],
    "outcome": ["reconciliation", "silence", "publication", "exile", "fame"],
    "count": ["three", "seven", "twelve", "twenty", "forty"],
    "companion": ["brother", "student", "editor", "translator", "publisher"],
}

_FILLER = [
    "The correspondence from this period survives in fragments.",
    "Contemporary readers debated the essay for months.",
    "Several drafts exist with marginal annotations.",
    "The episode is retold differently in later memoirs.",
    "Archival records confirm the sequence of events.",
    "Critics at the time dismissed the piece as minor.",
]


@dataclass
class Question:
    qid: str
    text: str
    fan_in: int
    doc_ids: list[str]
    answer_shards: list[str]   # tokens that must all appear in the answer
    topic: str
    entity: str


@dataclass
class AuthTraceConfig:
    n_docs: int = 120
    n_questions: int = 60
    seed: int = 0
    author: str = "lu_xun"
    noise_docs: int = 8        # low-information docs the filter Φ must drop
    fan_in_mix: tuple = (0.5, 0.3, 0.2)   # single / low / high buckets


def _rng(cfg: AuthTraceConfig, salt: str) -> random.Random:
    h = hashlib.sha256(f"{cfg.seed}:{cfg.author}:{salt}".encode()).digest()
    return random.Random(int.from_bytes(h[:8], "big"))


def generate_authtrace(cfg: AuthTraceConfig) -> tuple[list[dict], list[Question]]:
    """Returns (documents, questions)."""
    rng = _rng(cfg, "docs")
    docs: list[dict] = []
    facts_per_doc: dict[str, list[str]] = {}
    # fact pool: (topic, entity, key, value, parts) — parts spread over docs
    fact_pool = []
    for qi in range(cfg.n_questions):
        r = _rng(cfg, f"q{qi}")
        topic = r.choice(TOPICS)
        entity = r.choice(ENTITIES[topic])
        u = r.random()
        if u < cfg.fan_in_mix[0]:
            fan = 1
        elif u < cfg.fan_in_mix[0] + cfg.fan_in_mix[1]:
            fan = 2
        else:
            fan = r.choice([3, 3, 4])
        keys = r.sample(_KEYS, fan)
        shards = [(k, r.choice(_VALUES[k])) for k in keys]
        fact_pool.append((qi, topic, entity, shards))

    # documents: each carries a handful of fact shards + filler
    for di in range(cfg.n_docs):
        r = _rng(cfg, f"d{di}")
        topic = TOPICS[di % len(TOPICS)]
        entity = r.choice(ENTITIES[topic])
        did = f"{cfg.author}_doc{di:04d}"
        opening = (f"In this essay on {topic.replace('_', ' ')}, the author "
                   f"reflects on {entity.replace('_', ' ')} at length, {di}.")
        body = [opening]
        body.extend(r.sample(_FILLER, 3))
        docs.append({
            "id": did, "title": f"essay_{di:04d}", "topics": [topic],
            "entities": [entity], "text": "", "facts": [],
        })
        facts_per_doc[did] = []

    # place each question's shards into `fan` distinct docs of its topic
    questions: list[Question] = []
    for qi, topic, entity, shards in fact_pool:
        r = _rng(cfg, f"place{qi}")
        topic_docs = [d for d in docs if d["topics"] == [topic]]
        if len(topic_docs) < len(shards):
            topic_docs = docs
        chosen = r.sample(topic_docs, len(shards))
        doc_ids = []
        shard_tokens = []
        for d, (k, v) in zip(chosen, shards):
            line = (f"Regarding {entity.replace('_', ' ')}: the {k} was {v}. "
                    f"fact: q{qi}_{k}={v}.")
            facts_per_doc[d["id"]].append(line)
            d.setdefault("entities", []).append(entity)
            d["facts"].append(f"fact: q{qi}_{k}={v}")
            doc_ids.append(d["id"])
            shard_tokens.append(v)
        keys_str = " and ".join(k for k, _ in shards)
        qtext = (f"What was the {keys_str} of the "
                 f"{entity.replace('_', ' ')} matter?")
        questions.append(Question(
            qid=f"q{qi}", text=qtext, fan_in=len(shards),
            doc_ids=doc_ids, answer_shards=shard_tokens,
            topic=topic, entity=entity))

    # assemble doc text — openings rotate so same-author essays do not
    # trip the template-boilerplate filter (they are genuine originals)
    _OPENINGS = [
        "An essay concerning {t}, where the author turns to {e}.",
        "Notes toward {t}: observations gathered around {e}.",
        "{e} occupies this piece on {t} from beginning to end.",
        "Among the writings on {t}, this one dwells on {e}.",
        "A later reflection on {t}, returning once more to {e}.",
        "From the notebooks: {t}, and above all {e}.",
    ]
    for di, d in enumerate(docs):
        r = _rng(cfg, "asm" + d["id"])
        opening = _OPENINGS[di % len(_OPENINGS)].format(
            t=d["topics"][0].replace("_", " "),
            e=d["entities"][0].replace("_", " "))
        lines = [opening]
        lines.extend(facts_per_doc[d["id"]])
        lines.extend(r.sample(_FILLER, 2 + r.randrange(3)))
        d["text"] = " ".join(lines)
        d["entities"] = sorted(set(d["entities"]))

    # low-information noise (exercises the ingestion filter Φ)
    noise_templates = [
        "Happy new year to all our readers! Best wishes for the spring festival.",
        "Announcing our annual meetup. Save the date! Registration opens soon.",
        "Limited time offer: discount on the collected essays. Buy now!",
        "http://a.example http://b.example http://c.example http://d.example",
        "ok.",
    ]
    r = _rng(cfg, "noise")
    for ni in range(cfg.noise_docs):
        docs.append({
            "id": f"{cfg.author}_noise{ni:03d}",
            "title": f"notice_{ni:03d}", "topics": [], "entities": [],
            "text": noise_templates[ni % len(noise_templates)], "facts": [],
        })
    return docs, questions


def score_answer(answer: str, q: Question) -> float:
    """Pack-level answer correctness: 1.0 iff every shard value appears."""
    low = answer.lower()
    return 1.0 if all(s.lower() in low for s in q.answer_shards) else 0.0


def bucket(q: Question) -> str:
    if q.fan_in == 1:
        return "single"
    if q.fan_in == 2:
        return "low_multi"
    return "high_multi"
