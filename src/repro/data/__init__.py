from .corpus import AuthTraceConfig, generate_authtrace  # noqa: F401
from .tokenizer import HashTokenizer  # noqa: F401
from .pipeline import DataPipeline, PipelineState  # noqa: F401
