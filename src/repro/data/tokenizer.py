"""Deterministic hash tokenizer (no external vocab files).

Word-level with hashed fallback: frequent-token ids are stable given the
training corpus; unseen words map into a hashed bucket range.  Good
enough to train the router LM and to exercise the data pipeline with
realistic id distributions; NOT a BPE replacement (documented limitation).
"""
from __future__ import annotations

import hashlib
import re

_TOKEN_RE = re.compile(r"[a-z0-9_]+|[^\sa-z0-9_]")

PAD, BOS, EOS, UNK = 0, 1, 2, 3
_RESERVED = 4


class HashTokenizer:
    def __init__(self, vocab_size: int = 8192, hash_buckets: int = 1024):
        self.vocab_size = vocab_size
        self.hash_buckets = min(hash_buckets, vocab_size // 4)
        self._word_to_id: dict[str, int] = {}
        self._id_to_word: dict[int, str] = {}

    def fit(self, texts: list[str]) -> "HashTokenizer":
        from collections import Counter
        counts = Counter()
        for t in texts:
            counts.update(_TOKEN_RE.findall(t.lower()))
        budget = self.vocab_size - self.hash_buckets - _RESERVED
        for i, (w, _) in enumerate(counts.most_common(budget)):
            self._word_to_id[w] = _RESERVED + i
            self._id_to_word[_RESERVED + i] = w
        return self

    def _hash_id(self, w: str) -> int:
        h = int.from_bytes(hashlib.sha1(w.encode()).digest()[:4], "big")
        return self.vocab_size - self.hash_buckets + h % self.hash_buckets

    def encode(self, text: str, add_special: bool = True) -> list[int]:
        ids = [self._word_to_id.get(w, self._hash_id(w))
               for w in _TOKEN_RE.findall(text.lower())]
        return [BOS] + ids + [EOS] if add_special else ids

    def decode(self, ids) -> str:
        return " ".join(self._id_to_word.get(int(i), "<unk>")
                        for i in ids if int(i) >= _RESERVED)
