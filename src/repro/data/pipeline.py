"""Sharded, deterministic, *resumable* training-data pipeline.

Fault-tolerance contract: the pipeline's full position is captured by
``PipelineState`` (epoch, step-within-epoch, rng seed) — a tiny record
checkpointed alongside model state, so a restarted (or re-scaled) job
resumes mid-epoch with the exact same global batch sequence.

Sharding: each data-parallel rank draws the same permutation (seeded) and
takes its slice of every global batch — no inter-host coordination, which
is what survives elastic rescale: a restore onto a different dp_size just
re-slices the same global sequence.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PipelineState:
    epoch: int = 0
    index: int = 0
    seed: int = 0

    def to_dict(self) -> dict:
        return {"epoch": self.epoch, "index": self.index, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(**d)


class DataPipeline:
    """Packs token streams into (batch, seq) next-token-prediction batches."""

    def __init__(self, token_docs: list[list[int]], *, seq_len: int,
                 global_batch: int, seed: int = 0,
                 dp_rank: int = 0, dp_size: int = 1):
        assert global_batch % dp_size == 0
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // dp_size
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.state = PipelineState(seed=seed)
        # pack all docs into one ring of tokens (document-boundary EOS kept)
        stream = []
        for doc in token_docs:
            stream.extend(doc)
        need = seq_len + 1
        n_seqs = max(len(stream) // need, 1)
        stream = (stream * (need * 2 // max(len(stream), 1) + 1)
                  if len(stream) < need else stream)
        n_seqs = max(len(stream) // need, 1)
        self._seqs = np.asarray(
            stream[: n_seqs * need], dtype=np.int32).reshape(n_seqs, need)

    @property
    def steps_per_epoch(self) -> int:
        return max(len(self._seqs) // self.global_batch, 1)

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.RandomState((self.state.seed * 9973 + epoch) % 2**31)
        return rng.permutation(len(self._seqs))

    def next_batch(self) -> dict:
        st = self.state
        perm = self._perm(st.epoch)
        start = (st.index * self.global_batch) % len(self._seqs)
        idx = [perm[(start + j) % len(self._seqs)]
               for j in range(self.global_batch)]
        # local slice for this dp rank
        lo = self.dp_rank * self.local_batch
        rows = self._seqs[idx[lo: lo + self.local_batch]]
        st.index += 1
        if st.index >= self.steps_per_epoch:
            st.index = 0
            st.epoch += 1
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    # -- checkpoint integration --
    def snapshot(self) -> dict:
        return self.state.to_dict()

    def restore(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)
