"""Segment registry: the single source of truth for which segment files
are live — and, since the leveled-compaction PR, *where* each one sits in
the level hierarchy.  Written atomically (write-new-then-rename — the
same pattern as ``checkpoint/manager.py``'s step commit).

The manifest carries everything recovery needs besides the WAL itself:

* ``segments``      — live :class:`SegmentMeta` entries in chronological
                      (creation) order.  Within a level, later entries
                      shadow earlier ones; across levels, a lower level
                      always shadows a higher one (data only ever moves
                      downward, so every version in level L is newer than
                      any version of the same key below it).  Levels ≥ 1
                      written by partitioned compaction are key-range
                      disjoint, so shadowing within them never arises.
* ``next_seg``      — monotone id allocator (never reused within a
                      manifest lineage, so a crashed spill's or merge's
                      orphan file can never collide with a live one)
* ``epoch``         — last committed write epoch at manifest-write time
* ``device_epoch``  — epoch the device tier had applied when last marked
* ``pending_inval`` — journaled invalidation paths committed after
                      ``device_epoch`` (survives WAL truncation at spill
                      so device rehydration stays exact)
* ``compaction``    — in-flight resumable merge state (format 3), or
                      null when no merge is paused.  Inputs remain live
                      in ``segments`` for readers; ``outputs`` are
                      durable partition files not yet published.  A
                      budget-paused merge persists this state so a crash
                      resumes from ``next_key`` instead of redoing (or
                      worse, leaking) completed partitions.

Schema versions: format 3 (current) adds the ``compaction`` field;
format 2 stored ``segments`` as objects with ``level`` and the
bloom/key-range summary; format 1 (PR 3) stored bare file names.
``load`` accepts all three — a PR-3 manifest opens with every segment at
level 0 and unknown stats, a format-2 manifest opens with no pending
merge, and the first manifest write migrates either to format 3 on
disk.  Round-trip compatibility is tested in tests/test_storage.py.

A crash between segment write and manifest swap leaves an unreferenced
``seg_*.seg`` file; ``load`` reports live names so the engine can sweep
orphans.  Files named by ``compaction.outputs`` are *not* orphans —
they are paid-for merge work a resume will publish.  A crash mid-rename
is impossible to observe: ``os.replace`` is atomic on POSIX.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field

from . import failpoints as FP

MANIFEST_NAME = "MANIFEST.json"

#: current manifest schema version
#: (1 = PR-3 flat names, 2 = leveled, 3 = + resumable compaction state)
FORMAT = 3


@dataclass
class SegmentMeta:
    """One live segment's manifest entry.

    ``min_key``/``max_key`` are hex-encoded (JSON-safe) first/last keys;
    empty string means unknown (a migrated PR-3 segment).  ``bloom_k`` /
    ``bloom_bits`` summarize the filter serialized in the segment footer
    (0/0 → the segment carries none and every probe must touch it)."""

    name: str
    level: int = 0
    records: int = 0
    bytes: int = 0
    min_key: str = ""
    max_key: str = ""
    bloom_k: int = 0
    bloom_bits: int = 0


@dataclass
class CompactionState:
    """A paused (budget-throttled) merge, recorded crash-safely.

    ``inputs`` are segment names still live in ``segments``; ``outputs``
    are completed, fsynced partition files at ``out_level`` that the
    finalize step will publish atomically.  ``next_key`` (hex) is the
    first merged key not yet written — resume re-merges the inputs and
    skips everything below it.  ``drop_tombstones`` is decided once at
    merge start (whether any level deeper than ``out_level`` remains)
    and frozen here so a resume after an unrelated spill cannot change
    the merge's semantics mid-flight."""

    level: int
    out_level: int
    inputs: list[str] = field(default_factory=list)
    outputs: list[SegmentMeta] = field(default_factory=list)
    next_key: str = ""
    drop_tombstones: bool = False


@dataclass
class Manifest:
    segments: list[SegmentMeta] = field(default_factory=list)
    next_seg: int = 1
    epoch: int = 0
    device_epoch: int = 0
    pending_inval: list[str] = field(default_factory=list)
    compaction: CompactionState | None = None

    def alloc_segment(self) -> str:
        """Reserve the next (never-reused) segment file name."""
        name = f"seg_{self.next_seg:06d}.seg"
        self.next_seg += 1
        return name

    def segment_names(self) -> list[str]:
        """Live file names, chronological order."""
        return [m.name for m in self.segments]

    def level_counts(self) -> dict[int, int]:
        """→ ``{level: number of live segments}`` (ascending levels)."""
        out: dict[int, int] = {}
        for m in self.segments:
            out[m.level] = out.get(m.level, 0) + 1
        return dict(sorted(out.items()))


def _meta_from_json(o: object) -> SegmentMeta:
    if isinstance(o, str):                       # format 1: bare file name
        return SegmentMeta(name=o, level=0)
    assert isinstance(o, dict)
    return SegmentMeta(
        name=str(o["name"]),
        level=int(o.get("level", 0)),
        records=int(o.get("records", 0)),
        bytes=int(o.get("bytes", 0)),
        min_key=str(o.get("min_key", "")),
        max_key=str(o.get("max_key", "")),
        bloom_k=int(o.get("bloom_k", 0)),
        bloom_bits=int(o.get("bloom_bits", 0)),
    )


def _compaction_from_json(o: object) -> CompactionState | None:
    if o is None:
        return None
    assert isinstance(o, dict)
    return CompactionState(
        level=int(o["level"]),
        out_level=int(o["out_level"]),
        inputs=[str(n) for n in o.get("inputs", [])],
        outputs=[_meta_from_json(s) for s in o.get("outputs", [])],
        next_key=str(o.get("next_key", "")),
        drop_tombstones=bool(o.get("drop_tombstones", False)),
    )


def load(dirname: str) -> Manifest:
    """Read ``MANIFEST.json`` (any schema version); empty manifest if
    the file does not exist (a fresh store directory)."""
    path = os.path.join(dirname, MANIFEST_NAME)
    if not os.path.exists(path):
        return Manifest()
    with open(path, "r", encoding="utf-8") as f:
        o = json.load(f)
    return Manifest(
        segments=[_meta_from_json(s) for s in o.get("segments", [])],
        next_seg=int(o.get("next_seg", 1)),
        epoch=int(o.get("epoch", 0)),
        device_epoch=int(o.get("device_epoch", 0)),
        pending_inval=list(o.get("pending_inval", [])),
        compaction=_compaction_from_json(o.get("compaction")),
    )


def store(dirname: str, m: Manifest, sync: bool = True) -> None:
    """Atomic commit: serialize to ``MANIFEST.json.tmp``, fsync, rename.
    Always writes the current (format 3) schema — this is where older
    manifests migrate."""
    path = os.path.join(dirname, MANIFEST_NAME)
    tmp = path + ".tmp"
    payload = json.dumps({
        "format": FORMAT,
        "segments": [asdict(s) for s in m.segments],
        "next_seg": m.next_seg,
        "epoch": m.epoch,
        "device_epoch": m.device_epoch,
        "pending_inval": m.pending_inval,
        "compaction": None if m.compaction is None else asdict(m.compaction),
    }, sort_keys=True)
    with open(tmp, "w", encoding="utf-8") as f:
        FP.write("manifest.write", f, payload)
        f.flush()
        if sync:
            FP.hit("manifest.fsync")
            os.fsync(f.fileno())
    FP.hit("manifest.replace")
    os.replace(tmp, path)
    if sync:
        # the rename itself is directory metadata: without this fsync a
        # power loss after the WAL truncates could resurrect the OLD
        # manifest and lose the spilled segment
        from .wal import fsync_dir
        fsync_dir(dirname)


def sweep_orphans(dirname: str, m: Manifest) -> list[str]:
    """Delete ``seg_*.seg`` files not referenced by the manifest (debris
    from a crash between segment/merge write and manifest swap).  A
    paused merge's output partitions are referenced by ``compaction``
    rather than ``segments`` — they are live work, not debris."""
    live = set(m.segment_names())
    if m.compaction is not None:
        live.update(o.name for o in m.compaction.outputs)
    removed = []
    for name in sorted(os.listdir(dirname)):
        if name.endswith(".seg") and name not in live:
            os.remove(os.path.join(dirname, name))
            removed.append(name)
    return removed
