"""Segment registry: the single source of truth for which segment files
are live, written atomically (write-new-then-rename — the same pattern as
``checkpoint/manager.py``'s step commit).

The manifest carries everything recovery needs besides the WAL itself:

* ``segments``      — live segment file names, oldest → newest (newer
                      segments shadow older on reads)
* ``next_seg``      — monotone id allocator (never reused, so a crashed
                      spill's orphan file can never collide with a live one)
* ``epoch``         — last committed write epoch at manifest-write time
* ``device_epoch``  — epoch the device tier had applied when last marked
* ``pending_inval`` — journaled invalidation paths committed after
                      ``device_epoch`` (survives WAL truncation at spill
                      so device rehydration stays exact)

A crash between segment write and manifest swap leaves an unreferenced
``seg_*.seg`` file; ``load`` reports live names so the engine can sweep
orphans.  A crash mid-rename is impossible to observe: ``os.replace`` is
atomic on POSIX.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

MANIFEST_NAME = "MANIFEST.json"


@dataclass
class Manifest:
    segments: list[str] = field(default_factory=list)
    next_seg: int = 1
    epoch: int = 0
    device_epoch: int = 0
    pending_inval: list[str] = field(default_factory=list)

    def alloc_segment(self) -> str:
        name = f"seg_{self.next_seg:06d}.seg"
        self.next_seg += 1
        return name


def load(dirname: str) -> Manifest:
    path = os.path.join(dirname, MANIFEST_NAME)
    if not os.path.exists(path):
        return Manifest()
    with open(path, "r", encoding="utf-8") as f:
        o = json.load(f)
    return Manifest(
        segments=list(o.get("segments", [])),
        next_seg=int(o.get("next_seg", 1)),
        epoch=int(o.get("epoch", 0)),
        device_epoch=int(o.get("device_epoch", 0)),
        pending_inval=list(o.get("pending_inval", [])),
    )


def store(dirname: str, m: Manifest, sync: bool = True) -> None:
    """Atomic commit: serialize to ``MANIFEST.json.tmp``, fsync, rename."""
    path = os.path.join(dirname, MANIFEST_NAME)
    tmp = path + ".tmp"
    payload = json.dumps({
        "segments": m.segments,
        "next_seg": m.next_seg,
        "epoch": m.epoch,
        "device_epoch": m.device_epoch,
        "pending_inval": m.pending_inval,
    }, sort_keys=True)
    with open(tmp, "w", encoding="utf-8") as f:
        f.write(payload)
        f.flush()
        if sync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if sync:
        # the rename itself is directory metadata: without this fsync a
        # power loss after the WAL truncates could resurrect the OLD
        # manifest and lose the spilled segment
        from .wal import fsync_dir
        fsync_dir(dirname)


def sweep_orphans(dirname: str, m: Manifest) -> list[str]:
    """Delete ``seg_*.seg`` files not referenced by the manifest (debris
    from a crash between segment write and manifest swap)."""
    live = set(m.segments)
    removed = []
    for name in sorted(os.listdir(dirname)):
        if name.endswith(".seg") and name not in live:
            os.remove(os.path.join(dirname, name))
            removed.append(name)
    return removed
