"""``DurableKV`` — the disk-backed LSM engine behind the ``KVEngine``
protocol (ISSUE 3 tentpole; leveled compaction + bloom filters + block
cache since ISSUE 7; key-range-partitioned levels + compaction
backpressure since ISSUE 9).

Write path: every put/delete appends a WAL record (buffered) and lands in
the dict memtable.  ``commit_epoch(e)`` — called once per planner wave by
``QueryEngine.refresh()``, or via ``flush()`` between offline batches —
group-commits the buffered wave to the WAL; when the memtable exceeds its
limit the commit also *spills* it to a sorted level-0 segment and swaps
the manifest, after which the WAL is truncated (everything it held is now
in a segment).

Compaction is leveled with key-range-partitioned levels ≥ 1:

* Level 0 holds whole-memtable spills (overlapping ranges); when it
  accumulates ``level_ratio`` segments (default 4, ``REPRO_LEVEL_RATIO``)
  the whole run is merged down.
* Levels ≥ 1 hold segments with **disjoint key ranges**, split at
  ``REPRO_SEGMENT_TARGET_BYTES`` (default 2 MiB) per output partition.
  A level is triggered when its bytes exceed
  ``segment_target_bytes · level_ratio^level``; the merge picks one
  victim partition (the largest — fastest debt paydown) plus the
  range-overlapping partitions of the next level, and rewrites only
  those.  Merged bytes per trigger are O(victim + overlap), never
  O(level), and never O(total store).
* A legacy (pre-partitioned or migrated) level with unknown or
  overlapping ranges is merged whole, which partitions it — stores
  migrate themselves during normal operation.

Merges are throttled: ``REPRO_COMPACT_BUDGET_BYTES`` (0 = unlimited)
bounds the merged bytes per ``commit_epoch`` boundary.  A merge that
exhausts the budget *pauses* after the partition it is writing:
completed output partitions plus a resume key are recorded in the
manifest (format 3 ``compaction`` field) by the same atomic
write-then-swap that protects every other transition, the inputs stay
live for readers, and the next wave resumes from the recorded key.  The
outstanding work is exported as the ``compact_debt`` gauge (see
:meth:`compact_debt`) so the serving tier can observe backpressure.
``compact()`` remains the explicit *major* compaction (merge everything
to the bottom level, partitioned, dropping all tombstones — the
maintenance/benchmark path); it abandons any paused merge first (the
paused outputs are redundant copies of still-live inputs).

Read path: memtable first, then levels in order.  On a partitioned
level the probe is a binary search over the partition ranges — **at
most one segment per level** is consulted; level 0 (and any legacy
level) is probed newest-first.  Each consulted segment is counted as
``seg_probe`` and bloom-checked first (``REPRO_BLOOM_BITS`` bits/key,
default 10; the key is hashed once per lookup, not once per segment).
An optional shared :class:`~repro.storage.sstable.BlockCache`
(``REPRO_BLOCK_CACHE_BYTES``) serves hot index blocks from memory.
``scan`` k-way-merges only the segments whose key range can intersect
the prefix (first-seen-wins across memtable → L0 newest-first → deeper
levels).

Crash recovery (``recover()``, run at construction): load the manifest,
validate any paused-merge state, sweep orphan segments (a paused
merge's recorded outputs are *not* orphans), open the live segments,
replay the WAL's committed waves over them, truncate any
uncommitted/corrupt tail.  Guarantees:

* a crash loses at most the wave that had not yet committed (Δ = 1 wave
  across restart — the engine-layer tests assert this end to end);
* a torn WAL tail is detected by CRC and cleanly dropped;
* a crash between segment write and manifest swap — spill, merge
  partition, or merge finalize — leaves orphan files that recovery
  deletes: the manifest still references the pre-crash inputs, so the
  store's view is the pre-compaction one and nothing is lost or
  duplicated (WAL replay over segments is idempotent);
* a crash after a budget pause resumes the merge from the recorded
  key: the already-written partitions are kept, not redone.

The randomized crash-injection harness (tests/test_storage_fuzz.py,
``storage.failpoints``) exercises all of the above against an oracle.

Epoch rehydration: COMMIT records carry the write epoch and DEVMARK
records the epoch the device tier last applied; INV records journal
every invalidation-bus publish.  After restart, ``last_epoch()`` restores
the engine epoch and ``pending_invalidations()`` returns the committed
dirty paths the device tier had NOT yet applied — the exact
``TensorDelta`` work list for its first post-restart ``refresh()``.
"""
from __future__ import annotations

import bisect
import heapq
import os
import threading
from typing import Callable, Iterator, Optional

from .. import obs
from ..core import paths as P
from ..core.store import KVEngine, PathStore
from . import manifest as MF
from . import wal as W
from .sstable import (MISSING, TOMBSTONE, BlockCache, SSTable,
                      bloom_hash_pair, write_sstable)

WAL_NAME = "wikikv.wal"

#: ``REPRO_LEVEL_RATIO`` — L0 segment-count trigger, and the per-level
#: byte-capacity growth factor for levels ≥ 1 (default 4, min 2)
LEVEL_RATIO_ENV = "REPRO_LEVEL_RATIO"
#: ``REPRO_BLOOM_BITS`` — bloom bits per key written into new segment
#: footers (default 10 ≈ 0.8% FPR at k=7; 0 disables → PR-3 byte layout)
BLOOM_BITS_ENV = "REPRO_BLOOM_BITS"
#: ``REPRO_BLOCK_CACHE_BYTES`` — byte budget of the block cache
#: ``open_durable_store`` shares across shards (default 8 MiB; 0 disables)
BLOCK_CACHE_ENV = "REPRO_BLOCK_CACHE_BYTES"
#: ``REPRO_SEGMENT_TARGET_BYTES`` — partition size compaction splits its
#: outputs at; also the base of the per-level byte capacity
#: ``target · ratio^level`` (default 2 MiB)
SEGMENT_TARGET_ENV = "REPRO_SEGMENT_TARGET_BYTES"
#: ``REPRO_COMPACT_BUDGET_BYTES`` — merged bytes allowed per
#: ``commit_epoch`` boundary before the merge pauses resumably
#: (default 0 = unlimited, i.e. no backpressure throttling)
COMPACT_BUDGET_ENV = "REPRO_COMPACT_BUDGET_BYTES"
#: ``REPRO_BG_COMPACT`` — run budgeted merges on a per-store daemon
#: worker: ``commit_epoch`` enqueues compaction debt instead of paying
#: it inline (default 0 = inline, the pre-ISSUE-10 behavior)
BG_COMPACT_ENV = "REPRO_BG_COMPACT"

_TRUTHY = ("1", "true", "on", "yes")


def resolve_level_ratio(explicit: int | None = None) -> int:
    """Resolve the per-level compaction trigger (arg > env > default 4)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(LEVEL_RATIO_ENV, "4"))
    if val < 2:
        raise ValueError(f"level_ratio must be >= 2, got {val}")
    return val


def resolve_bloom_bits(explicit: int | None = None) -> int:
    """Resolve bloom bits/key for new segments (arg > env > default 10)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(BLOOM_BITS_ENV, "10"))
    if val < 0:
        raise ValueError(f"bloom_bits must be >= 0, got {val}")
    return val


def resolve_segment_target_bytes(explicit: int | None = None) -> int:
    """Resolve the partition target size (arg > env > default 2 MiB)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(SEGMENT_TARGET_ENV, str(2 << 20)))
    if val < 1:
        raise ValueError(f"segment_target_bytes must be >= 1, got {val}")
    return val


def resolve_compact_budget_bytes(explicit: int | None = None) -> int:
    """Resolve the per-commit merge budget (arg > env > default 0 =
    unlimited)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(COMPACT_BUDGET_ENV, "0"))
    if val < 0:
        raise ValueError(f"compact_budget_bytes must be >= 0, got {val}")
    return val


def resolve_bg_compact(explicit: bool | None = None) -> bool:
    """Resolve the background-compaction switch (arg > env > default
    off = merges run inline at the commit boundary)."""
    if explicit is not None:
        return bool(explicit)
    return os.environ.get(BG_COMPACT_ENV, "0").strip().lower() in _TRUTHY


def default_block_cache(explicit_bytes: int | None = None
                        ) -> BlockCache | None:
    """Build the shared block cache ``open_durable_store`` hands every
    shard (arg > env > default 8 MiB); 0 bytes → no cache (None)."""
    val = explicit_bytes if explicit_bytes is not None else \
        int(os.environ.get(BLOCK_CACHE_ENV, str(8 << 20)))
    if val < 0:
        raise ValueError(f"block cache bytes must be >= 0, got {val}")
    return BlockCache(val) if val else None


def _meta_range(m: MF.SegmentMeta) -> tuple[bytes, bytes] | None:
    """A segment's decoded key range, or None when unknown (migrated
    PR-3 metadata, or an empty-key edge case)."""
    if m.records > 0 and m.min_key and m.max_key:
        return bytes.fromhex(m.min_key), bytes.fromhex(m.max_key)
    return None


class _LevelView:
    """Read-path view of one level, rebuilt on every manifest change.

    ``partitioned`` means every segment's range is known and the ranges
    are pairwise disjoint — then ``mins``/``maxs`` (ascending) drive a
    binary search and a point read consults at most one segment.
    Otherwise ``entries`` is newest-first and every segment is a probe
    candidate (level 0, legacy levels, ``flat_reads`` mode)."""

    __slots__ = ("level", "partitioned", "entries", "mins", "maxs")

    def __init__(self, level: int, partitioned: bool, entries: list,
                 mins: list | None = None, maxs: list | None = None):
        self.level = level
        self.partitioned = partitioned
        self.entries = entries          # [(SegmentMeta, SSTable)]
        self.mins = mins
        self.maxs = maxs


class DurableKV(KVEngine):
    """Durable memtable → WAL → leveled-SSTable engine; one directory per
    engine (per digest-range shard under ``ShardedPathStore``).

    Args: ``dirname`` store directory (created; recovered if it already
    holds a store), ``memtable_limit`` entries before a commit spills,
    ``sync`` WAL sync mode (None → ``REPRO_WAL_SYNC``), ``level_ratio``
    L0 trigger + capacity growth factor (None → ``REPRO_LEVEL_RATIO``),
    ``bloom_bits`` filter bits/key for new segments (None →
    ``REPRO_BLOOM_BITS``; 0 writes PR-3-layout segments), ``block_cache``
    a shared :class:`BlockCache` or None (no cache — the default for a
    bare engine; ``open_durable_store`` wires a shared one),
    ``segment_target_bytes`` compaction partition size (None →
    ``REPRO_SEGMENT_TARGET_BYTES``), ``compact_budget_bytes`` merged
    bytes allowed per commit boundary (None →
    ``REPRO_COMPACT_BUDGET_BYTES``; 0 = unlimited), ``flat_reads``
    disable the per-level binary search and probe every segment — the
    benchmark A/B switch that reproduces the pre-partitioned (PR-5)
    read path on the same files, ``bg_compact`` move budgeted merges to
    a per-store daemon worker so ``commit_epoch`` enqueues debt instead
    of paying it (None → ``REPRO_BG_COMPACT``; the budget still bounds
    each worker slice, so backpressure flow control is unchanged)."""

    def __init__(self, dirname: str, memtable_limit: int = 4096,
                 sync: str | None = None, level_ratio: int | None = None,
                 bloom_bits: int | None = None,
                 block_cache: BlockCache | None = None,
                 segment_target_bytes: int | None = None,
                 compact_budget_bytes: int | None = None,
                 flat_reads: bool = False,
                 bg_compact: bool | None = None):
        self.dirname = dirname
        self._limit = memtable_limit
        self._ratio = resolve_level_ratio(level_ratio)
        self._bloom_bits = resolve_bloom_bits(bloom_bits)
        self._cache = block_cache
        self._sync = W.sync_mode(sync)
        self._target = resolve_segment_target_bytes(segment_target_bytes)
        self._budget = resolve_compact_budget_bytes(compact_budget_bytes)
        self._flat_reads = bool(flat_reads)
        self._lock = threading.RLock()
        self._mem: dict[bytes, object] = {}
        #: memtable sealed by a pipelined commit, awaiting its off-thread
        #: spill — reads consult it between the live memtable and levels
        self._frozen: dict[bytes, object] | None = None
        self._tables: dict[str, SSTable] = {}  # segment name -> open reader
        self._read_order: list[tuple[MF.SegmentMeta, SSTable]] = []
        self._levels: list[_LevelView] = []
        self._inval_buf: list[str] = []        # journaled, not yet committed
        self._closed = False
        #: merged bytes spent by the most recent commit/spill boundary —
        #: the per-wave compaction cost the backpressure tests assert on
        self.last_compact_bytes = 0
        # background compaction worker state (started below, after
        # recovery, so a recovered paused merge can resume immediately)
        self._bg = resolve_bg_compact(bg_compact)
        self._bg_thread: threading.Thread | None = None
        self._bg_wake = threading.Event()
        self._bg_stop = threading.Event()
        self._bg_exc: BaseException | None = None
        os.makedirs(dirname, exist_ok=True)
        self._recover()
        wal_path = os.path.join(dirname, WAL_NAME)
        wal_existed = os.path.exists(wal_path)
        self._wal = W.WAL(wal_path, sync=self._sync)
        if self._sync == "fsync" and not wal_existed:
            # a freshly created WAL's directory entry must be durable
            # before any commit claims its contents are
            W.fsync_dir(dirname)
        if self._bg:
            self._bg_thread = threading.Thread(
                target=self._bg_loop, name=f"lsm-compact:{dirname}",
                daemon=True)
            self._bg_thread.start()
            with self._lock:
                if self._compact_debt_locked() > 0:
                    self._bg_wake.set()

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _open_table(self, name: str) -> SSTable:
        return SSTable(os.path.join(self.dirname, name),
                       cache=self._cache, stat=self._count)

    def _rebuild_read_order(self) -> None:
        """Recompute the per-level read views and the flat probe order
        (levels ascending; newest-first within a non-partitioned level,
        range-ascending within a partitioned one)."""
        segs = self._manifest.segments
        by_level: dict[int, list[int]] = {}
        for i, m in enumerate(segs):
            by_level.setdefault(m.level, []).append(i)
        views: list[_LevelView] = []
        for level in sorted(by_level):
            idxs = by_level[level]
            ranges = [_meta_range(segs[i]) for i in idxs]
            view = None
            if level >= 1 and not self._flat_reads and all(ranges):
                ordered = sorted(zip(ranges, idxs), key=lambda t: t[0])
                disjoint = all(ordered[j][0][0] > ordered[j - 1][0][1]
                               for j in range(1, len(ordered)))
                if disjoint:
                    view = _LevelView(
                        level, True,
                        [(segs[i], self._tables[segs[i].name])
                         for _, i in ordered],
                        mins=[r[0] for r, _ in ordered],
                        maxs=[r[1] for r, _ in ordered])
            if view is None:
                # L0, legacy metadata, or flat_reads: probe every
                # segment newest-first (later manifest position = newer)
                view = _LevelView(
                    level, False,
                    [(segs[i], self._tables[segs[i].name])
                     for i in reversed(idxs)])
            views.append(view)
        self._levels = views
        self._read_order = [e for v in views for e in v.entries]

    def _recover(self) -> None:
        """Manifest → paused-merge validation → orphan sweep → open
        segments → WAL replay → truncate the uncommitted/corrupt tail
        (see module docstring)."""
        with obs.span("lsm.recover") as sp:
            self._recover_impl()
            sp.set(waves=self._epoch, dropped=self.recovery_dropped)

    def _recover_impl(self) -> None:
        m = MF.load(self.dirname)
        st = m.compaction
        if st is not None:
            # a paused merge is only resumable if its inputs are still
            # live and every recorded output file exists; anything else
            # (defensive — no crash point produces it) is abandoned and
            # the sweep below reclaims the output files
            names = set(m.segment_names())
            ok = (all(n in names for n in st.inputs)
                  and all(os.path.exists(os.path.join(self.dirname, o.name))
                          for o in st.outputs))
            if not ok:
                m.compaction = None
        MF.sweep_orphans(self.dirname, m)
        self._manifest = m
        self._tables = {meta.name: self._open_table(meta.name)
                        for meta in m.segments}
        self._rebuild_read_order()
        self._epoch = m.epoch
        self._device_epoch = m.device_epoch
        self._pending_inval: list[str] = list(m.pending_inval)
        wal_path = os.path.join(self.dirname, WAL_NAME)
        res = W.replay(wal_path)
        for wave in res.waves:
            for rec in wave:
                if rec.kind == W.PUT:
                    self._mem[rec.key] = rec.value
                elif rec.kind == W.DEL:
                    self._mem[rec.key] = TOMBSTONE
                elif rec.kind == W.INV:
                    self._pending_inval.append(rec.path)
                elif rec.kind == W.DEVMARK:
                    self._device_epoch = max(self._device_epoch, rec.epoch)
                    self._pending_inval.clear()
                elif rec.kind == W.COMMIT:
                    self._epoch = max(self._epoch, rec.epoch)
        self.recovery_dropped = res.dropped_records
        self.recovery_corrupt_tail = res.corrupt_tail
        if res.dropped_records or res.corrupt_tail:
            # drop the uncommitted wave / torn tail so the next append
            # starts at a clean frame boundary
            with open(wal_path, "rb+") as f:
                f.truncate(res.valid_end)

    # ------------------------------------------------------------------
    # KVEngine surface
    # ------------------------------------------------------------------
    def _raise_bg(self) -> None:
        """Surface a background-worker failure (IO error, injected
        crash) on the caller thread: sticky — once the worker has died,
        every subsequent mutation re-raises until close().  Callers must
        hold no assumption that the merge it was running completed."""
        exc = self._bg_exc
        if exc is not None:
            raise exc

    def put(self, key: bytes, value: bytes) -> None:
        """Upsert ``key`` → WAL buffer + memtable (durable at the next
        ``commit_epoch``).  O(1)."""
        self._count("put")
        self._raise_bg()
        with self._lock:
            self._wal.append_put(key, value)
            self._mem[key] = value

    def delete(self, key: bytes) -> None:
        """Tombstone ``key`` (shadows every older level until a bottom
        merge drops it).  O(1)."""
        self._count("delete")
        self._raise_bg()
        with self._lock:
            self._wal.append_delete(key)
            self._mem[key] = TOMBSTONE

    def get(self, key: bytes) -> Optional[bytes]:
        """Point lookup: memtable, then levels in order — a binary
        search over the partition ranges on a partitioned level (≤ 1
        segment consulted), newest-first probe-all on level 0 / legacy
        levels.

        Complexity: O(1) memtable hit; otherwise O(log partitions) per
        partitioned level and O(segments) on level 0.  Every consulted
        segment counts as ``seg_probe`` in :meth:`op_counts`; the key is
        bloom-hashed **once** and a negative filter skips the segment
        (``bloom_neg``) before any of its bytes are touched.  Surviving
        probes cost O(log n_index) bisect + one ≤ SPARSE_EVERY-record
        block (served from the shared block cache when attached:
        ``cache_hit``/``cache_miss`` counters)."""
        self._count("get")
        with self._lock:
            v = self._mem.get(key)
            if v is None and self._frozen is not None:
                # sealed by a pipelined commit, spill still in flight
                v = self._frozen.get(key)
            if v is not None:
                return None if v is TOMBSTONE else v  # type: ignore[return-value]
            hashes: tuple[int, int] | None = None
            for view in self._levels:
                if view.partitioned:
                    i = bisect.bisect_right(view.mins, key) - 1
                    if i < 0 or key > view.maxs[i]:
                        continue
                    cands = (view.entries[i],)
                else:
                    cands = view.entries
                for meta, seg in cands:
                    self._count("seg_probe")
                    if seg.bloom is not None:
                        if hashes is None:
                            hashes = bloom_hash_pair(key)
                        if not seg.bloom.may_contain_hashes(*hashes):
                            self._count("bloom_neg")
                            continue
                    v = seg.get(key)
                    if v is TOMBSTONE:
                        return None
                    if v is not MISSING:
                        return v  # type: ignore[return-value]
        return None

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        """Ordered iteration over live ``prefix``-keyed pairs (tombstones
        resolved): a k-way merge over the memtable and only the segments
        whose key range can intersect the prefix (``scan_skip`` counts
        the pruned ones).  First occurrence of a key in merge-rank order
        — memtable, then levels ascending, newest-first within level 0 —
        wins; partitioned levels are disjoint so rank among their
        partitions cannot matter.  Scans bypass bloom filters and the
        block cache by design (range reads would pollute it)."""
        self._count("scan")
        plen = len(prefix)
        with self._lock:
            runs: list[list[tuple[bytes, int, object]]] = []
            mem = sorted((k, v) for k, v in self._mem.items()
                         if k.startswith(prefix))
            runs.append([(k, 0, v) for k, v in mem])
            if self._frozen is not None:
                # the sealed-not-yet-spilled wave: older than the live
                # memtable, newer than every segment
                frz = sorted((k, v) for k, v in self._frozen.items()
                             if k.startswith(prefix))
                runs.append([(k, 1, v) for k, v in frz])
            rank = 2
            for view in self._levels:
                for meta, seg in view.entries:
                    r = _meta_range(meta)
                    if r is not None and (
                            r[1] < prefix or (plen and r[0][:plen] > prefix)):
                        self._count("scan_skip")
                        continue
                    runs.append([(k, rank, v) for k, v in seg.scan(prefix)])
                    rank += 1
            out: list[tuple[bytes, bytes]] = []
            last: bytes | None = None
            # (key, rank) pairs are unique across runs, so the merge
            # never compares values; the lowest rank for a key comes
            # first and shadows the rest
            for k, _, v in heapq.merge(*runs):
                if k == last:
                    continue
                last = k
                if v is not TOMBSTONE:
                    out.append((k, v))  # type: ignore[arg-type]
        yield from out

    def flush(self) -> None:
        """KVEngine hygiene hook (offline pipeline batches): commit the
        buffered wave at the current epoch — durability without an epoch
        bump."""
        self.commit_epoch(self._epoch)

    # ------------------------------------------------------------------
    # group commit + spill (the wave boundary)
    # ------------------------------------------------------------------
    def commit_epoch(self, epoch: int) -> None:
        """Group-commit the buffered wave at ``epoch`` (monotone), spill
        the memtable if over its limit, then pay compaction debt up to
        the per-wave byte budget — inline, or by waking the background
        worker when ``bg_compact`` is on."""
        self._raise_bg()
        with self._lock:
            # monotone: a lagging engine sharing this store (e.g. a
            # device mirror whose own counter trails the host's) must
            # never move the committed epoch backwards
            epoch = max(epoch, self._epoch)
            if (epoch == self._epoch and self._wal.pending_bytes() == 0
                    and not self._inval_buf and len(self._mem) < self._limit):
                # same epoch, nothing to make durable: skip the COMMIT
                # frame and its fsync, so repeated flush() calls never
                # grow the WAL with redundant empty waves.  An epoch
                # ADVANCE is always recorded, even content-free — the
                # committed epoch sequence must survive restart.
                return
            self._wal.commit(epoch)
            self._epoch = epoch
            self._manifest.epoch = epoch
            self._pending_inval.extend(self._inval_buf)
            self._inval_buf.clear()
            if len(self._mem) >= self._limit:
                self._spill_locked()
            self._kick_compaction_locked()

    def seal_commit(self, epoch: int):
        """Synchronous half of a pipelined group commit (monotone, same
        skip rule as :meth:`commit_epoch`).  Under the lock: seal the
        WAL buffer (cheap byte copy — no IO), advance the epoch
        bookkeeping, and *freeze* an over-limit memtable so the next
        wave's writes land in a fresh one.  Returns None when there is
        nothing to commit, else a zero-arg ``complete`` closure for the
        commit sequencer: it writes + fsyncs the sealed bytes WITHOUT
        the engine lock (so the fsync overlaps the caller's compute),
        then — back under the lock — spills the frozen memtable and
        kicks compaction.  The caller must run ``complete`` exactly once
        and join it before the next seal (the sequencer's depth-1
        invariant); until it finishes, the epoch is sealed but NOT
        durable and must not be advertised as such."""
        with self._lock:
            self._raise_bg()
            epoch = max(epoch, self._epoch)
            if (epoch == self._epoch and self._wal.pending_bytes() == 0
                    and not self._inval_buf and len(self._mem) < self._limit):
                return None
            sealed = self._wal.seal(epoch)
            self._epoch = epoch
            self._manifest.epoch = epoch
            self._pending_inval.extend(self._inval_buf)
            self._inval_buf.clear()
            if len(self._mem) >= self._limit:
                assert self._frozen is None, \
                    "pipelined commit overlap exceeded depth 1"
                self._frozen = self._mem
                self._mem = {}

        def complete() -> None:
            self._wal.write_sealed(sealed, epoch)
            with self._lock:
                self._spill_frozen_locked()
                self._kick_compaction_locked()
        return complete

    def spill(self) -> None:
        """Commit the open wave and force the memtable to a level-0
        segment regardless of the limit (then run any triggered leveled
        merges).  Maintenance/benchmark hook: after it, every committed
        record is served from segment files — a truly cold read path."""
        self._raise_bg()
        with self._lock:
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._spill_locked()
            self._kick_compaction_locked()

    def _store_manifest_locked(self) -> None:
        """Swap the manifest carrying the LIVE counters, not whatever it
        held on disk: after a reopen the committed epoch may exist only
        in WAL COMMIT records, and a spill's WAL reset truncates those."""
        self._manifest.epoch = self._epoch
        self._manifest.device_epoch = self._device_epoch
        self._manifest.pending_inval = list(self._pending_inval)
        MF.store(self.dirname, self._manifest, sync=self._sync == "fsync")

    def _spill_locked(self) -> None:
        """Freeze the (fully committed) memtable into a new level-0
        segment and make it live: segment write + fsync → manifest swap →
        WAL truncate.  Each arrow is a crash boundary recovery handles
        (orphan sweep / idempotent WAL replay)."""
        if not self._mem:
            return
        with obs.span("lsm.spill", records=len(self._mem)):
            self._spill_items_locked(self._mem)
            self._mem = {}

    def _spill_frozen_locked(self) -> None:
        """Spill the memtable a pipelined ``seal_commit`` froze (no-op
        if it froze none).  Runs on the sequencer worker; the WAL
        truncate inside preserves the next wave's buffered appends
        (``WAL.truncate`` drops the file, not the buffer).  The frozen
        dict is released only after the manifest swap succeeds, so a
        failure here leaves it readable and its records replayable."""
        if self._frozen is None:
            return
        if self._frozen:
            with obs.span("lsm.spill", records=len(self._frozen)):
                self._spill_items_locked(self._frozen)
        self._frozen = None

    def _spill_items_locked(self, mem: dict) -> None:
        name = self._manifest.alloc_segment()
        path = os.path.join(self.dirname, name)
        stats = write_sstable(path, sorted(mem.items()),
                              sync=self._sync == "fsync",
                              bloom_bits_per_key=self._bloom_bits)
        self._manifest.segments.append(MF.SegmentMeta(
            name=name, level=0, records=stats.n_records,
            bytes=stats.file_bytes,
            min_key=stats.min_key.hex(), max_key=stats.max_key.hex(),
            bloom_k=stats.bloom_k, bloom_bits=stats.bloom_nbits))
        self._store_manifest_locked()
        self._tables[name] = self._open_table(name)
        self._rebuild_read_order()
        self._wal.truncate()

    # ------------------------------------------------------------------
    # leveled compaction: partitioned, budgeted, resumable
    # ------------------------------------------------------------------
    def _cap_bytes(self, level: int) -> int:
        """Byte capacity of ``level``: ``target · ratio^level``."""
        return self._target * (self._ratio ** level)

    def _level_bytes(self) -> dict[int, int]:
        lb: dict[int, int] = {}
        for m in self._manifest.segments:
            lb[m.level] = lb.get(m.level, 0) + m.bytes
        return lb

    def _pick_trigger_locked(self) -> int | None:
        """→ the shallowest level owing a merge: L0 by segment count,
        levels ≥ 1 by byte capacity; None when no level is over."""
        counts = self._manifest.level_counts()
        if counts.get(0, 0) >= self._ratio:
            return 0
        lb = self._level_bytes()
        for level in sorted(lb):
            if level >= 1 and lb[level] > self._cap_bytes(level):
                return level
        return None

    def _begin_compaction_locked(self, level: int) -> MF.CompactionState:
        """Freeze one merge's shape: inputs (victim + range-overlapping
        next-level partitions), output level, and whether tombstones may
        drop — recorded once so a later resume cannot change semantics."""
        segs = self._manifest.segments
        part = {v.level: v.partitioned for v in self._levels}
        src = [m for m in segs if m.level == level]
        if level >= 1 and part.get(level, False):
            # one victim partition: the largest pays the debt down
            # fastest; peers are disjoint so they can stay put
            inputs = [max(src, key=lambda m: (m.bytes, m.name))]
        else:
            # L0 ranges overlap (and a legacy level's may): the whole
            # run must move together or shadowing order would invert
            inputs = list(src)
        out_level = level + 1
        nxt = [m for m in segs if m.level == out_level]
        if nxt:
            if part.get(out_level, False):
                ranges = [_meta_range(m) for m in inputs]
                if all(ranges):
                    lo = min(r[0] for r in ranges)
                    hi = max(r[1] for r in ranges)
                    overlap = [m for m in nxt
                               if not (bytes.fromhex(m.max_key) < lo
                                       or bytes.fromhex(m.min_key) > hi)]
                else:
                    overlap = list(nxt)     # unknown span: take everything
            else:
                # merging INTO an unpartitioned level partitions it,
                # but only if the whole level is rewritten
                overlap = list(nxt)
            inputs = inputs + overlap
        drop = not any(m.level > out_level for m in segs)
        return MF.CompactionState(
            level=level, out_level=out_level,
            inputs=[m.name for m in inputs], outputs=[],
            next_key="", drop_tombstones=drop)

    def _merge_inputs_locked(self, st: MF.CompactionState
                             ) -> list[tuple[bytes, object]]:
        """Re-derive the merge's sorted item stream from its live inputs
        (deterministic, so a resume reproduces the exact same stream)."""
        pos = {m.name: i for i, m in enumerate(self._manifest.segments)}
        names = set(st.inputs)
        metas = [m for m in self._manifest.segments if m.name in names]
        merged: dict[bytes, object] = {}
        # oldest version first so newer overwrites: deeper level first,
        # then chronological manifest position within a level
        for m in sorted(metas, key=lambda m: (-m.level, pos[m.name])):
            for k, v in self._tables[m.name].iter_all():
                merged[k] = v
        if st.drop_tombstones:
            return sorted((k, v) for k, v in merged.items()
                          if v is not TOMBSTONE)
        return sorted(merged.items())

    def _partition_spans(self, items: list) -> Iterator[tuple[int, int]]:
        """Split points: each span is ≥ 1 record and crosses the target
        size by at most one record (estimated as klen + vlen + 8)."""
        i, n = 0, len(items)
        while i < n:
            est, j = 0, i
            while j < n and (j == i or est < self._target):
                k, v = items[j]
                est += len(k) + (len(v) if isinstance(v, bytes) else 0) + 8
                j += 1
            yield i, j
            i = j

    def _write_partition_locked(self, items: list, out_level: int
                                ) -> MF.SegmentMeta:
        name = self._manifest.alloc_segment()
        stats = write_sstable(os.path.join(self.dirname, name), items,
                              sync=self._sync == "fsync",
                              bloom_bits_per_key=self._bloom_bits)
        return MF.SegmentMeta(
            name=name, level=out_level, records=stats.n_records,
            bytes=stats.file_bytes,
            min_key=stats.min_key.hex(), max_key=stats.max_key.hex(),
            bloom_k=stats.bloom_k, bloom_bits=stats.bloom_nbits)

    def _advance_compaction_locked(self, st: MF.CompactionState,
                                   budget_left: int | None) -> int:
        """Run one merge until done or out of budget; → bytes written.

        On pause, the completed partitions + resume key go into the
        manifest atomically (``compaction`` field) while the inputs stay
        live — a crash either resumes from exactly here or, if it beat
        the manifest swap, re-merges from the previous pause point and
        the unrecorded partition files are swept as orphans."""
        items = self._merge_inputs_locked(st)
        if st.next_key:
            resume = bytes.fromhex(st.next_key)
            items = [kv for kv in items if kv[0] >= resume]
        spent = 0
        for i, j in self._partition_spans(items):
            meta = self._write_partition_locked(items[i:j], st.out_level)
            st.outputs.append(meta)
            spent += meta.bytes
            if j < len(items) and budget_left is not None \
                    and spent >= budget_left:
                st.next_key = items[j][0].hex()
                self._manifest.compaction = st
                self._count("compact_pause")
                self._store_manifest_locked()
                return spent
        self._finalize_compaction_locked(st)
        return spent

    def _finalize_compaction_locked(self, st: MF.CompactionState) -> None:
        """Publish the merge: outputs become live, inputs are deleted —
        one atomic manifest swap is the commit point."""
        self._count("compact_level")
        names = set(st.inputs)
        keep = [m for m in self._manifest.segments if m.name not in names]
        self._manifest.segments = keep + list(st.outputs)
        self._manifest.compaction = None
        self._store_manifest_locked()
        for name in st.inputs:
            table = self._tables.pop(name, None)
            if table is not None:
                table.close()
            try:
                os.remove(os.path.join(self.dirname, name))
            except FileNotFoundError:
                pass
        for meta in st.outputs:
            self._tables[meta.name] = self._open_table(meta.name)
        self._rebuild_read_order()

    def _maybe_compact_locked(self) -> None:
        """Pay down compaction debt up to the per-wave byte budget:
        resume any paused merge first, then keep servicing triggers
        (L0 count, then byte-capacity overflow shallowest-first) until
        the debt or the budget is exhausted.  Unbudgeted (0), this runs
        every owed merge to completion — each merge still only touches
        its victim + overlap, never the whole store."""
        budget = self._budget
        spent = 0
        while True:
            st = self._manifest.compaction
            if st is None:
                level = self._pick_trigger_locked()
                if level is None:
                    break
                st = self._begin_compaction_locked(level)
                self._manifest.compaction = st  # durable only at a pause
            left = None if budget == 0 else max(1, budget - spent)
            with obs.span("lsm.compact_level", level=st.level,
                          segments=len(st.inputs),
                          resumed=bool(st.next_key)):
                spent += self._advance_compaction_locked(st, left)
            if self._manifest.compaction is not None:
                break                           # paused on budget
            if budget and spent >= budget:
                break
        self.last_compact_bytes = spent

    def _kick_compaction_locked(self) -> None:
        """Compaction admission at a commit/spill boundary: pay the debt
        inline (up to the budget), or — with ``bg_compact`` on — wake
        the daemon worker and return immediately, leaving the debt on
        the ``compact_debt`` gauge for backpressure."""
        if self._bg_thread is not None:
            if self._manifest.compaction is not None \
                    or self._compact_debt_locked() > 0:
                self._bg_wake.set()
        else:
            self._maybe_compact_locked()

    def _bg_loop(self) -> None:
        """Daemon worker: one budget-bounded merge slice per wakeup,
        re-arming itself while debt remains so the lock is released
        between slices (readers and commits interleave).  Any failure —
        IO error or an injected crash firing on this thread — parks in
        ``_bg_exc`` and is re-raised by the next mutation on the caller
        thread (:meth:`_raise_bg`)."""
        while True:
            self._bg_wake.wait()
            self._bg_wake.clear()
            if self._bg_stop.is_set():
                return
            try:
                with self._lock:
                    if self._closed:
                        return
                    self._maybe_compact_locked()
                    more = (self._manifest.compaction is not None
                            or self._compact_debt_locked() > 0)
                if more:
                    self._bg_wake.set()
            except BaseException as e:          # noqa: BLE001 - re-raised
                self._bg_exc = e
                return

    def _stop_bg(self) -> None:
        """Stop + join the worker (idempotent; close() and tests use it;
        the fuzz harness's ``abandon`` calls it too — a dead process has
        no threads)."""
        t = self._bg_thread
        if t is None:
            return
        self._bg_stop.set()
        self._bg_wake.set()
        if t is not threading.current_thread():
            t.join(timeout=10.0)
        self._bg_thread = None

    def compact_debt(self) -> int:
        """Outstanding merge work, in bytes — the backpressure gauge.

        Sums the over-capacity bytes of every level (all of L0 when its
        count trigger is armed) plus the unwritten remainder of a paused
        merge.  0 ⇔ no merge is owed; the serving tier reads this
        through ``QueryEngine.stats`` / ``stats_snapshot()`` as
        ``compact_debt``."""
        with self._lock:
            return self._compact_debt_locked()

    def _compact_debt_locked(self) -> int:
        lb = self._level_bytes()
        counts = self._manifest.level_counts()
        debt = 0
        if counts.get(0, 0) >= self._ratio:
            debt += lb.get(0, 0)
        for level, b in lb.items():
            if level >= 1:
                debt += max(0, b - self._cap_bytes(level))
        st = self._manifest.compaction
        if st is not None:
            names = set(st.inputs)
            in_bytes = sum(m.bytes for m in self._manifest.segments
                           if m.name in names)
            done = sum(o.bytes for o in st.outputs)
            debt += max(0, in_bytes - done)
        return debt

    def _abandon_compaction_locked(self) -> None:
        """Drop a paused merge (major compaction supersedes it): the
        recorded outputs are redundant copies of still-live inputs, so
        deleting them loses nothing."""
        st = self._manifest.compaction
        if st is None:
            return
        self._count("compact_abandon")
        self._manifest.compaction = None
        for meta in st.outputs:
            try:
                os.remove(os.path.join(self.dirname, meta.name))
            except FileNotFoundError:
                pass
        self._store_manifest_locked()

    def compact(self) -> None:
        """**Major** compaction: commit + spill the open tail, abandon
        any paused merge, then merge *every* level into the bottom level
        (partitioned at the segment target), dropping all tombstones
        (the merge covers the whole keyspace).  O(total bytes) — the
        explicit maintenance/benchmark operation; the online trigger
        path (:meth:`commit_epoch` → ``_maybe_compact_locked``) only
        ever merges one victim + overlap at a time."""
        self._raise_bg()
        with self._lock:
            # segments may only ever hold committed records (recovery
            # trusts them unconditionally) — close the open wave first
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._spill_locked()
            self._compact_all_locked()

    def _compact_all_locked(self) -> None:
        """Full merge of all segments into partitions at the bottom."""
        self._abandon_compaction_locked()
        if not self._manifest.segments:
            return
        with obs.span("lsm.compact_major",
                      segments=len(self._manifest.segments)):
            self._compact_all_impl()

    def _compact_all_impl(self) -> None:
        merged: dict[bytes, object] = {}
        for _, seg in reversed(self._read_order):   # oldest version first
            for k, v in seg.iter_all():
                merged[k] = v
        items = sorted((k, v) for k, v in merged.items() if v is not TOMBSTONE)
        out_level = max(1, max(m.level for m in self._manifest.segments))
        old = list(self._manifest.segments)
        outs = [self._write_partition_locked(items[i:j], out_level)
                for i, j in self._partition_spans(items)]
        # a major compact pays off ALL debt: sink the run to the first
        # level whose byte capacity holds it (real bytes are only known
        # post-write; the level lives in the manifest, not the file)
        total = sum(m.bytes for m in outs)
        while total > self._cap_bytes(out_level):
            out_level += 1
        for m in outs:
            m.level = out_level
        self._manifest.segments = outs
        self._store_manifest_locked()
        for meta in old:
            self._tables.pop(meta.name).close()
            try:
                os.remove(os.path.join(self.dirname, meta.name))
            except FileNotFoundError:
                pass
        for meta in outs:
            self._tables[meta.name] = self._open_table(meta.name)
        self._rebuild_read_order()

    def level_counts(self) -> dict[int, int]:
        """→ ``{level: live segment count}`` — the compaction-tree shape
        (tests and the ``wikikv_durable_cold`` benchmark assert on it)."""
        with self._lock:
            return self._manifest.level_counts()

    def set_flat_reads(self, flag: bool) -> None:
        """Toggle the benchmark A/B switch: True probes every segment of
        every level (the pre-partitioned read path) on the same files."""
        with self._lock:
            self._flat_reads = bool(flag)
            self._rebuild_read_order()

    # ------------------------------------------------------------------
    # epoch / invalidation journal (device rehydration contract)
    # ------------------------------------------------------------------
    def journal_invalidation(self, path: str) -> None:
        """Journal one invalidation-bus publish into the WAL (device
        rehydration work list; see module docstring)."""
        with self._lock:
            self._wal.append_inval(path)
            self._inval_buf.append(path)

    def mark_device_epoch(self, epoch: int) -> None:
        """The device tier has applied every dirty path through ``epoch``
        (called inside ``DeviceEngine.refresh`` just before the commit, so
        DEVMARK lands in the same WAL wave as its COMMIT).  Clearing the
        pending list is the real effect; the recorded epoch is kept
        monotone like the commit epoch."""
        with self._lock:
            epoch = max(epoch, self._device_epoch)
            self._wal.append_devmark(epoch)
            self._device_epoch = epoch
            self._pending_inval.clear()
            self._inval_buf.clear()

    def last_epoch(self) -> int:
        """Last committed write epoch (restored across restart)."""
        return self._epoch

    def device_epoch(self) -> int:
        """Epoch the device tier last DEVMARKed as fully applied."""
        return self._device_epoch

    def pending_invalidations(self) -> list[str]:
        """Committed dirty paths the device tier has not applied — the
        rehydration work list (order preserved, duplicates kept: the
        dirty-set consumer dedups)."""
        return list(self._pending_inval)

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Clean shutdown: stop the background worker, commit any
        buffered tail so a reopen is byte-identical, then release file
        handles (idempotent).  A paused merge stays paused — its
        manifest state survives and the reopened store resumes it.  A
        parked background failure RE-RAISES here instead of being
        swallowed: the worker may have died mid-merge with in-memory
        level state partially mutated, and a clean close would commit
        and publish from that wounded state.  Raising makes the caller
        treat the store as crashed — reopen recovers from the on-disk
        state, which the merge only ever mutates atomically."""
        if self._closed:
            return
        self._stop_bg()
        self._raise_bg()
        with self._lock:
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._wal.close()
            for seg in self._tables.values():
                seg.close()
            self._closed = True


# ---------------------------------------------------------------------------
# store-level helpers
# ---------------------------------------------------------------------------
def durable_engine_factory(root: str, memtable_limit: int = 4096,
                           sync: str | None = None,
                           level_ratio: int | None = None,
                           bloom_bits: int | None = None,
                           block_cache: BlockCache | None = None,
                           segment_target_bytes: int | None = None,
                           compact_budget_bytes: int | None = None,
                           bg_compact: bool | None = None
                           ) -> Callable[[int], DurableKV]:
    """Engine factory for ``ShardedPathStore``: shard *i* gets its own
    WAL + segment directory ``<root>/shard_<i>`` — per-shard group commit
    and compaction (and, with ``bg_compact``, a per-shard compaction
    worker), the per-shard isolation of the in-memory tier kept on
    disk.  ``block_cache`` (if any) is shared by every shard: one global
    byte budget, hot shards take more of it."""
    def make(i: int) -> DurableKV:
        return DurableKV(os.path.join(root, f"shard_{i:02d}"),
                         memtable_limit=memtable_limit, sync=sync,
                         level_ratio=level_ratio, bloom_bits=bloom_bits,
                         block_cache=block_cache,
                         segment_target_bytes=segment_target_bytes,
                         compact_budget_bytes=compact_budget_bytes,
                         bg_compact=bg_compact)
    return make


STORE_META = "STORE.json"


def open_durable_store(root: str, n_shards: int | None = None,
                       depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET,
                       memtable_limit: int = 4096, sync: str | None = None,
                       level_ratio: int | None = None,
                       bloom_bits: int | None = None,
                       block_cache_bytes: int | None = None,
                       segment_target_bytes: int | None = None,
                       compact_budget_bytes: int | None = None,
                       bg_compact: bool | None = None,
                       shard_workers: int | None = None,
                       commit_pipeline: bool | None = None):
    """Open (or create) a durable path store rooted at ``root``.

    ``n_shards == 1`` → a ``PathStore`` over one ``DurableKV``;
    otherwise a digest-range ``ShardedPathStore`` with one WAL+segment
    directory per shard.  Reopening an existing root recovers from disk
    — zero re-ingestion.  ``level_ratio`` / ``bloom_bits`` /
    ``block_cache_bytes`` / ``segment_target_bytes`` /
    ``compact_budget_bytes`` / ``bg_compact`` / ``shard_workers`` /
    ``commit_pipeline`` default to their ``REPRO_*`` env knobs (see
    docs/STORAGE.md); the block cache is ONE shared LRU across all
    shards, so the byte budget is store-global.

    The shard count is persisted in ``STORE.json`` at creation and
    enforced on reopen: digest-range routing depends on S, so reopening
    with a different count would silently send every lookup to the wrong
    shard.  Pass ``n_shards=None`` to reopen with whatever the store was
    created with."""
    import json
    from ..core.engine import ShardedPathStore
    do_sync = W.sync_mode(sync) == "fsync"
    os.makedirs(root, exist_ok=True)
    cache = default_block_cache(block_cache_bytes)
    meta_path = os.path.join(root, STORE_META)
    if os.path.exists(meta_path):
        with open(meta_path, "r", encoding="utf-8") as f:
            persisted = int(json.load(f)["n_shards"])
        if n_shards is not None and n_shards != persisted:
            raise ValueError(
                f"store at {root!r} was created with n_shards={persisted}, "
                f"cannot reopen with n_shards={n_shards} (digest-range "
                "routing would change)")
        n_shards = persisted
    else:
        n_shards = 1 if n_shards is None else max(1, n_shards)
        tmp = meta_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({"n_shards": n_shards}, f)
            if do_sync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, meta_path)
        if do_sync:
            # the shard-count guard is itself part of the durability
            # story: without it a power loss could leave shard data with
            # no STORE.json, letting a wrong-S reopen misroute digests
            W.fsync_dir(root)
    if n_shards <= 1:
        return PathStore(DurableKV(root, memtable_limit=memtable_limit,
                                   sync=sync, level_ratio=level_ratio,
                                   bloom_bits=bloom_bits, block_cache=cache,
                                   segment_target_bytes=segment_target_bytes,
                                   compact_budget_bytes=compact_budget_bytes,
                                   bg_compact=bg_compact),
                         depth_budget=depth_budget)
    return ShardedPathStore(
        n_shards=n_shards,
        engine_factory=durable_engine_factory(
            root, memtable_limit=memtable_limit, sync=sync,
            level_ratio=level_ratio, bloom_bits=bloom_bits,
            block_cache=cache, segment_target_bytes=segment_target_bytes,
            compact_budget_bytes=compact_budget_bytes,
            bg_compact=bg_compact),
        depth_budget=depth_budget, shard_workers=shard_workers,
        commit_pipeline=commit_pipeline)
