"""``DurableKV`` — the disk-backed LSM engine behind the ``KVEngine``
protocol (ISSUE 3 tentpole).

Write path: every put/delete appends a WAL record (buffered) and lands in
the dict memtable.  ``commit_epoch(e)`` — called once per planner wave by
``QueryEngine.refresh()``, or via ``flush()`` between offline batches —
group-commits the buffered wave to the WAL; when the memtable exceeds its
limit the commit also *spills* it to a sorted segment file and swaps the
manifest, after which the WAL is truncated (everything it held is now in
a segment).

Read path: memtable first, then segments newest-first (tombstone-aware),
exactly MemKV's shape with the frozen runs on disk.

Crash recovery (``recover()``, run at construction): load the manifest,
sweep orphan segments, open the live segments, replay the WAL's committed
waves over them, truncate any uncommitted/corrupt tail.  Guarantees:

* a crash loses at most the wave that had not yet committed (Δ = 1 wave
  across restart — the engine-layer tests assert this end to end);
* a torn WAL tail is detected by CRC and cleanly dropped;
* a crash between segment write and manifest swap leaves an orphan file
  that recovery deletes — the WAL still holds those records, so nothing
  is lost and nothing is duplicated (WAL replay over segments is
  idempotent: upserts and tombstones, not increments).

Epoch rehydration: COMMIT records carry the write epoch and DEVMARK
records the epoch the device tier last applied; INV records journal
every invalidation-bus publish.  After restart, ``last_epoch()`` restores
the engine epoch and ``pending_invalidations()`` returns the committed
dirty paths the device tier had NOT yet applied — the exact
``TensorDelta`` work list for its first post-restart ``refresh()``.
"""
from __future__ import annotations

import os
import threading
from typing import Callable, Iterator, Optional

from ..core import paths as P
from ..core.store import KVEngine, PathStore
from . import manifest as MF
from . import wal as W
from .sstable import MISSING, TOMBSTONE, SSTable, write_sstable

WAL_NAME = "wikikv.wal"


class DurableKV(KVEngine):
    """Durable memtable → WAL → SSTable engine; one directory per engine
    (per digest-range shard when used under ``ShardedPathStore``)."""

    def __init__(self, dirname: str, memtable_limit: int = 4096,
                 sync: str | None = None, auto_compact_segments: int = 8):
        self.dirname = dirname
        self._limit = memtable_limit
        self._auto = auto_compact_segments
        self._sync = W.sync_mode(sync)
        self._lock = threading.RLock()
        self._mem: dict[bytes, object] = {}
        self._segments: list[SSTable] = []     # oldest first; newest wins
        self._inval_buf: list[str] = []        # journaled, not yet committed
        self._closed = False
        os.makedirs(dirname, exist_ok=True)
        self._recover()
        wal_path = os.path.join(dirname, WAL_NAME)
        wal_existed = os.path.exists(wal_path)
        self._wal = W.WAL(wal_path, sync=self._sync)
        if self._sync == "fsync" and not wal_existed:
            # a freshly created WAL's directory entry must be durable
            # before any commit claims its contents are
            W.fsync_dir(dirname)

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _recover(self) -> None:
        m = MF.load(self.dirname)
        MF.sweep_orphans(self.dirname, m)
        self._manifest = m
        self._segments = [SSTable(os.path.join(self.dirname, name))
                          for name in m.segments]
        self._epoch = m.epoch
        self._device_epoch = m.device_epoch
        self._pending_inval: list[str] = list(m.pending_inval)
        wal_path = os.path.join(self.dirname, WAL_NAME)
        res = W.replay(wal_path)
        for wave in res.waves:
            for rec in wave:
                if rec.kind == W.PUT:
                    self._mem[rec.key] = rec.value
                elif rec.kind == W.DEL:
                    self._mem[rec.key] = TOMBSTONE
                elif rec.kind == W.INV:
                    self._pending_inval.append(rec.path)
                elif rec.kind == W.DEVMARK:
                    self._device_epoch = max(self._device_epoch, rec.epoch)
                    self._pending_inval.clear()
                elif rec.kind == W.COMMIT:
                    self._epoch = max(self._epoch, rec.epoch)
        self.recovery_dropped = res.dropped_records
        self.recovery_corrupt_tail = res.corrupt_tail
        if res.dropped_records or res.corrupt_tail:
            # drop the uncommitted wave / torn tail so the next append
            # starts at a clean frame boundary
            with open(wal_path, "rb+") as f:
                f.truncate(res.valid_end)

    # ------------------------------------------------------------------
    # KVEngine surface
    # ------------------------------------------------------------------
    def put(self, key: bytes, value: bytes) -> None:
        self._count("put")
        with self._lock:
            self._wal.append_put(key, value)
            self._mem[key] = value

    def delete(self, key: bytes) -> None:
        self._count("delete")
        with self._lock:
            self._wal.append_delete(key)
            self._mem[key] = TOMBSTONE

    def get(self, key: bytes) -> Optional[bytes]:
        self._count("get")
        with self._lock:
            v = self._mem.get(key)
            if v is not None:
                return None if v is TOMBSTONE else v  # type: ignore[return-value]
            for seg in reversed(self._segments):
                v = seg.get(key)
                if v is TOMBSTONE:
                    return None
                if v is not MISSING:
                    return v  # type: ignore[return-value]
        return None

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        self._count("scan")
        with self._lock:
            merged: dict[bytes, object] = {}
            for seg in self._segments:          # oldest → newest
                for k, v in seg.scan(prefix):
                    merged[k] = v
            for k, v in self._mem.items():
                if k.startswith(prefix):
                    merged[k] = v
        for k in sorted(merged):
            v = merged[k]
            if v is not TOMBSTONE:
                yield k, v  # type: ignore[misc]

    def flush(self) -> None:
        """KVEngine hygiene hook (offline pipeline batches): commit the
        buffered wave at the current epoch — durability without an epoch
        bump."""
        self.commit_epoch(self._epoch)

    # ------------------------------------------------------------------
    # group commit + spill (the wave boundary)
    # ------------------------------------------------------------------
    def commit_epoch(self, epoch: int) -> None:
        with self._lock:
            # monotone: a lagging engine sharing this store (e.g. a
            # device mirror whose own counter trails the host's) must
            # never move the committed epoch backwards
            epoch = max(epoch, self._epoch)
            if (epoch == self._epoch and self._wal.pending_bytes() == 0
                    and not self._inval_buf and len(self._mem) < self._limit):
                # same epoch, nothing to make durable: skip the COMMIT
                # frame and its fsync, so repeated flush() calls never
                # grow the WAL with redundant empty waves.  An epoch
                # ADVANCE is always recorded, even content-free — the
                # committed epoch sequence must survive restart.
                return
            self._wal.commit(epoch)
            self._epoch = epoch
            self._manifest.epoch = epoch
            self._pending_inval.extend(self._inval_buf)
            self._inval_buf.clear()
            if len(self._mem) >= self._limit:
                self._spill_locked()
                if len(self._segments) >= self._auto:
                    self._compact_locked()

    def _spill_locked(self) -> None:
        """Freeze the (fully committed) memtable into a new segment and
        make it live: segment write + fsync → manifest swap → WAL reset.
        Each arrow is a crash boundary recovery handles (orphan sweep /
        idempotent WAL replay)."""
        if not self._mem:
            return
        name = self._manifest.alloc_segment()
        path = os.path.join(self.dirname, name)
        write_sstable(path, sorted(self._mem.items()),
                      sync=self._sync == "fsync")
        self._manifest.segments.append(name)
        # the manifest must carry the LIVE counters, not whatever it held
        # on disk: after a reopen the committed epoch may exist only in
        # WAL COMMIT records, and the reset below truncates those
        self._manifest.epoch = self._epoch
        self._manifest.device_epoch = self._device_epoch
        self._manifest.pending_inval = list(self._pending_inval)
        MF.store(self.dirname, self._manifest, sync=self._sync == "fsync")
        self._segments.append(SSTable(path))
        self._mem = {}
        self._wal.reset()

    def compact(self) -> None:
        with self._lock:
            # segments may only ever hold committed records (recovery
            # trusts them unconditionally) — close the open wave first
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._spill_locked()
            self._compact_locked()

    def _compact_locked(self) -> None:
        """Full merge of all segments into one; tombstones drop (the merge
        covers the whole keyspace).  Crash-safe: the merged segment only
        becomes live at the manifest swap, and the old files are deleted
        only after it."""
        if not self._segments:
            return
        merged: dict[bytes, object] = {}
        for seg in self._segments:
            for k, v in seg.iter_all():
                merged[k] = v
        items = sorted((k, v) for k, v in merged.items() if v is not TOMBSTONE)
        old = list(self._manifest.segments)
        if items:
            name = self._manifest.alloc_segment()
            write_sstable(os.path.join(self.dirname, name), items,
                          sync=self._sync == "fsync")
            self._manifest.segments = [name]
        else:
            self._manifest.segments = []
        self._manifest.epoch = self._epoch
        self._manifest.device_epoch = self._device_epoch
        self._manifest.pending_inval = list(self._pending_inval)
        MF.store(self.dirname, self._manifest, sync=self._sync == "fsync")
        for seg in self._segments:
            seg.close()
        for stale in old:
            try:
                os.remove(os.path.join(self.dirname, stale))
            except FileNotFoundError:
                pass
        self._segments = [SSTable(os.path.join(self.dirname, n))
                          for n in self._manifest.segments]

    # ------------------------------------------------------------------
    # epoch / invalidation journal (device rehydration contract)
    # ------------------------------------------------------------------
    def journal_invalidation(self, path: str) -> None:
        with self._lock:
            self._wal.append_inval(path)
            self._inval_buf.append(path)

    def mark_device_epoch(self, epoch: int) -> None:
        """The device tier has applied every dirty path through ``epoch``
        (called inside ``DeviceEngine.refresh`` just before the commit, so
        DEVMARK lands in the same WAL wave as its COMMIT).  Clearing the
        pending list is the real effect; the recorded epoch is kept
        monotone like the commit epoch."""
        with self._lock:
            epoch = max(epoch, self._device_epoch)
            self._wal.append_devmark(epoch)
            self._device_epoch = epoch
            self._pending_inval.clear()
            self._inval_buf.clear()

    def last_epoch(self) -> int:
        return self._epoch

    def device_epoch(self) -> int:
        return self._device_epoch

    def pending_invalidations(self) -> list[str]:
        """Committed dirty paths the device tier has not applied — the
        rehydration work list (order preserved, duplicates kept: the
        dirty-set consumer dedups)."""
        return list(self._pending_inval)

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Clean shutdown: commit any buffered tail so a reopen is
        byte-identical, then release file handles."""
        if self._closed:
            return
        with self._lock:
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._wal.close()
            for seg in self._segments:
                seg.close()
            self._closed = True


# ---------------------------------------------------------------------------
# store-level helpers
# ---------------------------------------------------------------------------
def durable_engine_factory(root: str, memtable_limit: int = 4096,
                           sync: str | None = None
                           ) -> Callable[[int], DurableKV]:
    """Engine factory for ``ShardedPathStore``: shard *i* gets its own
    WAL + segment directory ``<root>/shard_<i>`` — per-shard group commit
    and compaction, the per-shard isolation of the in-memory tier kept on
    disk."""
    def make(i: int) -> DurableKV:
        return DurableKV(os.path.join(root, f"shard_{i:02d}"),
                         memtable_limit=memtable_limit, sync=sync)
    return make


STORE_META = "STORE.json"


def open_durable_store(root: str, n_shards: int | None = None,
                       depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET,
                       memtable_limit: int = 4096, sync: str | None = None):
    """Open (or create) a durable path store rooted at ``root``.

    ``n_shards == 1`` → a ``PathStore`` over one ``DurableKV``;
    otherwise a digest-range ``ShardedPathStore`` with one WAL+segment
    directory per shard.  Reopening an existing root recovers from disk
    — zero re-ingestion.

    The shard count is persisted in ``STORE.json`` at creation and
    enforced on reopen: digest-range routing depends on S, so reopening
    with a different count would silently send every lookup to the wrong
    shard.  Pass ``n_shards=None`` to reopen with whatever the store was
    created with."""
    import json
    from ..core.engine import ShardedPathStore
    do_sync = W.sync_mode(sync) == "fsync"
    os.makedirs(root, exist_ok=True)
    meta_path = os.path.join(root, STORE_META)
    if os.path.exists(meta_path):
        with open(meta_path, "r", encoding="utf-8") as f:
            persisted = int(json.load(f)["n_shards"])
        if n_shards is not None and n_shards != persisted:
            raise ValueError(
                f"store at {root!r} was created with n_shards={persisted}, "
                f"cannot reopen with n_shards={n_shards} (digest-range "
                "routing would change)")
        n_shards = persisted
    else:
        n_shards = 1 if n_shards is None else max(1, n_shards)
        tmp = meta_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({"n_shards": n_shards}, f)
            if do_sync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, meta_path)
        if do_sync:
            # the shard-count guard is itself part of the durability
            # story: without it a power loss could leave shard data with
            # no STORE.json, letting a wrong-S reopen misroute digests
            W.fsync_dir(root)
    if n_shards <= 1:
        return PathStore(DurableKV(root, memtable_limit=memtable_limit,
                                   sync=sync),
                         depth_budget=depth_budget)
    return ShardedPathStore(
        n_shards=n_shards,
        engine_factory=durable_engine_factory(
            root, memtable_limit=memtable_limit, sync=sync),
        depth_budget=depth_budget)
