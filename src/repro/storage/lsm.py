"""``DurableKV`` — the disk-backed LSM engine behind the ``KVEngine``
protocol (ISSUE 3 tentpole; leveled compaction + bloom filters + block
cache since ISSUE 7).

Write path: every put/delete appends a WAL record (buffered) and lands in
the dict memtable.  ``commit_epoch(e)`` — called once per planner wave by
``QueryEngine.refresh()``, or via ``flush()`` between offline batches —
group-commits the buffered wave to the WAL; when the memtable exceeds its
limit the commit also *spills* it to a sorted level-0 segment and swaps
the manifest, after which the WAL is truncated (everything it held is now
in a segment).

Compaction is size-tiered and leveled: when any level accumulates
``level_ratio`` segments (default 4, ``REPRO_LEVEL_RATIO``), that one
level's run is merged into a single segment at the next level down —
O(bytes of the triggering level) per trigger, never O(total store).
Data only moves downward, so every version in level L is strictly newer
than any version of the same key below it; tombstones are dropped only
when the merge output lands at the bottom of the tree (no older level
left to shadow).  ``compact()`` remains the explicit *major* compaction
(merge everything into one bottom segment — the maintenance/benchmark
path), but the online trigger never does that.

Read path: memtable first, then segments level by level (newest first
within a level), tombstone-aware.  Each new segment carries a bloom
filter in its footer (``REPRO_BLOOM_BITS`` bits/key, default 10; 0
disables and writes PR-3-compatible bytes), so a point miss skips a
segment with k bit-probes instead of touching its mmap — the key is
hashed once per lookup, not once per segment.  An optional shared
:class:`~repro.storage.sstable.BlockCache` (``REPRO_BLOCK_CACHE_BYTES``)
serves hot index blocks from memory.

Crash recovery (``recover()``, run at construction): load the manifest,
sweep orphan segments, open the live segments, replay the WAL's committed
waves over them, truncate any uncommitted/corrupt tail.  Guarantees:

* a crash loses at most the wave that had not yet committed (Δ = 1 wave
  across restart — the engine-layer tests assert this end to end);
* a torn WAL tail is detected by CRC and cleanly dropped;
* a crash between segment write and manifest swap — whether the segment
  was a memtable spill or a level merge — leaves an orphan file that
  recovery deletes: the manifest still references the pre-crash inputs,
  so the store's view is the pre-compaction one and nothing is lost or
  duplicated (WAL replay over segments is idempotent: upserts and
  tombstones, not increments).

Epoch rehydration: COMMIT records carry the write epoch and DEVMARK
records the epoch the device tier last applied; INV records journal
every invalidation-bus publish.  After restart, ``last_epoch()`` restores
the engine epoch and ``pending_invalidations()`` returns the committed
dirty paths the device tier had NOT yet applied — the exact
``TensorDelta`` work list for its first post-restart ``refresh()``.
"""
from __future__ import annotations

import os
import threading
from typing import Callable, Iterator, Optional

from .. import obs
from ..core import paths as P
from ..core.store import KVEngine, PathStore
from . import manifest as MF
from . import wal as W
from .sstable import (MISSING, TOMBSTONE, BlockCache, SSTable,
                      bloom_hash_pair, write_sstable)

WAL_NAME = "wikikv.wal"

#: ``REPRO_LEVEL_RATIO`` — segments a level may hold before its run is
#: merged into the next level (size-ratio trigger; default 4, min 2)
LEVEL_RATIO_ENV = "REPRO_LEVEL_RATIO"
#: ``REPRO_BLOOM_BITS`` — bloom bits per key written into new segment
#: footers (default 10 ≈ 0.8% FPR at k=7; 0 disables → PR-3 byte layout)
BLOOM_BITS_ENV = "REPRO_BLOOM_BITS"
#: ``REPRO_BLOCK_CACHE_BYTES`` — byte budget of the block cache
#: ``open_durable_store`` shares across shards (default 8 MiB; 0 disables)
BLOCK_CACHE_ENV = "REPRO_BLOCK_CACHE_BYTES"


def resolve_level_ratio(explicit: int | None = None) -> int:
    """Resolve the per-level compaction trigger (arg > env > default 4)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(LEVEL_RATIO_ENV, "4"))
    if val < 2:
        raise ValueError(f"level_ratio must be >= 2, got {val}")
    return val


def resolve_bloom_bits(explicit: int | None = None) -> int:
    """Resolve bloom bits/key for new segments (arg > env > default 10)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(BLOOM_BITS_ENV, "10"))
    if val < 0:
        raise ValueError(f"bloom_bits must be >= 0, got {val}")
    return val


def default_block_cache(explicit_bytes: int | None = None
                        ) -> BlockCache | None:
    """Build the shared block cache ``open_durable_store`` hands every
    shard (arg > env > default 8 MiB); 0 bytes → no cache (None)."""
    val = explicit_bytes if explicit_bytes is not None else \
        int(os.environ.get(BLOCK_CACHE_ENV, str(8 << 20)))
    if val < 0:
        raise ValueError(f"block cache bytes must be >= 0, got {val}")
    return BlockCache(val) if val else None


class DurableKV(KVEngine):
    """Durable memtable → WAL → leveled-SSTable engine; one directory per
    engine (per digest-range shard under ``ShardedPathStore``).

    Args: ``dirname`` store directory (created; recovered if it already
    holds a store), ``memtable_limit`` entries before a commit spills,
    ``sync`` WAL sync mode (None → ``REPRO_WAL_SYNC``), ``level_ratio``
    segments per level before a merge (None → ``REPRO_LEVEL_RATIO``),
    ``bloom_bits`` filter bits/key for new segments (None →
    ``REPRO_BLOOM_BITS``; 0 writes PR-3-layout segments), ``block_cache``
    a shared :class:`BlockCache` or None (no cache — the default for a
    bare engine; ``open_durable_store`` wires a shared one)."""

    def __init__(self, dirname: str, memtable_limit: int = 4096,
                 sync: str | None = None, level_ratio: int | None = None,
                 bloom_bits: int | None = None,
                 block_cache: BlockCache | None = None):
        self.dirname = dirname
        self._limit = memtable_limit
        self._ratio = resolve_level_ratio(level_ratio)
        self._bloom_bits = resolve_bloom_bits(bloom_bits)
        self._cache = block_cache
        self._sync = W.sync_mode(sync)
        self._lock = threading.RLock()
        self._mem: dict[bytes, object] = {}
        self._tables: dict[str, SSTable] = {}  # segment name -> open reader
        self._read_order: list[tuple[MF.SegmentMeta, SSTable]] = []
        self._inval_buf: list[str] = []        # journaled, not yet committed
        self._closed = False
        os.makedirs(dirname, exist_ok=True)
        self._recover()
        wal_path = os.path.join(dirname, WAL_NAME)
        wal_existed = os.path.exists(wal_path)
        self._wal = W.WAL(wal_path, sync=self._sync)
        if self._sync == "fsync" and not wal_existed:
            # a freshly created WAL's directory entry must be durable
            # before any commit claims its contents are
            W.fsync_dir(dirname)

    # ------------------------------------------------------------------
    # recovery
    # ------------------------------------------------------------------
    def _open_table(self, name: str) -> SSTable:
        return SSTable(os.path.join(self.dirname, name),
                       cache=self._cache, stat=self._count)

    def _rebuild_read_order(self) -> None:
        """Recompute probe order: level ascending (lower shadows deeper),
        newest-first within a level (chronological manifest position)."""
        segs = self._manifest.segments
        order = sorted(range(len(segs)),
                       key=lambda i: (segs[i].level, -i))
        self._read_order = [(segs[i], self._tables[segs[i].name])
                            for i in order]

    def _recover(self) -> None:
        """Manifest → orphan sweep → open segments → WAL replay →
        truncate the uncommitted/corrupt tail (see module docstring)."""
        with obs.span("lsm.recover") as sp:
            self._recover_impl()
            sp.set(waves=self._epoch, dropped=self.recovery_dropped)

    def _recover_impl(self) -> None:
        m = MF.load(self.dirname)
        MF.sweep_orphans(self.dirname, m)
        self._manifest = m
        self._tables = {meta.name: self._open_table(meta.name)
                        for meta in m.segments}
        self._rebuild_read_order()
        self._epoch = m.epoch
        self._device_epoch = m.device_epoch
        self._pending_inval: list[str] = list(m.pending_inval)
        wal_path = os.path.join(self.dirname, WAL_NAME)
        res = W.replay(wal_path)
        for wave in res.waves:
            for rec in wave:
                if rec.kind == W.PUT:
                    self._mem[rec.key] = rec.value
                elif rec.kind == W.DEL:
                    self._mem[rec.key] = TOMBSTONE
                elif rec.kind == W.INV:
                    self._pending_inval.append(rec.path)
                elif rec.kind == W.DEVMARK:
                    self._device_epoch = max(self._device_epoch, rec.epoch)
                    self._pending_inval.clear()
                elif rec.kind == W.COMMIT:
                    self._epoch = max(self._epoch, rec.epoch)
        self.recovery_dropped = res.dropped_records
        self.recovery_corrupt_tail = res.corrupt_tail
        if res.dropped_records or res.corrupt_tail:
            # drop the uncommitted wave / torn tail so the next append
            # starts at a clean frame boundary
            with open(wal_path, "rb+") as f:
                f.truncate(res.valid_end)

    # ------------------------------------------------------------------
    # KVEngine surface
    # ------------------------------------------------------------------
    def put(self, key: bytes, value: bytes) -> None:
        """Upsert ``key`` → WAL buffer + memtable (durable at the next
        ``commit_epoch``).  O(1)."""
        self._count("put")
        with self._lock:
            self._wal.append_put(key, value)
            self._mem[key] = value

    def delete(self, key: bytes) -> None:
        """Tombstone ``key`` (shadows every older level until a bottom
        merge drops it).  O(1)."""
        self._count("delete")
        with self._lock:
            self._wal.append_delete(key)
            self._mem[key] = TOMBSTONE

    def get(self, key: bytes) -> Optional[bytes]:
        """Point lookup: memtable, then segments level by level (newest
        first within a level).

        Complexity: O(1) memtable hit; otherwise the key is bloom-hashed
        **once** and each of the S live segments costs k bit-probes — a
        negative filter skips the segment entirely (counted as
        ``bloom_neg`` in :meth:`op_counts`) — plus, for the segments that
        may contain it, O(log n_index) bisect + one ≤ SPARSE_EVERY-record
        block (served from the shared block cache when attached:
        ``cache_hit``/``cache_miss`` counters).  A miss over an all-bloom
        store therefore touches **no** segment bytes at ~0.8% FPR."""
        self._count("get")
        with self._lock:
            v = self._mem.get(key)
            if v is not None:
                return None if v is TOMBSTONE else v  # type: ignore[return-value]
            hashes: tuple[int, int] | None = None
            for meta, seg in self._read_order:
                if seg.bloom is not None:
                    if hashes is None:
                        hashes = bloom_hash_pair(key)
                    if not seg.bloom.may_contain_hashes(*hashes):
                        self._count("bloom_neg")
                        continue
                v = seg.get(key)
                if v is TOMBSTONE:
                    return None
                if v is not MISSING:
                    return v  # type: ignore[return-value]
        return None

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        """Ordered iteration over live ``prefix``-keyed pairs (tombstones
        resolved).  Complexity: O(hits · S) merge over every segment's
        prefix range plus the memtable — scans bypass bloom filters and
        the block cache by design (range reads would pollute it)."""
        self._count("scan")
        with self._lock:
            merged: dict[bytes, object] = {}
            # oldest version first so newer levels overwrite: reversed
            # probe order == deepest level upward, oldest-first within
            for _, seg in reversed(self._read_order):
                for k, v in seg.scan(prefix):
                    merged[k] = v
            for k, v in self._mem.items():
                if k.startswith(prefix):
                    merged[k] = v
        for k in sorted(merged):
            v = merged[k]
            if v is not TOMBSTONE:
                yield k, v  # type: ignore[misc]

    def flush(self) -> None:
        """KVEngine hygiene hook (offline pipeline batches): commit the
        buffered wave at the current epoch — durability without an epoch
        bump."""
        self.commit_epoch(self._epoch)

    # ------------------------------------------------------------------
    # group commit + spill (the wave boundary)
    # ------------------------------------------------------------------
    def commit_epoch(self, epoch: int) -> None:
        """Group-commit the buffered wave at ``epoch`` (monotone), then
        spill the memtable if over its limit and run any leveled
        compaction the spill triggers."""
        with self._lock:
            # monotone: a lagging engine sharing this store (e.g. a
            # device mirror whose own counter trails the host's) must
            # never move the committed epoch backwards
            epoch = max(epoch, self._epoch)
            if (epoch == self._epoch and self._wal.pending_bytes() == 0
                    and not self._inval_buf and len(self._mem) < self._limit):
                # same epoch, nothing to make durable: skip the COMMIT
                # frame and its fsync, so repeated flush() calls never
                # grow the WAL with redundant empty waves.  An epoch
                # ADVANCE is always recorded, even content-free — the
                # committed epoch sequence must survive restart.
                return
            self._wal.commit(epoch)
            self._epoch = epoch
            self._manifest.epoch = epoch
            self._pending_inval.extend(self._inval_buf)
            self._inval_buf.clear()
            if len(self._mem) >= self._limit:
                self._spill_locked()
                self._maybe_compact_locked()

    def spill(self) -> None:
        """Commit the open wave and force the memtable to a level-0
        segment regardless of the limit (then run any triggered leveled
        merges).  Maintenance/benchmark hook: after it, every committed
        record is served from segment files — a truly cold read path."""
        with self._lock:
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._spill_locked()
            self._maybe_compact_locked()

    def _store_manifest_locked(self) -> None:
        """Swap the manifest carrying the LIVE counters, not whatever it
        held on disk: after a reopen the committed epoch may exist only
        in WAL COMMIT records, and a spill's WAL reset truncates those."""
        self._manifest.epoch = self._epoch
        self._manifest.device_epoch = self._device_epoch
        self._manifest.pending_inval = list(self._pending_inval)
        MF.store(self.dirname, self._manifest, sync=self._sync == "fsync")

    def _spill_locked(self) -> None:
        """Freeze the (fully committed) memtable into a new level-0
        segment and make it live: segment write + fsync → manifest swap →
        WAL reset.  Each arrow is a crash boundary recovery handles
        (orphan sweep / idempotent WAL replay)."""
        if not self._mem:
            return
        with obs.span("lsm.spill", records=len(self._mem)):
            self._spill_impl()

    def _spill_impl(self) -> None:
        name = self._manifest.alloc_segment()
        path = os.path.join(self.dirname, name)
        stats = write_sstable(path, sorted(self._mem.items()),
                              sync=self._sync == "fsync",
                              bloom_bits_per_key=self._bloom_bits)
        self._manifest.segments.append(MF.SegmentMeta(
            name=name, level=0, records=stats.n_records,
            bytes=stats.file_bytes,
            min_key=stats.min_key.hex(), max_key=stats.max_key.hex(),
            bloom_k=stats.bloom_k, bloom_bits=stats.bloom_nbits))
        self._store_manifest_locked()
        self._tables[name] = self._open_table(name)
        self._rebuild_read_order()
        self._mem = {}
        self._wal.reset()

    # ------------------------------------------------------------------
    # leveled compaction
    # ------------------------------------------------------------------
    def _maybe_compact_locked(self) -> None:
        """Size-ratio trigger: merge any level holding ≥ ``level_ratio``
        segments into the next level, cascading until no level is over
        the trigger.  Each merge touches only the triggering level's
        bytes — never the whole store."""
        changed = True
        while changed:
            changed = False
            for level in sorted(self._manifest.level_counts()):
                if self._manifest.level_counts()[level] >= self._ratio:
                    self._compact_level_locked(level)
                    changed = True
                    break

    def _compact_level_locked(self, level: int) -> None:
        """Merge level ``level``'s whole run into one segment at
        ``level + 1``.  O(bytes of this level).  Tombstones drop only if
        no deeper level remains to shadow (the merge output is then the
        oldest data in the store).  Crash-safe: the merged segment only
        becomes live at the manifest swap, and the input files are
        deleted only after it."""
        inputs = [m for m in self._manifest.segments if m.level == level]
        if not inputs:
            return
        self._count("compact_level")
        with obs.span("lsm.compact_level", level=level,
                      segments=len(inputs)):
            self._compact_level_impl(level, inputs)

    def _compact_level_impl(self, level: int, inputs) -> None:
        merged: dict[bytes, object] = {}
        for meta in inputs:                     # oldest → newest wins
            for k, v in self._tables[meta.name].iter_all():
                merged[k] = v
        # deeper data (level > this one) is strictly older: a tombstone
        # must survive the merge to keep shadowing it
        has_older = any(m.level > level for m in self._manifest.segments)
        if has_older:
            items = sorted(merged.items())
        else:
            items = sorted((k, v) for k, v in merged.items()
                           if v is not TOMBSTONE)
        keep = [m for m in self._manifest.segments if m.level != level]
        if items:
            name = self._manifest.alloc_segment()
            stats = write_sstable(os.path.join(self.dirname, name), items,
                                  sync=self._sync == "fsync",
                                  bloom_bits_per_key=self._bloom_bits)
            keep.append(MF.SegmentMeta(
                name=name, level=level + 1, records=stats.n_records,
                bytes=stats.file_bytes,
                min_key=stats.min_key.hex(), max_key=stats.max_key.hex(),
                bloom_k=stats.bloom_k, bloom_bits=stats.bloom_nbits))
        self._manifest.segments = keep
        self._store_manifest_locked()
        for meta in inputs:
            self._tables.pop(meta.name).close()
            try:
                os.remove(os.path.join(self.dirname, meta.name))
            except FileNotFoundError:
                pass
        if items:
            self._tables[name] = self._open_table(name)
        self._rebuild_read_order()

    def compact(self) -> None:
        """**Major** compaction: commit + spill the open tail, then merge
        *every* level into one bottom segment, dropping all tombstones
        (the merge covers the whole keyspace).  O(total bytes) — the
        explicit maintenance/benchmark operation; the online trigger path
        (:meth:`commit_epoch` → ``_maybe_compact_locked``) only ever
        merges one level at a time."""
        with self._lock:
            # segments may only ever hold committed records (recovery
            # trusts them unconditionally) — close the open wave first
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._spill_locked()
            self._compact_all_locked()

    def _compact_all_locked(self) -> None:
        """Full merge of all segments into one at the bottom level."""
        if not self._manifest.segments:
            return
        with obs.span("lsm.compact_major",
                      segments=len(self._manifest.segments)):
            self._compact_all_impl()

    def _compact_all_impl(self) -> None:
        merged: dict[bytes, object] = {}
        for _, seg in reversed(self._read_order):   # oldest version first
            for k, v in seg.iter_all():
                merged[k] = v
        items = sorted((k, v) for k, v in merged.items() if v is not TOMBSTONE)
        out_level = max(1, max(m.level for m in self._manifest.segments))
        old = list(self._manifest.segments)
        if items:
            name = self._manifest.alloc_segment()
            stats = write_sstable(os.path.join(self.dirname, name), items,
                                  sync=self._sync == "fsync",
                                  bloom_bits_per_key=self._bloom_bits)
            self._manifest.segments = [MF.SegmentMeta(
                name=name, level=out_level, records=stats.n_records,
                bytes=stats.file_bytes,
                min_key=stats.min_key.hex(), max_key=stats.max_key.hex(),
                bloom_k=stats.bloom_k, bloom_bits=stats.bloom_nbits)]
        else:
            self._manifest.segments = []
        self._store_manifest_locked()
        for meta in old:
            self._tables.pop(meta.name).close()
            try:
                os.remove(os.path.join(self.dirname, meta.name))
            except FileNotFoundError:
                pass
        if items:
            self._tables[name] = self._open_table(name)
        self._rebuild_read_order()

    def level_counts(self) -> dict[int, int]:
        """→ ``{level: live segment count}`` — the compaction-tree shape
        (tests and the ``wikikv_durable_cold`` benchmark assert on it)."""
        with self._lock:
            return self._manifest.level_counts()

    # ------------------------------------------------------------------
    # epoch / invalidation journal (device rehydration contract)
    # ------------------------------------------------------------------
    def journal_invalidation(self, path: str) -> None:
        """Journal one invalidation-bus publish into the WAL (device
        rehydration work list; see module docstring)."""
        with self._lock:
            self._wal.append_inval(path)
            self._inval_buf.append(path)

    def mark_device_epoch(self, epoch: int) -> None:
        """The device tier has applied every dirty path through ``epoch``
        (called inside ``DeviceEngine.refresh`` just before the commit, so
        DEVMARK lands in the same WAL wave as its COMMIT).  Clearing the
        pending list is the real effect; the recorded epoch is kept
        monotone like the commit epoch."""
        with self._lock:
            epoch = max(epoch, self._device_epoch)
            self._wal.append_devmark(epoch)
            self._device_epoch = epoch
            self._pending_inval.clear()
            self._inval_buf.clear()

    def last_epoch(self) -> int:
        """Last committed write epoch (restored across restart)."""
        return self._epoch

    def device_epoch(self) -> int:
        """Epoch the device tier last DEVMARKed as fully applied."""
        return self._device_epoch

    def pending_invalidations(self) -> list[str]:
        """Committed dirty paths the device tier has not applied — the
        rehydration work list (order preserved, duplicates kept: the
        dirty-set consumer dedups)."""
        return list(self._pending_inval)

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Clean shutdown: commit any buffered tail so a reopen is
        byte-identical, then release file handles (idempotent)."""
        if self._closed:
            return
        with self._lock:
            if self._wal.pending_bytes() or self._inval_buf:
                self.commit_epoch(self._epoch)
            self._wal.close()
            for seg in self._tables.values():
                seg.close()
            self._closed = True


# ---------------------------------------------------------------------------
# store-level helpers
# ---------------------------------------------------------------------------
def durable_engine_factory(root: str, memtable_limit: int = 4096,
                           sync: str | None = None,
                           level_ratio: int | None = None,
                           bloom_bits: int | None = None,
                           block_cache: BlockCache | None = None
                           ) -> Callable[[int], DurableKV]:
    """Engine factory for ``ShardedPathStore``: shard *i* gets its own
    WAL + segment directory ``<root>/shard_<i>`` — per-shard group commit
    and compaction, the per-shard isolation of the in-memory tier kept on
    disk.  ``block_cache`` (if any) is shared by every shard: one global
    byte budget, hot shards take more of it."""
    def make(i: int) -> DurableKV:
        return DurableKV(os.path.join(root, f"shard_{i:02d}"),
                         memtable_limit=memtable_limit, sync=sync,
                         level_ratio=level_ratio, bloom_bits=bloom_bits,
                         block_cache=block_cache)
    return make


STORE_META = "STORE.json"


def open_durable_store(root: str, n_shards: int | None = None,
                       depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET,
                       memtable_limit: int = 4096, sync: str | None = None,
                       level_ratio: int | None = None,
                       bloom_bits: int | None = None,
                       block_cache_bytes: int | None = None):
    """Open (or create) a durable path store rooted at ``root``.

    ``n_shards == 1`` → a ``PathStore`` over one ``DurableKV``;
    otherwise a digest-range ``ShardedPathStore`` with one WAL+segment
    directory per shard.  Reopening an existing root recovers from disk
    — zero re-ingestion.  ``level_ratio`` / ``bloom_bits`` /
    ``block_cache_bytes`` default to their ``REPRO_*`` env knobs (see
    docs/STORAGE.md); the block cache is ONE shared LRU across all
    shards, so the byte budget is store-global.

    The shard count is persisted in ``STORE.json`` at creation and
    enforced on reopen: digest-range routing depends on S, so reopening
    with a different count would silently send every lookup to the wrong
    shard.  Pass ``n_shards=None`` to reopen with whatever the store was
    created with."""
    import json
    from ..core.engine import ShardedPathStore
    do_sync = W.sync_mode(sync) == "fsync"
    os.makedirs(root, exist_ok=True)
    cache = default_block_cache(block_cache_bytes)
    meta_path = os.path.join(root, STORE_META)
    if os.path.exists(meta_path):
        with open(meta_path, "r", encoding="utf-8") as f:
            persisted = int(json.load(f)["n_shards"])
        if n_shards is not None and n_shards != persisted:
            raise ValueError(
                f"store at {root!r} was created with n_shards={persisted}, "
                f"cannot reopen with n_shards={n_shards} (digest-range "
                "routing would change)")
        n_shards = persisted
    else:
        n_shards = 1 if n_shards is None else max(1, n_shards)
        tmp = meta_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump({"n_shards": n_shards}, f)
            if do_sync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, meta_path)
        if do_sync:
            # the shard-count guard is itself part of the durability
            # story: without it a power loss could leave shard data with
            # no STORE.json, letting a wrong-S reopen misroute digests
            W.fsync_dir(root)
    if n_shards <= 1:
        return PathStore(DurableKV(root, memtable_limit=memtable_limit,
                                   sync=sync, level_ratio=level_ratio,
                                   bloom_bits=bloom_bits, block_cache=cache),
                         depth_budget=depth_budget)
    return ShardedPathStore(
        n_shards=n_shards,
        engine_factory=durable_engine_factory(
            root, memtable_limit=memtable_limit, sync=sync,
            level_ratio=level_ratio, bloom_bits=bloom_bits,
            block_cache=cache),
        depth_budget=depth_budget)
