"""Write-ahead log: length-prefixed, CRC-checksummed append records with
group commit at planner-wave boundaries.

Record framing (little-endian)::

    record := crc32(body) u32 | len(body) u32 | body
    body   := kind u8 | payload

Kinds::

    PUT     key_len u32 | key | value          one engine upsert
    DEL     key                                one engine tombstone
    INV     path (utf-8)                       invalidation-bus publish journal
    DEVMARK epoch u64                          device tier applied through epoch
    COMMIT  epoch u64                          group-commit marker

Appends buffer in memory; ``commit(epoch)`` writes the whole buffered
batch plus one COMMIT marker in a single OS write and then flushes (and
fsyncs, unless ``sync="none"``).  Because planner waves call commit
exactly once — at ``QueryEngine.refresh()`` — WAL batch boundaries align
with epoch boundaries: a crash loses at most the uncommitted wave, never
part of one.

``replay()`` walks the log, verifying every CRC; records past the last
valid COMMIT (an uncommitted wave, a torn write, or a corrupt tail) are
reported via ``valid_end`` so the recovering engine can truncate them.

Note: the WAL deliberately knows nothing about compaction levels — a
record's level placement is decided at spill/merge time and recorded in
the manifest, so the COMMIT framing needed no change for the leveled
tier (replay always lands records in the memtable, i.e. above level 0).
"""
from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass

from .. import obs
from . import failpoints as FP

PUT = 1
DEL = 2
INV = 3
DEVMARK = 4
COMMIT = 5

_HDR = struct.Struct("<II")      # crc32(body), len(body)
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")

#: ``REPRO_WAL_SYNC`` values: "fsync" (default — durable against power
#: loss), "none" (flush to the OS only; the CI knob for stable timings)
SYNC_ENV = "REPRO_WAL_SYNC"


def sync_mode(explicit: str | None = None) -> str:
    mode = explicit if explicit is not None else os.environ.get(SYNC_ENV, "fsync")
    if mode not in ("fsync", "none"):
        raise ValueError(f"unknown WAL sync mode {mode!r} (want 'fsync' or 'none')")
    return mode


def fsync_dir(dirname: str) -> None:
    """fsync the directory entry itself — a rename or newly created file
    is only power-loss durable once its directory metadata is on disk."""
    fd = os.open(dirname, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _frame(body: bytes) -> bytes:
    return _HDR.pack(zlib.crc32(body) & 0xFFFFFFFF, len(body)) + body


@dataclass(frozen=True)
class WALRecord:
    kind: int
    key: bytes = b""
    value: bytes = b""
    epoch: int = 0

    @property
    def path(self) -> str:
        """INV payload decoded (paths are utf-8 by construction)."""
        return self.key.decode("utf-8")


class WAL:
    """Append side of the log.  Thread safety is the caller's (DurableKV
    serializes all mutations under its own lock)."""

    def __init__(self, path: str, sync: str | None = None):
        self.path = path
        self.sync = sync_mode(sync)
        self._buf = bytearray()
        self._f = open(path, "ab")

    # -- buffered appends (group-committed) ---------------------------------
    def append_put(self, key: bytes, value: bytes) -> None:
        """Buffer one upsert record (durable at the next ``commit``)."""
        FP.hit("wal.append")
        self._buf += _frame(bytes([PUT]) + _U32.pack(len(key)) + key + value)

    def append_delete(self, key: bytes) -> None:
        """Buffer one tombstone record for ``key``."""
        FP.hit("wal.append")
        self._buf += _frame(bytes([DEL]) + key)

    def append_inval(self, path: str) -> None:
        """Buffer one invalidation-bus publish (device rehydration journal)."""
        FP.hit("wal.append")
        self._buf += _frame(bytes([INV]) + path.encode("utf-8"))

    def append_devmark(self, epoch: int) -> None:
        """Buffer a DEVMARK: device tier has applied through ``epoch``."""
        FP.hit("wal.append")
        self._buf += _frame(bytes([DEVMARK]) + _U64.pack(epoch))

    def pending_bytes(self) -> int:
        """Bytes buffered since the last ``commit`` (0 ⇒ wave is clean)."""
        return len(self._buf)

    # -- group commit -------------------------------------------------------
    def seal(self, epoch: int) -> bytes:
        """Freeze the buffered wave + its COMMIT marker into one byte
        string and clear the buffer — the synchronous half of a commit.
        The caller owns writing the sealed bytes (``write_sealed``);
        until it does, the wave is neither durable nor lost: appends for
        the *next* wave can start buffering immediately, which is what
        lets a pipelined commit overlap wave e's fsync with wave e+1's
        compute."""
        self._buf += _frame(bytes([COMMIT]) + _U64.pack(epoch))
        sealed = bytes(self._buf)
        self._buf.clear()
        return sealed

    def write_sealed(self, sealed: bytes, epoch: int) -> None:
        """One OS write for a sealed wave, then flush (+fsync) — the
        (possibly off-thread) durability half.  The commit marker inside
        ``sealed`` is what makes the wave real: replay drops everything
        after the last valid COMMIT."""
        with obs.span("wal.commit", epoch=epoch, bytes=len(sealed)):
            FP.write("wal.commit", self._f, sealed)
            self._f.flush()
            if self.sync == "fsync":
                with obs.span("wal.fsync"):
                    FP.hit("wal.fsync")
                    os.fsync(self._f.fileno())

    def commit(self, epoch: int) -> None:
        """Synchronous group commit: seal + write + flush (+fsync)."""
        self.write_sealed(self.seal(epoch), epoch)

    def truncate(self) -> None:
        """Truncate the log *file*, preserving any buffered-but-unsealed
        appends (called after a memtable spill: every committed record
        now lives in a segment; the manifest swap made that real.  Under
        a pipelined commit the spill runs off-thread while the next wave
        is already buffering — those records must survive)."""
        self._f.close()
        self._f = open(self.path, "wb")
        self._f.flush()
        if self.sync == "fsync":
            os.fsync(self._f.fileno())

    def reset(self) -> None:
        """Truncate the log and drop the buffer (full reset)."""
        self._buf.clear()
        self.truncate()

    def close(self) -> None:
        """Release the file handle (buffered, uncommitted records drop —
        exactly the crash semantics a real crash would have)."""
        self._f.close()


def _parse_body(body: bytes) -> WALRecord:
    kind = body[0]
    payload = body[1:]
    if kind == PUT:
        (klen,) = _U32.unpack_from(payload)
        key = payload[4:4 + klen]
        return WALRecord(PUT, key=key, value=payload[4 + klen:])
    if kind == DEL:
        return WALRecord(DEL, key=payload)
    if kind == INV:
        return WALRecord(INV, key=payload)
    if kind in (DEVMARK, COMMIT):
        (epoch,) = _U64.unpack_from(payload)
        return WALRecord(kind, epoch=epoch)
    raise ValueError(f"unknown WAL record kind {kind}")


@dataclass
class ReplayResult:
    """Outcome of a WAL scan: committed waves only.

    ``valid_end`` is the byte offset just past the last valid COMMIT —
    the recovering engine truncates the file there, dropping both torn
    tails (CRC/length mismatch) and uncommitted waves.
    """

    waves: list[list[WALRecord]]
    valid_end: int
    dropped_records: int   # records read but past the last commit
    corrupt_tail: bool     # CRC mismatch / torn frame detected


def replay(path: str) -> ReplayResult:
    """Scan the log at ``path`` and return its committed waves (see
    :class:`ReplayResult`); a missing file replays as empty."""
    waves: list[list[WALRecord]] = []
    current: list[WALRecord] = []
    valid_end = 0
    dropped = 0
    corrupt = False
    if not os.path.exists(path):
        return ReplayResult(waves, 0, 0, False)
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off + _HDR.size <= len(data):
        crc, blen = _HDR.unpack_from(data, off)
        body = data[off + _HDR.size: off + _HDR.size + blen]
        # blen == 0 passes the CRC check (crc32(b"") == 0) but no valid
        # record is empty — a zero-filled torn page, treat as corrupt
        if blen == 0 or len(body) < blen or (zlib.crc32(body) & 0xFFFFFFFF) != crc:
            corrupt = True
            break
        try:
            rec = _parse_body(body)
        except (ValueError, IndexError, struct.error):
            corrupt = True
            break
        off += _HDR.size + blen
        if rec.kind == COMMIT:
            current.append(rec)
            waves.append(current)
            current = []
            valid_end = off
        else:
            current.append(rec)
    # a partial header at EOF is a normal torn tail, not corruption
    if off + _HDR.size > len(data) and off < len(data):
        corrupt = True
    dropped = len(current)
    return ReplayResult(waves, valid_end, dropped, corrupt)


