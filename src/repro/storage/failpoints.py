"""Deterministic failpoint injection for crash-testing the durable tier.

The WAL, SSTable writer, and manifest route their durability-critical
IO through this module.  When no plan is armed (the default) every hook
is a cheap no-op, so production code pays one ``is None`` check per
faultable operation.  When a :class:`FailPlan` is armed, the N-th
operation whose site matches the plan raises :class:`InjectedCrash` —
either *before* any bytes reach the file (``mode="fail"``) or after a
torn prefix has been written and flushed (``mode="torn"``), simulating
a power cut mid-write.

Two arming paths:

* in-process tests use the :func:`armed` context manager;
* subprocess crash tests set ``REPRO_FAILPOINT="N[:mode[:site,site]]"``
  in the child environment — the plan is armed at import time, so the
  child dies with a nonzero exit the moment the N-th matching op runs.

Sites currently wired (see wal.py / sstable.py / manifest.py):

    ==================  =====================================================
    site                faultable operation
    ==================  =====================================================
    wal.append          a record is staged into the group-commit buffer
    wal.commit          the buffered wave (incl. COMMIT frame) hits the file
    wal.fsync           the WAL file fsync after a group commit
    segment.write       an SSTable body is written (single large write)
    segment.fsync       the segment-file fsync after the body write
    manifest.write      the manifest JSON is written to its tmp file
    manifest.fsync      the tmp-file fsync before the atomic rename
    manifest.replace    the atomic ``os.replace`` that publishes the manifest
    ==================  =====================================================

Counting is global across sites unless the plan restricts ``sites``:
the plan's counter increments once per *matching* faultable op, and the
op whose count equals ``crash_at`` dies.  ``crash_at <= 0`` never
fires, which turns the plan into a pure op counter (``plan.hits``) —
the fuzz harness uses that to learn a schedule's length before picking
a crash point.
"""
from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field

ENV = "REPRO_FAILPOINT"

SITES = (
    "wal.append", "wal.commit", "wal.fsync",
    "segment.write", "segment.fsync",
    "manifest.write", "manifest.fsync", "manifest.replace",
)


class InjectedCrash(RuntimeError):
    """Raised by an armed failpoint; simulates the process dying here."""

    def __init__(self, site: str, op_index: int):
        super().__init__(f"injected crash at {site} (op #{op_index})")
        self.site = site
        self.op_index = op_index


@dataclass
class FailPlan:
    """One deterministic crash schedule.

    ``crash_at`` is 1-based over matching ops; ``mode`` is ``"fail"``
    (die before any bytes are written) or ``"torn"`` (write
    ``int(len * torn_keep)`` bytes, capped at ``len - 1`` so the write
    is never accidentally complete, flush, then die).  ``sites=None``
    matches every site.
    """

    crash_at: int
    mode: str = "fail"
    sites: frozenset[str] | None = None
    torn_keep: float = 0.5
    seen: int = 0
    fired: bool = False
    hits: list[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.mode not in ("fail", "torn"):
            raise ValueError(f"unknown failpoint mode: {self.mode!r}")
        unknown = set(self.sites or ()) - set(SITES)
        if unknown:
            raise ValueError(f"unknown failpoint sites: {sorted(unknown)}")

    def _matches(self, site: str) -> bool:
        return self.sites is None or site in self.sites


_ACTIVE: FailPlan | None = None

# Faultable ops may run on worker threads (shard executor, commit
# sequencer, background compaction) with one plan armed process-wide:
# the counter mutation must be atomic or two concurrent ops could both
# claim the crash_at slot (double fire) or skip it entirely.
_PLAN_LOCK = threading.Lock()


def arm(plan: FailPlan) -> None:
    global _ACTIVE
    _ACTIVE = plan


def disarm() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> FailPlan | None:
    return _ACTIVE


class armed:
    """``with failpoints.armed(plan): ...`` — arms for the block only."""

    def __init__(self, plan: FailPlan):
        self.plan = plan

    def __enter__(self) -> FailPlan:
        arm(self.plan)
        return self.plan

    def __exit__(self, *exc) -> None:
        disarm()


def _tick(plan: FailPlan, site: str) -> tuple[bool, int]:
    """Atomically count one matching op; → (should_crash, op_index)."""
    with _PLAN_LOCK:
        if plan.fired or not plan._matches(site):
            return False, 0
        plan.seen += 1
        plan.hits.append(site)
        if plan.seen == plan.crash_at:
            plan.fired = True
            return True, plan.seen
        return False, plan.seen


def hit(site: str) -> None:
    """A faultable op with no payload (fsync, rename): maybe die here."""
    plan = _ACTIVE
    if plan is None:
        return
    crash, idx = _tick(plan, site)
    if crash:
        raise InjectedCrash(site, idx)


def write(site: str, f, data: bytes) -> None:
    """A faultable write: either completes, dies clean, or dies torn.

    In torn mode the prefix is flushed before raising so the partial
    bytes are durable from the recovering process's point of view —
    the worst case a real power cut can leave behind.
    """
    plan = _ACTIVE
    if plan is None:
        f.write(data)
        return
    crash, idx = _tick(plan, site)
    if not crash:
        f.write(data)
        return
    if plan.mode == "torn" and data:
        keep = min(len(data) - 1, max(0, int(len(data) * plan.torn_keep)))
        f.write(data[:keep])
        f.flush()
    raise InjectedCrash(site, idx)


def plan_from_env(env: str | None = None) -> FailPlan | None:
    """Parse ``REPRO_FAILPOINT="N[:mode[:site,site]]"`` into a plan."""
    raw = os.environ.get(ENV) if env is None else env
    if not raw:
        return None
    parts = raw.split(":")
    crash_at = int(parts[0])
    mode = parts[1] if len(parts) > 1 and parts[1] else "fail"
    sites = None
    if len(parts) > 2 and parts[2]:
        sites = frozenset(s.strip() for s in parts[2].split(",") if s.strip())
    return FailPlan(crash_at=crash_at, mode=mode, sites=sites)


_env_plan = plan_from_env()
if _env_plan is not None:       # pragma: no cover - subprocess-only path
    arm(_env_plan)
