"""Durable storage tier (ISSUE 3): WAL + SSTable segments + manifest
behind the ``KVEngine`` protocol, with crash recovery and the epoch /
invalidation journal the device tier rehydrates from.  See
docs/STORAGE.md for the on-disk layout and recovery protocol."""
from .lsm import DurableKV, durable_engine_factory, open_durable_store
from .sstable import SSTable, write_sstable
from .wal import WAL, replay

__all__ = ["DurableKV", "durable_engine_factory", "open_durable_store",
           "SSTable", "write_sstable", "WAL", "replay"]
