"""Durable storage tier: WAL + leveled SSTable segments + manifest
behind the ``KVEngine`` protocol, with per-segment bloom filters, a
shared block cache, crash recovery, and the epoch / invalidation journal
the device tier rehydrates from.  See docs/STORAGE.md for the on-disk
layout, compaction state machine, and recovery protocol; docs/ARCHITECTURE.md
places this tier in the full system."""
from .lsm import (DurableKV, default_block_cache, durable_engine_factory,
                  open_durable_store)
from .sstable import (BlockCache, BloomFilter, SegmentStats, SSTable,
                      write_sstable)
from .wal import WAL, replay

__all__ = ["DurableKV", "durable_engine_factory", "open_durable_store",
           "default_block_cache", "BlockCache", "BloomFilter",
           "SegmentStats", "SSTable", "write_sstable", "WAL", "replay"]
