"""On-disk sorted segment files (SSTables): sparse index, per-segment
bloom filter, and an optional shared block cache.

A segment is a frozen sorted run spilled from the memtable (or merged by
compaction), tombstones included — a delete must shadow older levels
until a merge proves nothing older remains.

Layout, format v2 (little-endian; documented byte-for-byte in
docs/STORAGE.md and asserted against real files by
``tests/test_storage.py::test_segment_footer_matches_documented_layout``)::

    magic  b"WSEG1\\n"
    data   N records: key_len u32 | val_len u32 | key | value
           (val_len == 0xFFFFFFFF encodes a tombstone; no value bytes)
    index  every SPARSE_EVERY-th record: key_len u32 | key | offset u64
    bloom  ceil(bloom_nbits / 8) raw filter bytes
    footer index_off u64 | bloom_off u64 | n_index u32 | n_records u32
           | bloom_k u32 | bloom_nbits u64 | magic b"WEND2\\n"

Format v1 (PR 3) is the same without the bloom section and with the
short footer ``index_off u64 | n_index u32 | n_records u32 | b"WEND1\\n"``.
``SSTable`` reads both: the trailing magic selects the footer shape, and
a v1 segment simply has ``bloom is None`` (every probe must touch it).
``write_sstable(..., bloom_bits_per_key=0)`` still emits v1 bytes — that
is the compatibility writer the migration tests use.

Reads mmap the file: ``get`` is a bisect over the sparse index plus a
short forward scan (≤ SPARSE_EVERY records) — the LevelDB read shape.
With a ``BlockCache`` attached, the index block covering the key is
parsed once and served from memory afterwards (hot paths skip the mmap
entirely).  ``scan`` seeks to the index block covering the prefix and
walks records in key order, yielding tombstones for the merge layer to
resolve; scans never populate the cache (no pollution from range reads).
"""
from __future__ import annotations

import bisect
import hashlib
import itertools
import mmap
import os
import struct
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Iterator, Optional

from . import failpoints as FP

#: process-unique SSTable open ids — the generation component of block
#: cache keys (see :class:`BlockCache`)
_OPEN_IDS = itertools.count(1)

MAGIC = b"WSEG1\n"
END_MAGIC_V1 = b"WEND1\n"
END_MAGIC = b"WEND2\n"
SPARSE_EVERY = 16
_TOMB_LEN = 0xFFFFFFFF

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_KV = struct.Struct("<II")
_FOOTER_V1 = struct.Struct("<QII")    # index_off, n_index, n_records
#: v2 footer: index_off, bloom_off, n_index, n_records, bloom_k, bloom_nbits
_FOOTER = struct.Struct("<QQIIIQ")

#: sentinel for an on-disk delete; distinct from "key absent" (None is
#: never returned by segment lookups — absence is reported as MISSING)
TOMBSTONE = object()
MISSING = object()


# ---------------------------------------------------------------------------
# bloom filter
# ---------------------------------------------------------------------------
def bloom_hash_pair(key: bytes) -> tuple[int, int]:
    """Two independent 64-bit hashes of ``key`` for double hashing.

    Probe ``i`` lands at ``(h1 + i*h2) % nbits`` — the standard
    Kirsch–Mitzenmacher construction, so one digest serves every probe of
    every segment's filter (``DurableKV.get`` hashes the key once per
    lookup, not once per segment)."""
    d = hashlib.blake2b(key, digest_size=16).digest()
    h1 = int.from_bytes(d[:8], "little")
    h2 = int.from_bytes(d[8:], "little") | 1      # odd: full-period stride
    return h1, h2


class BloomFilter:
    """k-hash bloom filter over a segment's keys (tombstone keys too —
    a deleted key must still be *findable* so its tombstone can shadow
    older levels).

    Args: ``nbits`` filter width in bits, ``k`` probes per key, ``bits``
    the backing ``bytearray``/``bytes`` of ``ceil(nbits/8)`` bytes."""

    __slots__ = ("nbits", "k", "bits")

    def __init__(self, nbits: int, k: int, bits: bytes | bytearray):
        self.nbits = nbits
        self.k = k
        self.bits = bits

    @classmethod
    def build(cls, keys, bits_per_key: int) -> "BloomFilter":
        """Size a filter for ``keys`` at ``bits_per_key`` and populate it.
        ``k`` follows the optimum ``bits_per_key · ln 2`` (≈0.7/bit)."""
        n = max(1, len(keys))
        nbits = max(64, n * bits_per_key)
        k = max(1, min(30, round(bits_per_key * 0.69)))
        bits = bytearray((nbits + 7) // 8)
        for key in keys:
            h1, h2 = bloom_hash_pair(key)
            for i in range(k):
                pos = (h1 + i * h2) % nbits
                bits[pos >> 3] |= 1 << (pos & 7)
        return cls(nbits, k, bits)

    def may_contain_hashes(self, h1: int, h2: int) -> bool:
        """Membership test from a precomputed :func:`bloom_hash_pair`."""
        nbits, bits = self.nbits, self.bits
        for i in range(self.k):
            pos = (h1 + i * h2) % nbits
            if not bits[pos >> 3] & (1 << (pos & 7)):
                return False
        return True

    def may_contain(self, key: bytes) -> bool:
        """Membership test (no false negatives; FPR ≈ 0.6^(k) at the
        designed load — property-tested in tests/test_storage.py)."""
        return self.may_contain_hashes(*bloom_hash_pair(key))


# ---------------------------------------------------------------------------
# block cache
# ---------------------------------------------------------------------------
class BlockCache:
    """Shared LRU cache of parsed index blocks, bounded by a byte budget.

    One instance is shared across every shard of a ``ShardedPathStore``
    (``open_durable_store`` creates it), so the budget is global: hot
    shards can use more than their share.  Keys are
    ``(segment_path, file_id, block_index)`` where ``file_id`` is a
    process-unique id minted per SSTable open.  ``Manifest.next_seg``
    keeps names unique *within* one manifest lineage, but a cache can
    outlive a lineage (a store directory recreated after a crash test,
    or a restore-from-backup, re-allocates ``seg_000001.seg`` at the
    same path) — and inode numbers can be recycled by the filesystem,
    so neither path nor inode distinguishes segment generations.  The
    open id does: a stale parsed block can never be served for a
    replacement file.
    Entries are dropped eagerly on segment close and age out via LRU
    otherwise.  Thread-safe (its own lock: per-shard ``DurableKV`` locks
    do not protect cross-shard sharing).
    """

    def __init__(self, capacity_bytes: int = 8 << 20):
        self.capacity = capacity_bytes
        self._lock = threading.Lock()
        self._d: "OrderedDict[tuple[str, int, int], tuple[list, int]]" = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple[str, int, int]):
        """→ cached parsed block (list of ``(key, value)``), or None."""
        with self._lock:
            ent = self._d.get(key)
            if ent is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return ent[0]

    def put(self, key: tuple[str, int, int], block: list, nbytes: int) -> None:
        """Insert a parsed block charged at ``nbytes``; evicts LRU entries
        until the budget holds.  A block larger than the whole budget is
        simply not cached."""
        if nbytes > self.capacity:
            return
        with self._lock:
            old = self._d.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
            self._d[key] = (block, nbytes)
            self._bytes += nbytes
            while self._bytes > self.capacity and self._d:
                _, (_, evicted) = self._d.popitem(last=False)
                self._bytes -= evicted

    def drop_segment(self, path: str) -> int:
        """Evict every block of one segment (called when a compaction
        deletes its file); returns the number of entries dropped."""
        with self._lock:
            stale = [k for k in self._d if k[0] == path]
            for k in stale:
                self._bytes -= self._d.pop(k)[1]
            return len(stale)

    def used_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._d)


# ---------------------------------------------------------------------------
# write side
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SegmentStats:
    """What ``write_sstable`` measured while writing — the manifest
    summary for the new segment (level is assigned by the caller)."""

    n_records: int
    file_bytes: int
    min_key: bytes
    max_key: bytes
    bloom_k: int
    bloom_nbits: int


def write_sstable(path: str, items: list[tuple[bytes, object]],
                  sync: bool = True,
                  bloom_bits_per_key: int = 10) -> SegmentStats:
    """Write sorted ``(key, value | TOMBSTONE)`` items as one segment.

    Args: ``path`` target file, ``items`` sorted unique-key pairs,
    ``sync`` fsync file + directory entry, ``bloom_bits_per_key`` filter
    budget (0 → no filter, v1/PR-3 byte layout).  Returns the
    :class:`SegmentStats` the caller records in the manifest.

    Writes to ``path`` directly; the caller makes the segment *live* only
    via the manifest swap, so a torn segment file is unreachable garbage,
    never corruption.
    """
    buf = bytearray(MAGIC)
    index: list[tuple[bytes, int]] = []
    for i, (key, value) in enumerate(items):
        if i % SPARSE_EVERY == 0:
            index.append((key, len(buf)))
        if value is TOMBSTONE:
            buf += _KV.pack(len(key), _TOMB_LEN) + key
        else:
            buf += _KV.pack(len(key), len(value)) + key + value
    index_off = len(buf)
    for key, off in index:
        buf += _U32.pack(len(key)) + key + _U64.pack(off)
    if bloom_bits_per_key > 0:
        bloom = BloomFilter.build([k for k, _ in items], bloom_bits_per_key)
        bloom_off = len(buf)
        buf += bytes(bloom.bits)
        buf += _FOOTER.pack(index_off, bloom_off, len(index), len(items),
                            bloom.k, bloom.nbits) + END_MAGIC
        bloom_k, bloom_nbits = bloom.k, bloom.nbits
    else:
        buf += _FOOTER_V1.pack(index_off, len(index), len(items)) + END_MAGIC_V1
        bloom_k = bloom_nbits = 0
    with open(path, "wb") as f:
        FP.write("segment.write", f, bytes(buf))
        f.flush()
        if sync:
            FP.hit("segment.fsync")
            os.fsync(f.fileno())
    if sync:
        # the new file's directory entry must hit disk before the
        # manifest swap advertises it
        from .wal import fsync_dir
        fsync_dir(os.path.dirname(path) or ".")
    return SegmentStats(
        n_records=len(items), file_bytes=len(buf),
        min_key=items[0][0] if items else b"",
        max_key=items[-1][0] if items else b"",
        bloom_k=bloom_k, bloom_nbits=bloom_nbits)


# ---------------------------------------------------------------------------
# read side
# ---------------------------------------------------------------------------
class SSTable:
    """Read side of one immutable segment file (v1 or v2 layout).

    Args: ``path`` segment file; ``cache`` an optional shared
    :class:`BlockCache` (point gets parse whole index blocks through it);
    ``stat`` an optional ``Callable[[str], None]`` counter hook the
    owning engine uses for per-engine ``cache_hit``/``cache_miss``
    accounting (the cache itself keeps only global totals).
    """

    def __init__(self, path: str, cache: "BlockCache | None" = None,
                 stat: Optional[Callable[[str], None]] = None):
        self.path = path
        self._cache = cache
        self._stat = stat
        self._f = open(path, "rb")
        # per-open cache identity: a recreated file at the same path (a
        # new store generation) must never hit the old file's blocks,
        # and inode numbers can be recycled — a process-unique open id
        # cannot collide within the (in-process) cache's lifetime
        self._file_id = next(_OPEN_IDS)
        try:
            self._mm = mmap.mmap(self._f.fileno(), 0, access=mmap.ACCESS_READ)
        except ValueError:          # zero-length file cannot be mmapped
            self._f.close()
            raise CorruptSegment(f"empty segment file {path!r}")
        mm = self._mm
        tail = bytes(mm[-len(END_MAGIC):]) if len(mm) >= len(END_MAGIC) else b""
        self.bloom: BloomFilter | None = None
        if tail == END_MAGIC:
            foot_at = len(mm) - _FOOTER.size - len(END_MAGIC)
            if foot_at < len(MAGIC) or mm[:len(MAGIC)] != MAGIC:
                self.close()
                raise CorruptSegment(f"bad segment framing in {path!r}")
            (self._index_off, bloom_off, n_index, self.n_records,
             bloom_k, bloom_nbits) = _FOOTER.unpack_from(mm, foot_at)
            if bloom_nbits:
                bits = bytes(mm[bloom_off:bloom_off + (bloom_nbits + 7) // 8])
                self.bloom = BloomFilter(bloom_nbits, bloom_k, bits)
        elif tail == END_MAGIC_V1:
            foot_at = len(mm) - _FOOTER_V1.size - len(END_MAGIC_V1)
            if foot_at < len(MAGIC) or mm[:len(MAGIC)] != MAGIC:
                self.close()
                raise CorruptSegment(f"bad segment framing in {path!r}")
            self._index_off, n_index, self.n_records = \
                _FOOTER_V1.unpack_from(mm, foot_at)
        else:
            self.close()
            raise CorruptSegment(f"bad segment framing in {path!r}")
        self._idx_keys: list[bytes] = []
        self._idx_offs: list[int] = []
        off = self._index_off
        for _ in range(n_index):
            (klen,) = _U32.unpack_from(mm, off)
            off += 4
            self._idx_keys.append(bytes(mm[off:off + klen]))
            off += klen
            (doff,) = _U64.unpack_from(mm, off)
            off += 8
            self._idx_offs.append(doff)

    # ------------------------------------------------------------------
    def _read_record(self, off: int) -> tuple[bytes, object, int]:
        klen, vlen = _KV.unpack_from(self._mm, off)
        off += _KV.size
        key = bytes(self._mm[off:off + klen])
        off += klen
        if vlen == _TOMB_LEN:
            return key, TOMBSTONE, off
        return key, bytes(self._mm[off:off + vlen]), off + vlen

    def _block_bounds(self, block: int) -> tuple[int, int]:
        end = (self._idx_offs[block + 1] if block + 1 < len(self._idx_offs)
               else self._index_off)
        return self._idx_offs[block], end

    def _load_block(self, block: int) -> list[tuple[bytes, object]]:
        """Parse (or fetch from the cache) one index block — the ≤
        SPARSE_EVERY records between two sparse-index entries."""
        ck = (self.path, self._file_id, block)
        cached = self._cache.get(ck)        # type: ignore[union-attr]
        if cached is not None:
            if self._stat:
                self._stat("cache_hit")
            return cached
        if self._stat:
            self._stat("cache_miss")
        off, end = self._block_bounds(block)
        entries: list[tuple[bytes, object]] = []
        nbytes = 64
        while off < end:
            k, v, off = self._read_record(off)
            entries.append((k, v))
            nbytes += len(k) + (len(v) if isinstance(v, bytes) else 0) + 48
        self._cache.put(ck, entries, nbytes)  # type: ignore[union-attr]
        return entries

    def get(self, key: bytes) -> object:
        """Point lookup → value bytes, TOMBSTONE, or MISSING.

        O(log n_index) bisect + one block: a cached parsed block when a
        ``BlockCache`` is attached, else a ≤ SPARSE_EVERY-record forward
        scan off the mmap."""
        if not self._idx_keys or key < self._idx_keys[0]:
            return MISSING
        block = bisect.bisect_right(self._idx_keys, key) - 1
        if self._cache is not None:
            for k, v in self._load_block(block):
                if k == key:
                    return v
                if k > key:
                    break
            return MISSING
        off, end = self._block_bounds(block)
        while off < end:
            k, v, off = self._read_record(off)
            if k == key:
                return v
            if k > key:
                break
        return MISSING

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, object]]:
        """Yield (key, value | TOMBSTONE) for keys with ``prefix``, in key
        order.  Tombstones are yielded — shadowing is the merge layer's
        job, not the segment's.  Never touches the block cache."""
        if self._idx_keys:
            block = max(0, bisect.bisect_right(self._idx_keys, prefix) - 1)
            off = self._idx_offs[block]
        else:
            off = len(MAGIC)
        while off < self._index_off:
            k, v, off = self._read_record(off)
            if k.startswith(prefix):
                yield k, v
            elif k > prefix:
                return

    def iter_all(self) -> Iterator[tuple[bytes, object]]:
        """Yield every record oldest-file-order (compaction's merge input)."""
        off = len(MAGIC)
        while off < self._index_off:
            k, v, off = self._read_record(off)
            yield k, v

    def close(self) -> None:
        """Release the mmap/file handle and evict this segment's cached
        blocks (safe to call twice)."""
        if getattr(self, "_mm", None) is not None:
            self._mm.close()
            self._mm = None
        self._f.close()
        if self._cache is not None:
            self._cache.drop_segment(self.path)


class CorruptSegment(RuntimeError):
    """Segment framing/footer validation failed (torn or foreign file)."""
