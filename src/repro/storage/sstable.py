"""On-disk sorted segment files (SSTables) with a sparse in-memory index.

A segment is MemKV's frozen run spilled to disk: the whole memtable,
sorted by key, tombstones included (a delete must shadow older segments
until a full compaction proves nothing older remains).

Layout (little-endian)::

    magic  b"WSEG1\\n"
    data   N records: key_len u32 | val_len u32 | key | value
           (val_len == 0xFFFFFFFF encodes a tombstone; no value bytes)
    index  every SPARSE_EVERY-th record: key_len u32 | key | offset u64
    footer index_off u64 | n_index u32 | n_records u32 | magic b"WEND1\\n"

Reads mmap the file: ``get`` is a bisect over the sparse index plus a
short forward scan (≤ SPARSE_EVERY records) — the LevelDB read shape.
``scan`` seeks to the index block covering the prefix and walks records
in key order, yielding tombstones for the merge layer to resolve.
"""
from __future__ import annotations

import bisect
import mmap
import os
import struct
from typing import Iterator

MAGIC = b"WSEG1\n"
END_MAGIC = b"WEND1\n"
SPARSE_EVERY = 16
_TOMB_LEN = 0xFFFFFFFF

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_KV = struct.Struct("<II")
_FOOTER = struct.Struct("<QII")   # index_off, n_index, n_records

#: sentinel for an on-disk delete; distinct from "key absent" (None is
#: never returned by segment lookups — absence is reported as MISSING)
TOMBSTONE = object()
MISSING = object()


def write_sstable(path: str, items: list[tuple[bytes, object]],
                  sync: bool = True) -> None:
    """Write sorted ``(key, value | TOMBSTONE)`` items as one segment.

    Writes to ``path`` directly; the caller makes the segment *live* only
    via the manifest swap, so a torn segment file is unreachable garbage,
    never corruption.
    """
    buf = bytearray(MAGIC)
    index: list[tuple[bytes, int]] = []
    for i, (key, value) in enumerate(items):
        if i % SPARSE_EVERY == 0:
            index.append((key, len(buf)))
        if value is TOMBSTONE:
            buf += _KV.pack(len(key), _TOMB_LEN) + key
        else:
            buf += _KV.pack(len(key), len(value)) + key + value
    index_off = len(buf)
    for key, off in index:
        buf += _U32.pack(len(key)) + key + _U64.pack(off)
    buf += _FOOTER.pack(index_off, len(index), len(items)) + END_MAGIC
    with open(path, "wb") as f:
        f.write(bytes(buf))
        f.flush()
        if sync:
            os.fsync(f.fileno())
    if sync:
        # the new file's directory entry must hit disk before the
        # manifest swap advertises it
        from .wal import fsync_dir
        fsync_dir(os.path.dirname(path) or ".")


class SSTable:
    """Read side of one immutable segment file."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "rb")
        try:
            self._mm = mmap.mmap(self._f.fileno(), 0, access=mmap.ACCESS_READ)
        except ValueError:          # zero-length file cannot be mmapped
            self._f.close()
            raise CorruptSegment(f"empty segment file {path!r}")
        mm = self._mm
        foot_at = len(mm) - _FOOTER.size - len(END_MAGIC)
        if (foot_at < len(MAGIC) or mm[:len(MAGIC)] != MAGIC
                or mm[-len(END_MAGIC):] != END_MAGIC):
            self.close()
            raise CorruptSegment(f"bad segment framing in {path!r}")
        self._index_off, n_index, self.n_records = _FOOTER.unpack_from(mm, foot_at)
        self._idx_keys: list[bytes] = []
        self._idx_offs: list[int] = []
        off = self._index_off
        for _ in range(n_index):
            (klen,) = _U32.unpack_from(mm, off)
            off += 4
            self._idx_keys.append(bytes(mm[off:off + klen]))
            off += klen
            (doff,) = _U64.unpack_from(mm, off)
            off += 8
            self._idx_offs.append(doff)

    # ------------------------------------------------------------------
    def _read_record(self, off: int) -> tuple[bytes, object, int]:
        klen, vlen = _KV.unpack_from(self._mm, off)
        off += _KV.size
        key = bytes(self._mm[off:off + klen])
        off += klen
        if vlen == _TOMB_LEN:
            return key, TOMBSTONE, off
        return key, bytes(self._mm[off:off + vlen]), off + vlen

    def get(self, key: bytes) -> object:
        """→ value bytes, TOMBSTONE, or MISSING."""
        if not self._idx_keys or key < self._idx_keys[0]:
            return MISSING
        block = bisect.bisect_right(self._idx_keys, key) - 1
        off = self._idx_offs[block]
        end = (self._idx_offs[block + 1] if block + 1 < len(self._idx_offs)
               else self._index_off)
        while off < end:
            k, v, off = self._read_record(off)
            if k == key:
                return v
            if k > key:
                break
        return MISSING

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, object]]:
        """Yield (key, value | TOMBSTONE) for keys with ``prefix``, in key
        order.  Tombstones are yielded — shadowing is the merge layer's
        job, not the segment's."""
        if self._idx_keys:
            block = max(0, bisect.bisect_right(self._idx_keys, prefix) - 1)
            off = self._idx_offs[block]
        else:
            off = len(MAGIC)
        while off < self._index_off:
            k, v, off = self._read_record(off)
            if k.startswith(prefix):
                yield k, v
            elif k > prefix:
                return

    def iter_all(self) -> Iterator[tuple[bytes, object]]:
        off = len(MAGIC)
        while off < self._index_off:
            k, v, off = self._read_record(off)
            yield k, v

    def close(self) -> None:
        if getattr(self, "_mm", None) is not None:
            self._mm.close()
            self._mm = None
        self._f.close()


class CorruptSegment(RuntimeError):
    """Segment framing/footer validation failed (torn or foreign file)."""
