"""Shared layers: norms, RoPE, GQA attention (+KV cache), gated MLP.

Everything is functional: params are plain dict pytrees, apply functions
are pure.  Initializers return (params, pspecs) pairs built in lockstep so
the sharding tree always matches the param tree (the dry-run lowers from
``jax.eval_shape`` over these initializers — no device allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels import ops
from .config import ModelConfig

# logical → mesh axes used by every pspec below:
#   "data"  : FSDP parameter shard axis (all-gather on use)
#   "model" : tensor-parallel axis
FSDP = "data"
TP = "model"


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, cfg: ModelConfig,
               shard: tuple | None = None, scale: float | None = None):
    """(d_in, d_out) matrix; default fan-in init."""
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(_dtype(cfg))
    spec = P(*shard) if shard is not None else P(None, None)
    return w, spec


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def norm_init(cfg: ModelConfig):
    if cfg.nonparam_ln:
        return {}, {}
    return ({"scale": jnp.ones((cfg.d_model,), _dtype(cfg))},
            {"scale": P(None)})


def norm_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.nonparam_ln:
        # OLMo non-parametric LN: center + normalize, no affine
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        return ((xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype)
    return ops.rmsnorm(x, params["scale"], eps=cfg.norm_eps)


def head_norm_apply(scale: jax.Array | None, x: jax.Array,
                    eps: float) -> jax.Array:
    """qk-norm: RMS over the head dim (last axis)."""
    return ops.rmsnorm(x, scale, eps=eps)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(cfg: ModelConfig) -> jax.Array:
    d = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    return inv  # (d/2,)


def apply_rope(x: jax.Array, positions: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """x: (..., S, D); positions: broadcastable to (..., S)."""
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention block (GQA + optional qk-norm) with KV-cache support
# ---------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    H, KV, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    params, specs = {}, {}
    params["wq"], specs["wq"] = dense_init(ks[0], D, H * Dh, cfg, (FSDP, TP))
    params["wk"], specs["wk"] = dense_init(ks[1], D, KV * Dh, cfg, (FSDP, TP))
    params["wv"], specs["wv"] = dense_init(ks[2], D, KV * Dh, cfg, (FSDP, TP))
    params["wo"], specs["wo"] = dense_init(ks[3], H * Dh, D, cfg, (TP, FSDP))
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((Dh,), _dtype(cfg))
        params["k_norm"] = jnp.ones((Dh,), _dtype(cfg))
        specs["q_norm"] = P(None)
        specs["k_norm"] = P(None)
    return params, specs


def _project_qkv(params: dict, x: jax.Array, positions: jax.Array,
                 cfg: ModelConfig):
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, KV, Dh)
    v = (x @ params["wv"]).reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = head_norm_apply(params["q_norm"], q, cfg.norm_eps)
        k = head_norm_apply(params["k_norm"], k, cfg.norm_eps)
    inv = rope_freqs(cfg)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions[:, None, :], inv)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None, :], inv)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v  # (B, H, S, Dh), (B, KV, S, Dh) x2


def attn_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
               causal: bool = True,
               positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence (training / prefill) attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    q, k, v = _project_qkv(params, x, positions, cfg)
    o = ops.attention(q, k, v, causal=causal)  # (B, H, S, Dh)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return o @ params["wo"]


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, KV, max_len, Dh), dtype),
        "v": jnp.zeros((batch, KV, max_len, Dh), dtype),
    }


def attn_decode(params: dict, x: jax.Array, cache: dict, lengths: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token decode: x (B, 1, D); cache k/v (B, KV, S, Dh); lengths (B,).
    Returns (B, 1, D) and the cache updated at position ``lengths``."""
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, lengths[:, None], cfg)
    # scatter the new kv at each row's write position
    b_idx = jnp.arange(B)
    k_cache = cache["k"].at[b_idx, :, lengths, :].set(
        k_new[:, :, 0, :].astype(cache["k"].dtype))
    v_cache = cache["v"].at[b_idx, :, lengths, :].set(
        v_new[:, :, 0, :].astype(cache["v"].dtype))
    o = ops.decode_attention(q[:, :, 0, :], k_cache, v_cache, lengths + 1)
    o = o.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return o @ params["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_attn_init(key, cfg: ModelConfig):
    return attn_init(key, cfg)


def cross_attn_apply(params: dict, x: jax.Array, enc_out: jax.Array,
                     cfg: ModelConfig) -> jax.Array:
    """x: (B, Sq, D) queries; enc_out: (B, Se, D) keys/values (no RoPE —
    whisper uses learned/sinusoidal positions folded into the stub)."""
    B, Sq, _ = x.shape
    Se = enc_out.shape[1]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, Sq, H, Dh).transpose(0, 2, 1, 3)
    k = (enc_out @ params["wk"]).reshape(B, Se, KV, Dh).transpose(0, 2, 1, 3)
    v = (enc_out @ params["wv"]).reshape(B, Se, KV, Dh).transpose(0, 2, 1, 3)
    o = ops.attention(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(B, Sq, H * Dh)
    return o @ params["wo"]


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    ks = jax.random.split(key, 3)
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    params, specs = {}, {}
    params["w_gate"], specs["w_gate"] = dense_init(ks[0], D, F, cfg, (FSDP, TP))
    params["w_up"], specs["w_up"] = dense_init(ks[1], D, F, cfg, (FSDP, TP))
    params["w_down"], specs["w_down"] = dense_init(ks[2], F, D, cfg, (TP, FSDP))
    return params, specs


def mlp_apply(params: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    u = (x @ params["w_up"]).astype(jnp.float32)
    return ((g * u).astype(x.dtype)) @ params["w_down"]
