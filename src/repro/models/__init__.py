from .config import ModelConfig, MoEConfig  # noqa: F401
from .model import (SHAPES, ShapeSpec, abstract_params, init_params,  # noqa: F401
                    input_specs, make_eval_step, make_prefill_step,
                    make_serve_step, make_train_step, model_flops,
                    param_shardings, spec_tree)
from . import transformer  # noqa: F401
