"""Selective SSM (Mamba) block — jamba's sub-quadratic layer.

Training/prefill uses the *parallel* form: the diagonal linear recurrence
  h_t = exp(Δ_t A) ⊙ h_{t−1} + Δ_t B_t x_t
is evaluated with ``jax.lax.associative_scan`` over time (Blelloch — the
TPU-idiomatic replacement for Mamba's CUDA selective-scan kernel; the
hardware-adaptation note in DESIGN.md §3 applies: a warp-parallel scan
becomes a log-depth associative scan XLA schedules across the VPU).

Decode carries O(1) state per layer: (conv window (d_conv−1, d_inner),
ssm state (d_inner, d_state)) — this is what makes jamba's ``long_500k``
cell runnable where full attention is not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import FSDP, TP, _dtype, dense_init


def ssm_init(key, cfg: ModelConfig):
    D = cfg.d_model
    Din = cfg.ssm_expand * D
    N = cfg.d_state
    ks = jax.random.split(key, 7)
    params, specs = {}, {}
    params["w_in"], specs["w_in"] = dense_init(ks[0], D, 2 * Din, cfg, (FSDP, TP))
    params["w_out"], specs["w_out"] = dense_init(ks[1], Din, D, cfg, (TP, FSDP))
    # depthwise causal conv over the inner channels
    params["conv_w"] = (jax.random.normal(ks[2], (cfg.d_conv, Din), jnp.float32)
                        / np.sqrt(cfg.d_conv)).astype(_dtype(cfg))
    specs["conv_w"] = P(None, TP)
    params["conv_b"] = jnp.zeros((Din,), _dtype(cfg))
    specs["conv_b"] = P(TP)
    # data-dependent Δ, B, C projections
    params["w_bc"], specs["w_bc"] = dense_init(ks[3], Din, 2 * N, cfg, (FSDP, None))
    params["w_dt"], specs["w_dt"] = dense_init(ks[4], Din, Din, cfg, (FSDP, TP),
                                               scale=0.01)
    params["dt_bias"] = jnp.asarray(
        np.log(np.expm1(np.linspace(1e-3, 1e-1, Din))), _dtype(cfg))
    specs["dt_bias"] = P(TP)
    # A: negative-real diagonal (S4D-real init), stored as log(−A)
    a = np.tile(np.arange(1, N + 1, dtype=np.float32)[None, :], (Din, 1))
    params["A_log"] = jnp.asarray(np.log(a), jnp.float32)
    specs["A_log"] = P(TP, None)
    params["D_skip"] = jnp.ones((Din,), jnp.float32)
    specs["D_skip"] = P(TP)
    return params, specs


def _ssm_core(u: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
              A_log: jax.Array, D_skip: jax.Array,
              h0: jax.Array | None = None):
    """u: (B, S, Din); dt: (B, S, Din); B/C: (B, S, N).
    Returns (y (B, S, Din), h_last (B, Din, N))."""
    A = -jnp.exp(A_log)                                   # (Din, N)
    dA = jnp.exp(dt[..., None] * A[None, None])           # (B, S, Din, N)
    dBx = (dt * u)[..., None] * B[:, :, None, :]          # (B, S, Din, N)
    if h0 is not None:
        # fold the carried state into step 0: h_0' = dA_0 h_{-1} + dBx_0
        dBx = dBx.at[:, 0].add(dA[:, 0] * h0)
    def combine(a, b):
        a1, a2 = a
        b1, b2 = b
        return (a1 * b1, a2 * b1 + b2)
    _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, C)
    y = y + u * D_skip[None, None]
    return y, hs[:, -1]


def ssm_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              conv_state: jax.Array | None = None,
              ssm_state: jax.Array | None = None,
              return_state: bool = False):
    """Full-sequence apply.  x: (B, S, D)."""
    Bsz, S, D = x.shape
    Din = cfg.ssm_expand * D
    N = cfg.d_state
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, S, Din) each
    # causal depthwise conv (width d_conv)
    pad = cfg.d_conv - 1
    if conv_state is not None:
        u_pad = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    else:
        u_pad = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
    windows = jnp.stack(
        [u_pad[:, i:i + S, :] for i in range(cfg.d_conv)], axis=2)
    u_conv = jnp.einsum("bskd,kd->bsd", windows, params["conv_w"]) + params["conv_b"]
    u_conv = jax.nn.silu(u_conv.astype(jnp.float32)).astype(x.dtype)
    # data-dependent SSM parameters
    bc = u_conv @ params["w_bc"]
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)      # (B, S, N)
    dt = jax.nn.softplus(
        (u_conv @ params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))                 # (B, S, Din)
    y, h_last = _ssm_core(u_conv.astype(jnp.float32), dt, Bm, Cm,
                          params["A_log"], params["D_skip"],
                          h0=ssm_state)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"]
    if return_state:
        new_conv = u_pad[:, -pad:, :] if pad > 0 else jnp.zeros(
            (Bsz, 0, Din), x.dtype)
        return out, (new_conv.astype(jnp.float32), h_last)
    return out


def ssm_state_init(cfg: ModelConfig, batch: int) -> tuple[jax.Array, jax.Array]:
    Din = cfg.ssm_expand * cfg.d_model
    return (jnp.zeros((batch, cfg.d_conv - 1, Din), jnp.float32),
            jnp.zeros((batch, Din, cfg.d_state), jnp.float32))


def ssm_decode(params: dict, x: jax.Array, state, cfg: ModelConfig):
    """One-token decode: x (B, 1, D); state = (conv (B, d_conv-1, Din),
    h (B, Din, N)).  O(1) compute/memory per step."""
    out, new_state = ssm_apply(params, x, cfg,
                               conv_state=state[0], ssm_state=state[1],
                               return_state=True)
    return out, new_state
