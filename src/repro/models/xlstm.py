"""xLSTM blocks (sLSTM + mLSTM) — arXiv:2405.04517, TPU-adapted.

* **mLSTM** (matrix memory, exponential gating): trained with the
  *parallel* quadratic form — a decay-masked attention-like product
  D_{ts} = exp(Σ_{r≤t} log f_r − Σ_{r≤s} log f_r + log i_s) for s ≤ t,
  row-stabilized like flash attention.  Decode is the O(1) recurrence on
  the (d_k × d_v) matrix state.  The paper's CUDA kernels become plain
  MXU matmuls over the (S × S) decay-masked scores — for the assigned
  350M config at train_4k this is the faithful quadratic-cost choice;
  the recurrent decode is what earns the ``long_500k`` cell.

* **sLSTM** (scalar memory, new-style gating with normalizer/stabilizer
  state): an inherently serial recurrence — evaluated with
  ``jax.lax.scan`` over time (compact HLO; noted as the latency-bound
  layer in the roofline analysis).  xlstm-350m places one sLSTM per
  8-layer period.

Blocks carry their own up/down projections (the assigned config's
``d_ff=0``): mLSTM uses a 2× pre-up-projection (qkv live in the expanded
space), sLSTM a post-block gated FFN of factor 4/3, per the paper's block
designs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import FSDP, TP, _dtype, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig):
    D = cfg.d_model
    Dp = 2 * D                      # paper: expansion 2 before qkv
    H = cfg.xlstm_heads
    ks = jax.random.split(key, 8)
    params, specs = {}, {}
    params["w_up"], specs["w_up"] = dense_init(ks[0], D, 2 * Dp, cfg, (FSDP, TP))
    params["w_q"], specs["w_q"] = dense_init(ks[1], Dp, Dp, cfg, (FSDP, TP))
    params["w_k"], specs["w_k"] = dense_init(ks[2], Dp, Dp, cfg, (FSDP, TP))
    params["w_v"], specs["w_v"] = dense_init(ks[3], Dp, Dp, cfg, (FSDP, TP))
    params["w_if"], specs["w_if"] = dense_init(ks[4], Dp, 2 * H, cfg, (FSDP, None),
                                               scale=0.02)
    params["if_bias"] = jnp.concatenate([
        jnp.zeros((H,), jnp.float32),                 # input gate bias
        jnp.linspace(3.0, 6.0, H).astype(jnp.float32)  # forget gate bias (high)
    ])
    specs["if_bias"] = P(None)
    params["w_down"], specs["w_down"] = dense_init(ks[5], Dp, D, cfg, (TP, FSDP))
    params["skip_scale"] = jnp.ones((Dp,), _dtype(cfg))
    specs["skip_scale"] = P(TP)
    return params, specs


def mlstm_chunkwise(q, k, v, log_i, log_f, state, chunk: int = 256):
    """Chunkwise-parallel stabilized mLSTM.

    q/k/v: (B, H, S, Dh); log_i/log_f: (B, H, S); state = (C, n, m) with
    C stored *stabilized* (C_true = C·e^m).  Quadratic work only within a
    chunk ((B,H,c,c) scores), linear recurrence across chunks — the
    memory shape that makes train_4k×256 shardable, and the same
    chunk-size trade the xLSTM TFLA kernels make on GPU.

    Returns (h (B, H, S, Dh), final state)."""
    B, H, S, Dh = q.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nchunk = S // c
    scale = 1.0 / np.sqrt(Dh)

    def resh(t, last=None):
        newshape = (B, H, nchunk, c) + ((last,) if last else ())
        return t.reshape(newshape)

    qc = resh(q, Dh) * scale
    kc = resh(k, Dh)
    vc = resh(v, Dh)
    lic = resh(log_i)
    lfc = resh(log_f)

    def chunk_step(carry, xs):
        C0, n0, m0 = carry                       # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qk, kk, vk, li, lf = xs                  # (B,H,c,·)
        F = jnp.cumsum(lf, axis=-1)              # (B,H,c)
        # intra-chunk log decay matrix w_ts = F_t − F_s + li_s, s ≤ t
        logD = F[..., :, None] - F[..., None, :] + li[..., None, :]
        causal = jnp.tril(jnp.ones((c, c), bool))
        logD = jnp.where(causal[None, None], logD, NEG_INF)
        m_intra = logD.max(axis=-1)              # (B,H,c)
        m_inter = m0[..., None] + F              # (B,H,c)
        m_t = jnp.maximum(m_intra, m_inter)      # matches the recurrence
        Dmat = jnp.exp(logD - m_t[..., None])    # (B,H,c,c)
        inter_w = jnp.exp(m_inter - m_t)         # (B,H,c)
        scores = qk @ kk.transpose(0, 1, 3, 2)   # (B,H,c,c)
        num = (scores * Dmat) @ vk \
            + inter_w[..., None] * jnp.einsum("bhcd,bhdv->bhcv", qk, C0)
        den_vec = (scores * Dmat).sum(axis=-1) \
            + inter_w * jnp.einsum("bhcd,bhd->bhc", qk, n0)
        den = jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_t))
        h = num / den[..., None]
        # carry update at chunk end (t = c-1 semantics of the recurrence)
        F_end = F[..., -1]
        m_new = jnp.maximum(m0 + F_end, (F_end[..., None] - F + li).max(-1))
        carry_w = jnp.exp(F_end[..., None] - F + li - m_new[..., None])  # (B,H,c)
        C1 = jnp.exp(m0 + F_end - m_new)[..., None, None] * C0 \
            + jnp.einsum("bhc,bhcd,bhcv->bhdv", carry_w, kk, vk)
        n1 = jnp.exp(m0 + F_end - m_new)[..., None] * n0 \
            + jnp.einsum("bhc,bhcd->bhd", carry_w, kk)
        return (C1, n1, m_new), h

    xs = (qc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
          vc.transpose(2, 0, 1, 3, 4), lic.transpose(2, 0, 1, 3),
          lfc.transpose(2, 0, 1, 3))
    final, hs = jax.lax.scan(chunk_step, state, xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, Dh)
    return h, final


def mlstm_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                state=None, return_state: bool = False):
    B, S, D = x.shape
    H = cfg.xlstm_heads
    up = x @ params["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)                 # (B, S, Dp)
    Dp = xin.shape[-1]
    Dh = Dp // H
    q = (xin @ params["w_q"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    k = (xin @ params["w_k"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    v = (xin @ params["w_v"]).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)
    gates = (xin @ params["w_if"]).astype(jnp.float32) + params["if_bias"]
    log_i = gates[..., :H].transpose(0, 2, 1)          # (B, H, S) — log-space
    log_f = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    if S == 1 and state is not None:
        h, h_last = _mlstm_recurrent(qf, kf, vf, log_i, log_f, state)
    else:
        st = state if state is not None else mlstm_state_init_raw(B, H, Dh)
        h, h_last = mlstm_chunkwise(qf, kf, vf, log_i, log_f, st,
                                    chunk=_pick_chunk(S))
    h = h.transpose(0, 2, 1, 3).reshape(B, S, Dp).astype(x.dtype)
    h = h + params["skip_scale"] * xin                 # learnable skip
    out = (h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) @ params["w_down"]
    if return_state:
        return out, h_last
    return out


def _mlstm_recurrent(q, k, v, log_i, log_f, state):
    """Step the matrix memory for S (usually 1) tokens.
    state = (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H))."""
    C0, n0, m0 = state
    Dh = q.shape[-1]

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt = q[:, :, t], k[:, :, t], v[:, :, t]   # (B, H, Dh)
        li, lf = log_i[:, :, t], log_f[:, :, t]
        m_new = jnp.maximum(lf + m, li)
        f_ = jnp.exp(lf + m - m_new)[..., None]
        i_ = jnp.exp(li - m_new)[..., None]
        kt_s = kt / np.sqrt(Dh)
        C = f_[..., None] * C + i_[..., None] * (kt_s[..., :, None] * vt[..., None, :])
        n = f_ * n + i_ * kt_s
        num = jnp.einsum("bhd,bhdv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0),
                                 jnp.arange(q.shape[2]))
    # hs: (S, B, H, Dh) → (B, H, S, Dh)
    return hs.transpose(1, 2, 0, 3), (C, n, m)


def _pick_chunk(S: int) -> int:
    for c in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if S % c == 0:
            return c
    return 1


def mlstm_state_init_raw(B, H, Dh):
    return (jnp.zeros((B, H, Dh, Dh), jnp.float32),
            jnp.zeros((B, H, Dh), jnp.float32),
            jnp.zeros((B, H), jnp.float32))


def mlstm_state_init(cfg: ModelConfig, batch: int):
    Dp = 2 * cfg.d_model
    Dh = Dp // cfg.xlstm_heads
    return mlstm_state_init_raw(batch, cfg.xlstm_heads, Dh)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig):
    D = cfg.d_model
    H = cfg.xlstm_heads
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    # fused input projection for (z, i, f, o) pre-activations
    params["w_x"], specs["w_x"] = dense_init(ks[0], D, 4 * D, cfg, (FSDP, TP))
    # recurrent weights are BLOCK-DIAGONAL over heads (paper §sLSTM):
    # (H, D/H, 4·D/H) — H× fewer recurrent params/bytes than dense, and
    # the per-timestep weight re-stream in the serial scan shrinks with it
    # (§Perf cell A: this is the dominant HBM term of the time scan)
    Dh = D // H
    params["w_h"] = (jax.random.normal(ks[1], (H, Dh, 4 * Dh), jnp.float32)
                     * 0.02).astype(jnp.dtype(cfg.param_dtype))
    specs["w_h"] = P(None, FSDP, TP)
    params["bias"] = jnp.concatenate([
        jnp.zeros((2 * D,), jnp.float32),
        jnp.full((D,), 3.0, jnp.float32),   # forget bias
        jnp.zeros((D,), jnp.float32)]).astype(jnp.float32)
    specs["bias"] = P(None)
    # post-block gated FFN (factor 4/3, paper block design), rounded up to
    # a 128 multiple so the TP shard divides evenly (and MXU-aligned)
    f = -(-int(D * 4 / 3) // 128) * 128
    params["w_ff_up"], specs["w_ff_up"] = dense_init(ks[2], D, 2 * f, cfg, (FSDP, TP))
    params["w_ff_down"], specs["w_ff_down"] = dense_init(ks[3], f, D, cfg, (TP, FSDP))
    return params, specs


def slstm_apply(params: dict, x: jax.Array, cfg: ModelConfig,
                state=None, return_state: bool = False):
    """x: (B, S, D).  Serial scan over time (sLSTM is not parallelizable:
    the normalizer/stabilizer recurrence is data-dependent)."""
    B, S, D = x.shape
    xin = (x @ params["w_x"]).astype(jnp.float32)       # (B, S, 4D)
    if state is None:
        state = slstm_state_init(cfg, B)
    h0, c0, n0, m0 = state
    H = cfg.xlstm_heads
    Dh = D // H

    def cell(carry, x_t):
        h, c, n, m = carry
        # block-diagonal recurrence: per-head (B, Dh) @ (Dh, 4Dh)
        hh = h.astype(x.dtype).reshape(B, H, Dh)
        rec = jnp.einsum("bhd,hdf->bhf", hh, params["w_h"])
        rec = rec.reshape(B, H, 4, Dh).transpose(0, 2, 1, 3).reshape(B, 4 * D)
        pre = x_t + rec.astype(jnp.float32) + params["bias"]
        z, i, f, o = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        log_f = jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(log_f + m, i)
        i_ = jnp.exp(i - m_new)
        f_ = jnp.exp(log_f + m - m_new)
        c = f_ * c + i_ * z
        n = f_ * n + i_
        h = o * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    # time-blocked scan (§Perf hillclimb A): the serial recurrence is
    # irreducible, but scanning one step at a time spends most of its
    # traffic on per-step carry packing (stacked-buffer updates billed at
    # full buffer size each step).  Blocks of TB steps read/write the
    # xin/hs buffers once per TB steps; the inner loop unrolls.
    TB = 32 if S % 32 == 0 else (8 if S % 8 == 0 else 1)

    def block_step(carry, x_blk):            # x_blk: (TB, B, 4D)
        hs_blk = []
        for t in range(TB):
            carry, h_t = cell(carry, x_blk[t])
            hs_blk.append(h_t)
        return carry, jnp.stack(hs_blk)

    xin_t = xin.transpose(1, 0, 2).reshape(S // TB, TB, B, 4 * D)
    (h, c, n, m), hs = jax.lax.scan(block_step, (h0, c0, n0, m0), xin_t)
    y = hs.reshape(S, B, D).transpose(1, 0, 2).astype(x.dtype)  # (B, S, D)
    # gated FFN
    up = y @ params["w_ff_up"]
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a.astype(jnp.float32)) * b.astype(jnp.float32)
         ).astype(x.dtype) @ params["w_ff_down"]
    if return_state:
        return y, (h, c, n, m)
    return y


def slstm_state_init(cfg: ModelConfig, batch: int):
    D = cfg.d_model
    z = jnp.zeros((batch, D), jnp.float32)
    return (z, z, z, jnp.full((batch, D), NEG_INF, jnp.float32))


def slstm_decode(params, x, state, cfg):
    return slstm_apply(params, x, cfg, state=state, return_state=True)


def mlstm_decode(params, x, state, cfg):
    return mlstm_apply(params, x, cfg, state=state, return_state=True)
