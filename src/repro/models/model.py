"""Public model facade: build, init, shard, step.

* ``abstract_params(cfg)``  — shapes-only params via jax.eval_shape (the
  dry-run path: no allocation ever happens for the full configs).
* ``param_shardings(cfg, mesh)`` — NamedSharding tree matching params.
* ``make_train_step(cfg, opt_cfg, mesh)`` — loss + grad + AdamW update,
  jit-able, shard-annotated.
* ``make_serve_step(cfg, mesh)`` — one-token decode over the cache.
* ``input_specs(cfg, shape)`` — ShapeDtypeStruct stand-ins for every model
  input of an assigned (arch × shape) cell, weak-type-correct, shardable.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.schedule import cosine_schedule
from .config import ModelConfig
from . import transformer as T
from .layers import TP


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def init_params(cfg: ModelConfig, seed: int = 0):
    params, _ = T.init_params(jax.random.PRNGKey(seed), cfg)
    return params


def abstract_params(cfg: ModelConfig):
    """Shape/dtype tree without allocation."""
    return jax.eval_shape(lambda k: T.init_params(k, cfg)[0],
                          jax.random.PRNGKey(0))


def param_shardings(cfg: ModelConfig, mesh):
    """NamedSharding tree matching the param tree."""
    specs = spec_tree(cfg)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))


_SPEC_CACHE: dict[str, Any] = {}


def spec_tree(cfg: ModelConfig):
    """PartitionSpec tree (no allocation: captured from an abstract trace —
    init builds specs structurally, so tracing under eval_shape yields them
    without materializing a single parameter)."""
    key = repr(cfg)
    if key not in _SPEC_CACHE:
        cell: dict[str, Any] = {}

        def f(k):
            params, specs = T.init_params(k, cfg)
            cell["specs"] = specs
            return params

        jax.eval_shape(f, jax.random.PRNGKey(0))
        _SPEC_CACHE[key] = cell["specs"]
    return _SPEC_CACHE[key]


def opt_spec_tree(params_specs, opt_cfg: AdamWConfig, cfg: ModelConfig,
                  abstract=None):
    """Moment shardings.  f32/bf16 moments mirror the params; int8
    block-quantized moments are (blocks, 256) — shard the block dim over
    the FSDP axis for leaves big enough to quantize (adamw._QUANT_MIN)."""
    from ..optim.adamw import _leaf_quantized
    if opt_cfg.state_dtype == "int8":
        if abstract is None:
            abstract = abstract_params(cfg)

        def qspec(s, a):
            if _leaf_quantized(a):
                full = tuple(s) + (None,) * (len(a.shape) - len(tuple(s)))
                # q mirrors the param's sharding exactly; per-row scale
                # drops the last (block) dim
                return {"q": P(*full), "scale": P(*full[:-1])}
            return s
        m = jax.tree.map(qspec, params_specs, abstract,
                         is_leaf=lambda s: isinstance(s, P))
        return {"m": m, "v": m, "step": P()}
    return {
        "m": params_specs,
        "v": params_specs,
        "step": P(),
    }


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, per assigned shape)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.frontend == "vision_stub":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_embeds, cfg.d_model), f)
        if cfg.is_encdec:
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.frontend == "vision_stub":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix_embeds, cfg.d_model), f)
        if cfg.is_encdec:
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
        return batch
    # decode: one new token against a cache of size S
    batch = {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    if cfg.is_encdec:
        batch["enc_out"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f)
    return batch


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    # batch/max_len are static shape inputs — close over them
    return jax.eval_shape(lambda: T.init_decode_state(cfg, batch, max_len))


def decode_state_specs(cfg: ModelConfig, batch: int, dp="data",
                       dp_size: int = 16, cache_layout: str = "auto",
                       tp_size: int = 16):
    """Sharding for the decode state.

    ``cache_layout`` (§Perf hillclimb B — see EXPERIMENTS.md):
      "seq"      — baseline: cache sequence dim over TP (context-parallel
                   KV).  Correct, but the per-step scatter at position
                   ``lengths`` crosses shard boundaries: GSPMD falls back
                   to "involuntary full rematerialization" (its own
                   warning) — the whole cache is re-gathered per layer per
                   token.  Measured tx = 1.2 s/token on qwen3 decode_32k.
      "head_dim" — shard the *head_dim* over TP.  The per-step cache write
                   is local to every shard; attention pays one (B, H, S)
                   psum for the Dh-partial logits instead.  The cache
                   memory per device is identical (Dh/16 × full S).
      "kv_head"  — MHA-class archs (n_kv_heads % tp == 0: codeqwen/olmo/
                   whisper): shard the KV-head dim itself — attention and
                   the cache write are *fully local per shard*, zero
                   decode collectives (§Perf B iteration 3; the head_dim
                   psum regressed exactly these archs).
      "auto"     — kv_head when divisible, else head_dim (default).

    Recurrent states (SSM / xLSTM) shard batch over data, features over TP.
    ``dp`` may be an axis name, a tuple of names, or None (batch too small).
    """
    b = dp if (batch % max(dp_size, 1) == 0 and batch >= dp_size) else None
    # with batch unshardable (long_500k B=1), put the sequence over data —
    # the cache is the only multi-GB tensor and must spread somewhere
    seq_axis = None if b is not None else dp

    if cache_layout in ("auto", "head_dim", "kv_head"):
        use_kv = (cfg.n_kv_heads % max(tp_size, 1) == 0
                  if cache_layout == "auto" else cache_layout == "kv_head")
    else:
        use_kv = False
    if cache_layout == "seq":
        attn_spec = P(None, b, None, TP, None)
        prefix_spec = P(b, None, TP, None)
    elif use_kv:
        attn_spec = P(None, b, TP, seq_axis, None)
        prefix_spec = P(b, TP, seq_axis, None)
    else:
        attn_spec = P(None, b, None, seq_axis, TP)
        prefix_spec = P(b, None, seq_axis, TP)

    def per_slot(kind):
        if kind == "attn":
            return {"k": attn_spec, "v": attn_spec}
        if kind == "mamba":
            return (P(None, b, None, TP),    # conv (periods, B, w, Din)
                    P(None, b, TP, None))    # h    (periods, B, Din, N)
        if kind == "mlstm":
            return (P(None, b, None, None, None),
                    P(None, b, None, None),
                    P(None, b, None))
        if kind == "slstm":
            z = P(None, b, TP)
            return (z, z, z, z)
        raise ValueError(kind)

    specs: dict[str, Any] = {}
    for s_idx, kind in enumerate(cfg.block_pattern):
        specs[f"slot{s_idx}"] = per_slot(kind)
    if cfg.n_dense_prefix:
        one = {"k": prefix_spec, "v": prefix_spec}
        specs["prefix"] = [one for _ in range(cfg.n_dense_prefix)]
    return specs


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None,
                    total_steps: int = 10000, warmup: int | None = None):
    wu = warmup if warmup is not None else max(1, min(200, total_steps // 20))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg, mesh))(params)
        lr_scale = cosine_schedule(opt_state["step"], warmup=wu,
                                   total=total_steps)
        new_params, new_opt = adamw_update(params, grads, opt_state,
                                           opt_cfg, lr_scale=lr_scale)
        return new_params, new_opt, {"loss": loss, "lr_scale": lr_scale}
    return train_step


def make_eval_step(cfg: ModelConfig, mesh=None):
    def eval_step(params, batch):
        return T.loss_fn(params, batch, cfg, mesh)
    return eval_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    def prefill_step(params, batch):
        return T.forward(params, batch, cfg, mesh)
    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None):
    def serve_step(params, state, batch):
        enc_out = batch.get("enc_out")
        logits, new_state = T.decode_step(
            params, state, batch["tokens"], batch["lengths"], cfg,
            mesh=mesh, enc_out=enc_out)
        # mask vocab-padding ids (embed table is padded to a 256 multiple)
        if cfg.padded_vocab != cfg.vocab:
            valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
            logits = jnp.where(valid[None, :], logits, -jnp.inf)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_state
    return serve_step


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for the roofline
    'useful compute' ratio.  N counted from the *active* parameter set."""
    n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens  # forward only
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_active * tokens


def _active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top_k + shared experts only)."""
    shapes = jax.tree.leaves(
        jax.tree.map(lambda x: x.shape,
                     abstract_params(cfg),
                     is_leaf=lambda x: hasattr(x, "shape")))
    # count full tree, then correct the MoE expert stacks
    total = 0.0
    tree = abstract_params(cfg)
    # jax.tree.flatten_with_path landed after the pinned 0.4.37; the
    # tree_util spelling exists across every supported version
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        keys = "/".join(str(p) for p in path)
        if cfg.moe is not None and ("w_gate" in keys or "w_up" in keys
                                    or "w_down" in keys) and "moe" in keys:
            n = n * (cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total
