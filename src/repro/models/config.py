"""Model configuration schema shared by all 10 assigned architectures.

One dataclass covers dense / MoE / SSM / hybrid / enc-dec families via a
per-period ``block_pattern`` (the scan unit): e.g. jamba's 1:7
attention:mamba interleave is ``["mamba"]*3 + ["attn"] + ["mamba"]*4``
with MoE on every second layer, scanned over 4 periods.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal

BlockKind = Literal["attn", "mamba", "mlstm", "slstm"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared (always-on) experts, kimi-style
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 → d_model // n_heads
    rope_theta: float = 10000.0
    qk_norm: bool = False       # qwen3
    nonparam_ln: bool = False   # olmo: layernorm without learned affine
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # block layout: pattern repeated n_layers/len(pattern) times by scan;
    # first ``n_dense_prefix`` layers are unrolled with dense FFN even in a
    # MoE model (kimi convention).
    block_pattern: tuple[BlockKind, ...] = ("attn",)
    moe_every: int = 0          # 0 = no MoE; else MoE FFN on layers i%moe_every==0
    n_dense_prefix: int = 0
    moe: MoEConfig | None = None

    # SSM (mamba) block parameters
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2

    # xLSTM block parameters
    xlstm_heads: int = 4

    # encoder-decoder (whisper)
    is_encdec: bool = False
    n_enc_layers: int = 0

    # modality frontend stub: inputs are precomputed embeddings
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    n_prefix_embeds: int = 0    # vlm: patch embeddings prepended to text

    # attention structure flags
    sub_quadratic: bool = False  # supports long_500k (ssm / hybrid)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows, padded to a 256 multiple so the vocab dim
        shards evenly over any TP axis ≤ 256 (whisper's 51865 and
        internvl's 151655 are not 16-divisible).  Logits are emitted at
        this width; serve_step masks the pad ids."""
        return -(-self.vocab // 256) * 256

    @property
    def n_periods(self) -> int:
        body = self.n_layers - self.n_dense_prefix
        assert body % len(self.block_pattern) == 0, \
            (self.n_layers, self.n_dense_prefix, self.block_pattern)
        return body // len(self.block_pattern)

    def layer_is_moe(self, layer_idx: int) -> bool:
        if self.moe is None or layer_idx < self.n_dense_prefix:
            return False
        if self.moe_every <= 0:
            return False
        return (layer_idx - self.n_dense_prefix) % self.moe_every == 0

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small: dict = dict(
            n_layers=len(self.block_pattern) * (2 if self.n_dense_prefix == 0 else 1)
            + self.n_dense_prefix,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            d_head=16,
            vocab=256,
            d_state=8,
            xlstm_heads=2,
            n_enc_layers=2 if self.is_encdec else 0,
            n_prefix_embeds=8 if self.n_prefix_embeds else 0,
            dtype="float32",
            param_dtype="float32",
        )
        if self.moe is not None:
            small["moe"] = replace(self.moe, n_experts=4,
                                   top_k=min(self.moe.top_k, 2), d_ff_expert=64)
        small.update(overrides)
        return replace(self, **small)
