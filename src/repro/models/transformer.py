"""Composable LM assembly for all 10 assigned architectures.

Layer layout = ``n_dense_prefix`` unrolled layers + ``jax.lax.scan`` over
periods of ``block_pattern`` (the HLO stays one-period-sized regardless of
depth — compile-time and multi-pod dry-run friendly).  Per-slot params are
stacked on a leading period axis; remat (jax.checkpoint) wraps the period
body.

Block kinds: "attn" (GQA, optional qk-norm / cross-attn), "mamba"
(selective SSM), "mlstm"/"slstm" (xLSTM).  FFN sublayer per slot: dense
gated MLP or MoE (expert-parallel), per ``cfg.layer_is_moe``.

Decode: per-slot recurrent state (KV cache / SSM state / xLSTM state)
stacked the same way, threaded through the same scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from . import layers as L
from . import moe as M
from . import ssm as S
from . import xlstm as X


def _dp_axes(mesh) -> tuple:
    if mesh is None:
        return ()
    return tuple(a for a in mesh.axis_names if a != L.TP)


def shard_act(x, mesh, *, batch_dim: int = 0, seq_dim: int | None = None):
    """Constrain an activation: batch over the data axes and — when
    ``seq_dim`` is given and divisible — sequence over the TP axis
    (sequence parallelism: the residual stream that scan saves for the
    backward pass is then 1/tp per device; XLA inserts the
    all-gather/reduce-scatter pairs around attention/MLP automatically)."""
    if mesh is None:
        return x
    dp = _dp_axes(mesh)
    spec: list = [None] * x.ndim
    if dp and x.shape[batch_dim] % int(np.prod([mesh.shape[a] for a in dp])) == 0 \
            and x.shape[batch_dim] > 1:
        spec[batch_dim] = dp
    if seq_dim is not None and L.TP in mesh.axis_names:
        tp = mesh.shape[L.TP]
        if tp > 1 and x.shape[seq_dim] % tp == 0 and x.shape[seq_dim] >= tp:
            spec[seq_dim] = L.TP
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# per-slot init
# ---------------------------------------------------------------------------
def _slot_init(key, kind: str, is_moe: bool, cfg: ModelConfig,
               with_cross: bool = False):
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    params["norm1"], specs["norm1"] = L.norm_init(cfg)
    if kind == "attn":
        params["attn"], specs["attn"] = L.attn_init(ks[0], cfg)
    elif kind == "mamba":
        params["ssm"], specs["ssm"] = S.ssm_init(ks[0], cfg)
    elif kind == "mlstm":
        params["mlstm"], specs["mlstm"] = X.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        params["slstm"], specs["slstm"] = X.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if with_cross:
        params["norm_x"], specs["norm_x"] = L.norm_init(cfg)
        params["cross"], specs["cross"] = L.cross_attn_init(ks[1], cfg)
    if kind in ("attn", "mamba"):  # xlstm blocks carry their own FFN
        params["norm2"], specs["norm2"] = L.norm_init(cfg)
        if is_moe:
            params["moe"], specs["moe"] = M.moe_init(ks[2], cfg)
        else:
            params["mlp"], specs["mlp"] = L.mlp_init(ks[2], cfg)
    return params, specs


def _slot_apply(kind: str, params: dict, x, cfg: ModelConfig, mesh,
                enc_out=None):
    """Full-sequence apply of one block."""
    h = L.norm_apply(params["norm1"], x, cfg)
    if kind == "attn":
        x = x + L.attn_apply(params["attn"], h, cfg, causal=not cfg.is_encdec
                             or enc_out is not None)
    elif kind == "mamba":
        x = x + S.ssm_apply(params["ssm"], h, cfg)
    elif kind == "mlstm":
        x = x + X.mlstm_apply(params["mlstm"], h, cfg)
    elif kind == "slstm":
        x = x + X.slstm_apply(params["slstm"], h, cfg)
    if "cross" in params and enc_out is not None:
        hx = L.norm_apply(params["norm_x"], x, cfg)
        x = x + L.cross_attn_apply(params["cross"], hx, enc_out, cfg)
    if "moe" in params:
        h2 = L.norm_apply(params["norm2"], x, cfg)
        x = x + M.moe_apply(params["moe"], h2, cfg, mesh=mesh)
    elif "mlp" in params:
        h2 = L.norm_apply(params["norm2"], x, cfg)
        x = x + L.mlp_apply(params["mlp"], h2)
    # sequence-parallel residual: the value scan saves for backward is
    # sharded over TP as well as DP
    return shard_act(x, mesh, seq_dim=1)


def _slot_is_moe(cfg: ModelConfig, slot: int) -> bool:
    if cfg.moe is None or cfg.moe_every <= 0:
        return False
    return slot % cfg.moe_every == (cfg.moe_every - 1) % cfg.moe_every


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig):
    """Returns (params, pspecs) with identical tree structure."""
    ks = jax.random.split(key, 16)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    dt = jnp.dtype(cfg.param_dtype)
    emb = (jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model),
                             jnp.float32) * 0.02).astype(dt)
    params["embed"] = emb
    specs["embed"] = P(L.TP, None)

    # dense prefix layers (unrolled)
    prefix, prefix_specs = [], []
    for i in range(cfg.n_dense_prefix):
        p, s = _slot_init(ks[1 + i], "attn", False, cfg)
        prefix.append(p)
        prefix_specs.append(s)
    if prefix:
        params["prefix"] = prefix
        specs["prefix"] = prefix_specs

    # scanned body: stack per-slot params over periods
    n_p = cfg.n_periods
    body, body_specs = {}, {}
    for s_idx, kind in enumerate(cfg.block_pattern):
        is_moe = _slot_is_moe(cfg, s_idx)
        stacked, stacked_specs = _stack_periods(
            ks[8], s_idx, kind, is_moe, cfg, n_p)
        body[f"slot{s_idx}"] = stacked
        body_specs[f"slot{s_idx}"] = stacked_specs
    params["body"] = body
    specs["body"] = body_specs

    if cfg.is_encdec:
        st, sts = _stack_periods(ks[9], 0, "attn", False, cfg,
                                 cfg.n_enc_layers, salt=101)
        params["enc_body"] = {"slot0": st}
        specs["enc_body"] = {"slot0": sts}
        # decoder cross-attention lives in body slots — rebuild with cross
        body, body_specs = {}, {}
        for s_idx, kind in enumerate(cfg.block_pattern):
            stacked, stacked_specs = _stack_periods(
                ks[10], s_idx, kind, _slot_is_moe(cfg, s_idx), cfg, n_p,
                with_cross=True)
            body[f"slot{s_idx}"] = stacked
            body_specs[f"slot{s_idx}"] = stacked_specs
        params["body"] = body
        specs["body"] = body_specs

    params["final_norm"], specs["final_norm"] = L.norm_init(cfg)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = L.dense_init(
            ks[11], cfg.d_model, cfg.padded_vocab, cfg, (None, L.TP))
    return params, specs


def _stack_periods(key, s_idx, kind, is_moe, cfg, n_p, with_cross=False,
                   salt=0):
    """Init one slot n_p times and stack leaves on a leading axis."""
    keys = jax.random.split(jax.random.fold_in(key, s_idx * 131 + salt), n_p)
    ps, sp0 = [], None
    for i in range(n_p):
        p, sp = _slot_init(keys[i], kind, is_moe, cfg, with_cross=with_cross)
        ps.append(p)
        sp0 = sp
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps)
    stacked_specs = jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), sp0,
        is_leaf=lambda s: isinstance(s, P))
    return stacked, stacked_specs


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens, cfg: ModelConfig):
    return params["embed"][tokens].astype(jnp.dtype(cfg.dtype))


def _body_scan(params_body, x, cfg: ModelConfig, mesh, enc_out=None,
               remat: bool = True):
    def period_fn(x, period_params):
        for s_idx, kind in enumerate(cfg.block_pattern):
            x = _slot_apply(kind, period_params[f"slot{s_idx}"], x, cfg,
                            mesh, enc_out=enc_out)
        return x

    if remat:
        # remat policy knob (§Perf): "nothing" recomputes the whole period
        # in the backward (min memory, max recompute — the default);
        # "dots" saves matmul outputs (skips recompute incl. the FSDP
        # re-gathers it needs, at an activation-memory cost).
        import os
        policy_name = os.environ.get("REPRO_REMAT_POLICY", "nothing")
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if policy_name == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        period_fn = jax.checkpoint(period_fn, policy=policy)

    def scan_fn(x, period_params):
        return period_fn(x, period_params), None

    x, _ = jax.lax.scan(scan_fn, x, params_body)
    return x


def forward(params, batch: dict, cfg: ModelConfig, mesh=None) -> jax.Array:
    """Returns logits (B, S_total, V).

    batch keys by family:
      tokens (B, S) int32                     — all LMs
      prefix_embeds (B, Np, D)                — vlm stub (prepended)
      frames (B, Se, D)                       — audio stub (encoder input)
    """
    x = hidden_states(params, batch, cfg, mesh)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return x @ head.astype(x.dtype)


def hidden_states(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Forward up to (but not including) the LM head."""
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg)
    if cfg.frontend == "vision_stub":
        pe = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    x = shard_act(x, mesh, seq_dim=1)
    enc_out = None
    if cfg.is_encdec:
        e = batch["frames"].astype(x.dtype)
        e = shard_act(e, mesh, seq_dim=1)
        e = _body_scan(params["enc_body"], e, cfg, mesh)
        enc_out = L.norm_apply(params["final_norm"], e, cfg)
    for p in params.get("prefix", []):
        x = _slot_apply("attn", p, x, cfg, mesh)
    x = _body_scan(params["body"], x, cfg, mesh, enc_out=enc_out)
    return L.norm_apply(params["final_norm"], x, cfg)


def loss_fn(params, batch, cfg: ModelConfig, mesh=None,
            loss_chunks: int = 8) -> jax.Array:
    """Next-token cross entropy; labels < 0 are masked (vlm prefix, pad).

    The LM head + CE run *chunked over tokens* under remat: only one
    chunk of f32 logits is live at a time (kimi: 163k vocab × 1M tokens
    would otherwise hold ~2.5 GB/device of logits twice through the
    backward pass)."""
    x = hidden_states(params, batch, cfg, mesh)
    labels = batch["labels"]
    if cfg.frontend == "vision_stub":
        npfx = batch["prefix_embeds"].shape[1]
        pad = jnp.full((labels.shape[0], npfx), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    lt = labels.reshape(B * S)
    n_chunks = loss_chunks if (B * S) % loss_chunks == 0 else 1

    def chunk_nll(x_c, l_c):
        logits = (x_c @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(l_c, 0)[:, None], axis=-1)[:, 0]
        mask = (l_c >= 0).astype(jnp.float32)
        return jnp.sum((lse - ll) * mask), jnp.sum(mask)

    if n_chunks == 1:
        total, count = chunk_nll(xt, lt)
    else:
        xc = xt.reshape(n_chunks, -1, D)
        lc = lt.reshape(n_chunks, -1)

        @jax.checkpoint
        def body(carry, xs):
            t, c = carry
            dt, dc = chunk_nll(*xs)
            return (t + dt, c + dc), None

        (total, count), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xc, lc))
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-slot decode states mirroring the body layout."""
    dt = jnp.dtype(cfg.dtype)

    def one(kind):
        if kind == "attn":
            return L.attn_cache_init(cfg, batch, max_len, dt)
        if kind == "mamba":
            return S.ssm_state_init(cfg, batch)
        if kind == "mlstm":
            return X.mlstm_state_init(cfg, batch)
        if kind == "slstm":
            return X.slstm_state_init(cfg, batch)
        raise ValueError(kind)

    state = {}
    for s_idx, kind in enumerate(cfg.block_pattern):
        per = [one(kind) for _ in range(cfg.n_periods)]
        state[f"slot{s_idx}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=0), *per)
    prefix_states = [one("attn") for _ in range(cfg.n_dense_prefix)]
    if prefix_states:
        state["prefix"] = prefix_states
    return state


def _slot_decode(kind, params, x, state, lengths, cfg, mesh, enc_out=None):
    h = L.norm_apply(params["norm1"], x, cfg)
    if kind == "attn":
        o, state = L.attn_decode(params["attn"], h, state, lengths, cfg)
        x = x + o
    elif kind == "mamba":
        o, state = S.ssm_decode(params["ssm"], h, state, cfg)
        x = x + o
    elif kind == "mlstm":
        o, state = X.mlstm_decode(params["mlstm"], h, state, cfg)
        x = x + o
    elif kind == "slstm":
        o, state = X.slstm_decode(params["slstm"], h, state, cfg)
        x = x + o
    if "cross" in params and enc_out is not None:
        hx = L.norm_apply(params["norm_x"], x, cfg)
        x = x + L.cross_attn_apply(params["cross"], hx, enc_out, cfg)
    if "moe" in params:
        h2 = L.norm_apply(params["norm2"], x, cfg)
        x = x + M.moe_apply(params["moe"], h2, cfg, mesh=mesh)
    elif "mlp" in params:
        h2 = L.norm_apply(params["norm2"], x, cfg)
        x = x + L.mlp_apply(params["mlp"], h2)
    return x, state


def decode_step(params, state: dict, tokens: jax.Array, lengths: jax.Array,
                cfg: ModelConfig, mesh=None, enc_out=None):
    """One decode step.  tokens: (B,) int32 — the freshly sampled token;
    lengths: (B,) current context lengths.  Returns (logits (B, V),
    new_state)."""
    x = embed_tokens(params, tokens[:, None], cfg)      # (B, 1, D)

    new_prefix = []
    for p, st in zip(params.get("prefix", []), state.get("prefix", [])):
        x, st2 = _slot_decode("attn", p, x, st, lengths, cfg, mesh,
                              enc_out=enc_out)
        new_prefix.append(st2)

    def scan_fn(carry, xs):
        x = carry
        period_params, period_state = xs
        new_state = {}
        for s_idx, kind in enumerate(cfg.block_pattern):
            x, st = _slot_decode(kind, period_params[f"slot{s_idx}"], x,
                                 period_state[f"slot{s_idx}"], lengths, cfg,
                                 mesh, enc_out=enc_out)
            new_state[f"slot{s_idx}"] = st
        return x, new_state

    body_state = {k: v for k, v in state.items() if k != "prefix"}
    x, new_body = jax.lax.scan(scan_fn, x, (params["body"], body_state))
    x = L.norm_apply(params["final_norm"], x, cfg)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head.astype(x.dtype))[:, 0, :]
    out_state = dict(new_body)
    if new_prefix:
        out_state["prefix"] = new_prefix
    return logits, out_state
