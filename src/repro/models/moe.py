"""Mixture-of-Experts FFN with expert parallelism.

Routing runs through the fused Pallas gate (kernels/moe_router); expert
compute is a *capacity-based batched dispatch*:

  sort assignments by expert → scatter token ids into an (E_loc, C_e)
  index buffer (capacity C_e per expert, GShard discipline; overflow
  drops) → gather tokens to (E_loc, C_e, D) → one batched einsum per
  projection → scatter-add combine weighted by the gate.

FLOPs are exact up to the capacity factor (E_loc·C_e·D·F ≈ top_k·T·D·F·cf)
— no one-hot dispatch einsums, and no ``lax.ragged_dot`` (whose XLA
expansion materializes dense per-group masks: measured 26 GiB × 24
buffers on kimi's 24-expert shard before this formulation).  The batched
einsum form is also what the TPU MXU wants: one (C_e × D × F) matmul per
expert, weight-stationary.

Two execution paths:

* ``moe_apply_local``  — single shard, all experts local (CPU smoke
  tests; also the k=top_k dense fallback).
* ``moe_apply``        — expert-parallel via shard_map: activations are
  replicated across the TP/EP axis between blocks (Megatron convention),
  experts sharded over it.  Each device keeps the assignments that land
  on *its* expert slice (local capacity-bounded selection — tokens are
  already resident, so dispatch needs **no all-to-all**), runs its local
  batched FFN, and partial outputs combine with one ``psum`` over the EP
  axis — the same single collective a dense TP FFN pays.

Shared experts (kimi-style) are a dense gated MLP added unconditionally.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..jax_compat import shard_map

from ..kernels import ops
from .config import ModelConfig
from .layers import FSDP, TP, _dtype, dense_init, mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig):
    assert cfg.moe is not None
    m = cfg.moe
    ks = jax.random.split(key, 5)
    D, F, E = cfg.d_model, m.d_ff_expert, m.n_experts
    params, specs = {}, {}
    params["router"], specs["router"] = dense_init(
        ks[0], D, E, cfg, (None, None), scale=0.02)
    # experts stacked on a leading E axis, sharded over the TP/EP axis
    def experts(k, d_in, d_out):
        w = (jax.random.normal(k, (E, d_in, d_out), jnp.float32)
             / np.sqrt(d_in)).astype(_dtype(cfg))
        return w, P(TP, FSDP, None)
    params["w_gate"], specs["w_gate"] = experts(ks[1], D, F)
    params["w_up"], specs["w_up"] = experts(ks[2], D, F)
    params["w_down"], specs["w_down"] = experts(ks[3], F, D)
    if m.n_shared > 0:
        sh, shs = mlp_init(ks[4], cfg, d_ff=F * m.n_shared)
        params["shared"], specs["shared"] = sh, shs
    return params, specs


def _dispatch_ffn(x, local_e, tok_flat, w_flat, n_local, cap_e,
                  w_gate, w_up, w_down):
    """Capacity dispatch + batched expert FFN + weighted combine.

    x: (T, D); local_e: (A,) local expert id per assignment (n_local ⇒
    not-mine/invalid); tok_flat/w_flat: (A,) token id / gate weight.
    Returns (T, D) f32 partial output (zeros for tokens with no local
    assignment)."""
    T, D = x.shape
    A = local_e.shape[0]
    order = jnp.argsort(local_e, stable=True)       # experts ascending,
    sorted_e = local_e[order]                       # invalid last
    sorted_tok = tok_flat[order]
    sorted_w = w_flat[order]
    sizes = jnp.bincount(local_e, length=n_local + 1)[:n_local]
    starts = jnp.concatenate(
        [jnp.zeros((1,), sizes.dtype), jnp.cumsum(sizes)[:-1]])
    pos_in_e = (jnp.arange(A, dtype=jnp.int32)
                - starts[jnp.clip(sorted_e, 0, n_local - 1)].astype(jnp.int32))
    valid = (sorted_e < n_local) & (pos_in_e < cap_e) & (pos_in_e >= 0)
    e_safe = jnp.where(valid, sorted_e, n_local)    # OOB ⇒ dropped
    p_safe = jnp.where(valid, pos_in_e, cap_e)
    buf = jnp.zeros((n_local, cap_e), jnp.int32).at[e_safe, p_safe].set(
        sorted_tok, mode="drop")
    wbuf = jnp.zeros((n_local, cap_e), jnp.float32).at[e_safe, p_safe].set(
        sorted_w, mode="drop")
    xs = x[buf]                                     # (E_loc, C_e, D)
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate.astype(xs.dtype))
    u = jnp.einsum("ecd,edf->ecf", xs, w_up.astype(xs.dtype))
    h = (jax.nn.silu(g.astype(jnp.float32))
         * u.astype(jnp.float32)).astype(xs.dtype)
    ys = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xs.dtype))
    contrib = ys.astype(jnp.float32) * wbuf[..., None]   # gate=0 ⇒ no-op
    out = jnp.zeros((T, D), jnp.float32)
    out = out.at[buf.reshape(-1)].add(contrib.reshape(-1, D))
    return out


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(int(np.ceil(tokens * top_k / max(n_experts, 1) * cf)), 4)


def moe_apply_local(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Single-shard MoE: x (T, D) → (T, D)."""
    m = cfg.moe
    T, D = x.shape
    logits = (x @ params["router"]).astype(jnp.float32)
    weights, idx = ops.moe_router(logits, m.top_k)          # (T, k)
    idx_flat = idx.reshape(-1)                              # (T·k,)
    tok_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), m.top_k)
    w_flat = weights.reshape(-1)
    cap = _capacity(T, m.top_k, m.n_experts, m.capacity_factor)
    out = _dispatch_ffn(x, idx_flat, tok_flat, w_flat, m.n_experts, cap,
                        params["w_gate"], params["w_up"], params["w_down"])
    out = out.astype(x.dtype)
    if m.n_shared > 0:
        out = out + mlp_apply(params["shared"], x)
    return out


def _moe_shard_body(x: jax.Array, router: jax.Array, w_gate, w_up, w_down,
                    *, cfg: ModelConfig, ep_shards: int, axis: str):
    """Per-device body under shard_map.

    x: (T_loc, D) — local tokens (sharded over data, replicated over
    TP/EP).  w_*: (E_loc, …) — this device's expert slice.  Every EP
    member computes the same router output for its token slice, keeps
    assignments for its own experts, and psums the partials."""
    m = cfg.moe
    T, D = x.shape
    E_loc = w_gate.shape[0]
    my = jax.lax.axis_index(axis)
    lo = my * E_loc

    logits = (x @ router).astype(jnp.float32)
    weights, idx = ops.moe_router(logits, m.top_k)
    idx_flat = idx.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), m.top_k)
    w_flat = weights.reshape(-1)
    mine = (idx_flat >= lo) & (idx_flat < lo + E_loc)
    local_e = jnp.where(mine, idx_flat - lo, E_loc)
    cap = _capacity(T, m.top_k, m.n_experts, m.capacity_factor)
    partial_out = _dispatch_ffn(x, local_e, tok_flat, w_flat, E_loc, cap,
                                w_gate, w_up, w_down)
    # combine in bf16: halves the EP-psum bytes (the per-layer collective)
    return jax.lax.psum(partial_out.astype(x.dtype), axis)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              mesh=None) -> jax.Array:
    """x: (B, S, D) → (B, S, D).  EP path when a mesh with a TP axis whose
    size divides n_experts is active; local path otherwise."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    m = cfg.moe
    ep = 0
    if mesh is not None and TP in mesh.axis_names:
        tp = mesh.shape[TP]
        if tp > 1 and m.n_experts % tp == 0:
            ep = tp
    if ep:
        data_axes = tuple(a for a in mesh.axis_names if a != TP)
        dp_size = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes else 1
        # decode batches too small to split stay replicated over data
        x_spec = (P(data_axes, None)
                  if (B * S) % max(dp_size, 1) == 0 and B * S >= dp_size
                  else P(None, None))
        body = partial(_moe_shard_body, cfg=cfg, ep_shards=ep, axis=TP)
        out = shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, P(None, None),
                      P(TP, None, None), P(TP, None, None), P(TP, None, None)),
            out_specs=x_spec,
            check_vma=False,
        )(xt, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
        if m.n_shared > 0:
            out = out + mlp_apply(params["shared"], xt)
    else:
        out = moe_apply_local(params, xt, cfg)
    return out.reshape(B, S, D)
