"""Trip-count-aware HLO cost walker.

XLA's ``HloCostAnalysis`` (behind ``compiled.cost_analysis()``) visits a
``while`` body **once** — for scan-over-layers models that undercounts
flops/bytes/collectives by the layer count.  This walker parses the
optimized HLO text, recovers each while loop's trip count from its
condition (``compare(iter, constant(N))`` pattern), and accumulates

  * ``flops``            — 2·M·N·K for every dot (batch dims included),
  * ``bytes``            — operand+result bytes of every traffic-bearing
                           op (fusions count their boundary, matching the
                           HBM-traffic model),
  * ``collectives``      — per-op-kind counts and bytes,

each multiplied by the product of enclosing trip counts.  Conditionals
take the max across branches; fusion/call bodies are charged to the call
site (not double-counted at top level).

This is the measurement backbone of EXPERIMENTS.md §Roofline/§Perf.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*)$")
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                        r"called_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    kind: str
    result: str
    rest: str


@dataclass
class _Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # op name -> result shape str

    def operand_shapes(self, op: "_Op") -> list[str]:
        args = op.rest.split(")")[0]
        return [self.shapes[n] for n in re.findall(r"%([\w.\-]+)", args)
                if n in self.shapes]


def parse_computations(hlo: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if (stripped.endswith("{") and "->" in stripped
                and " = " not in stripped):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = _Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = _Op(m.group(1), m.group(3), m.group(2), m.group(4))
            cur.ops.append(op)
            cur.shapes[op.name] = op.result
    return comps


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 × |result| × |contraction|: result shape × lhs contracting dims
    (lhs shape resolved through the computation's symbol table)."""
    res = 1
    for d in _shape_dims(op.result):
        res *= d
    opers = comp.operand_shapes(op)
    if not opers:
        return 0.0
    lhs_dims = _shape_dims(opers[0])
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contr = 1
    if cdims and cdims.group(1):
        for i in cdims.group(1).split(","):
            di = int(i)
            if di < len(lhs_dims):
                contr *= lhs_dims[di]
    return 2.0 * res * contr


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "reshape", "after-all", "partition-id",
               "replica-id", "custom-call"}


def _trip_count(cond: _Computation) -> int:
    """Best-effort: the largest integer constant in the condition.  Covers
    lax.scan/map/fori (compare(iter, constant(N)))."""
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            # op line was split at "constant(" → rest starts with "N)"
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
        m = _CONST_RE.search(op.rest)
        if m:
            best = max(best, int(m.group(1)))
    return best


@dataclass
class WalkResult:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    while_trips: list = field(default_factory=list)
    bytes_by_kind: dict = field(default_factory=dict)
    top_ops: dict = field(default_factory=dict)   # "kind result" -> bytes

    def as_dict(self) -> dict:
        top = dict(sorted(self.top_ops.items(), key=lambda kv: -kv[1])[:20])
        return {"flops": self.flops, "bytes": self.bytes,
                "collective_bytes": self.collective_bytes,
                "collectives": self.collectives,
                "while_trips": self.while_trips,
                "bytes_by_kind": dict(sorted(
                    self.bytes_by_kind.items(), key=lambda kv: -kv[1])[:15]),
                "top_ops": top}


def walk(hlo: str, entry: str | None = None) -> WalkResult:
    comps = parse_computations(hlo)
    if not comps:
        return WalkResult()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    out = WalkResult()

    def visit(comp_name: str, mult: float, depth: int = 0) -> None:
        comp = comps.get(comp_name)
        if comp is None or depth > 24:
            return
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                callees = dict(
                    re.findall(r"(body|condition)=%?([\w.\-]+)", op.rest))
                body, cond = callees.get("body"), callees.get("condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                out.while_trips.append(trips)
                if body:
                    visit(body, mult * trips, depth + 1)
                continue
            if kind == "conditional":
                branches = re.search(
                    r"branch_computations=\{([^}]*)\}", op.rest)
                names = ([b.strip().lstrip("%") for b in
                          branches.group(1).split(",")] if branches else [])
                for b in names:  # upper bound: sum of branches
                    visit(b, mult, depth + 1)
                continue
            if kind in ("fusion", "call", "map", "reduce", "reduce-window",
                        "scatter", "sort", "select-and-scatter"):
                # charge boundary traffic here; also walk fused dots so
                # MXU work inside fusions is counted
                cal = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.rest)
                if kind in ("fusion", "call") and cal:
                    _visit_dots_only(cal.group(1), mult, depth + 1)
            if kind == "dot" or kind == "convolution":
                out.flops += mult * _dot_flops(op, comp)
            if kind in COLLECTIVES:
                b = _shape_bytes(op.result)
                rec = out.collectives.setdefault(
                    kind, {"count": 0.0, "bytes": 0.0})
                rec["count"] += mult
                rec["bytes"] += mult * b
                out.collective_bytes += mult * b
            if kind not in _SKIP_BYTES:
                # HBM-traffic model: dots really stream their operands
                # (weights re-read per loop iteration!); everything else
                # is charged result×2 (read≈write) — charging full
                # operands would bill a dynamic-slice for the whole
                # buffer it slices from (measured 59 TB of fiction on
                # xlstm's time scan before this rule).
                if kind in ("dot", "convolution"):
                    b = _shape_bytes(op.result)
                    for s in comp.operand_shapes(op):
                        b += _shape_bytes(s)
                else:
                    # result×2 (read≈write).  Known limitation, documented
                    # in EXPERIMENTS.md §Roofline: scan-carry update
                    # fusions (dynamic-update-slice of a stacked buffer)
                    # are billed at full buffer size per step, which
                    # overstates the memory term of long *serial* scans
                    # (xlstm's sLSTM time loop).  Attempted operand-aware
                    # in-place detection re-billed slice reads at full
                    # buffer size — strictly worse; reverted.
                    b = 2 * _shape_bytes(op.result)
                out.bytes += mult * b
                out.bytes_by_kind[kind] = (out.bytes_by_kind.get(kind, 0.0)
                                           + mult * b)
                key = f"{kind} {op.result[:64]}"
                out.top_ops[key] = out.top_ops.get(key, 0.0) + mult * b

    def _visit_dots_only(comp_name: str, mult: float, depth: int) -> None:
        comp = comps.get(comp_name)
        if comp is None or depth > 24:
            return
        for op in comp.ops:
            if op.kind == "dot" or op.kind == "convolution":
                out.flops += mult * _dot_flops(op, comp)
            elif op.kind in ("fusion", "call"):
                cal = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", op.rest)
                if cal:
                    _visit_dots_only(cal.group(1), mult, depth + 1)
            elif op.kind == "while":
                callees = dict(
                    re.findall(r"(body|condition)=%?([\w.\-]+)", op.rest))
                body, cond = callees.get("body"), callees.get("condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    _visit_dots_only(body, mult * trips, depth + 1)

    visit(entry, 1.0)
    return out
