import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements of this module — jax
locks the device count on first init, and the production meshes need 512
placeholder host devices.  (Only the dry-run sets this; tests and benches
see 1 device.)

Per cell this script:
  1. builds the production mesh (16×16 single-pod or 2×16×16 multi-pod),
  2. builds abstract params/opt-state/batch (ShapeDtypeStruct — nothing is
     allocated, ever, for the full configs),
  3. ``jax.jit(step, in_shardings=…, out_shardings=…).lower(...)`` and
     ``.compile()`` — sharding mismatches, compile-time OOM and
     unsupported collectives all fail HERE,
  4. records ``compiled.memory_analysis()``, ``cost_analysis()`` and the
     per-collective byte counts parsed from the optimized HLO into
     ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import all_arch_ids, get_config
from repro.launch import hlo_walk
from repro.launch.mesh import dp_axes, dp_size, make_production_mesh
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# v5e hardware constants for the roofline terms (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _opt_cfg(cfg: ModelConfig) -> AdamWConfig:
    # trillion-param configs need quantized moments to fit (see configs/kimi)
    if cfg.name.startswith("kimi"):
        return AdamWConfig(state_dtype="int8")
    if cfg.name.startswith(("dbrx", "jamba")):
        return AdamWConfig(state_dtype="bfloat16")
    return AdamWConfig(state_dtype="float32")


def batch_shardings(cfg: ModelConfig, shape: M.ShapeSpec, mesh):
    dpa = dp_axes(mesh)
    dps = dp_size(mesh)
    specs = {}
    b_ok = shape.global_batch % dps == 0 and shape.global_batch >= dps
    bspec = dpa if b_ok else None
    for k, v in M.input_specs(cfg, shape).items():
        spec = [None] * len(v.shape)
        if len(v.shape) >= 1:
            spec[0] = bspec
        # decode with batch 1: shard the cache/context length instead
        if not b_ok and k in ("enc_out",) and len(v.shape) == 3:
            spec[1] = "model"
        specs[k] = NamedSharding(mesh, P(*spec))
    return specs


def skip_reason(cfg: ModelConfig, shape: M.ShapeSpec) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("SKIP(full-attn): 512k dense attention/KV is out of reach "
                "for a quadratic arch; DESIGN.md §4")
    return None


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               *, compile_: bool = True) -> dict:
    cfg = get_config(arch)
    shape = M.SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "skip", "skip_reason": reason}
    if reason is not None:
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    abstract = M.abstract_params(cfg)
    pspecs = M.spec_tree(cfg)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda s: isinstance(s, P))
    bshard = batch_shardings(cfg, shape, mesh)
    binputs = M.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_cfg = _opt_cfg(cfg)
        opt_abstract = jax.eval_shape(
            lambda p: adamw_init(p, opt_cfg), abstract)
        ospecs = M.opt_spec_tree(pspecs, opt_cfg, cfg, abstract=abstract)
        oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                              is_leaf=lambda s: isinstance(s, P))
        step = M.make_train_step(cfg, opt_cfg, mesh)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None))
        args = (abstract, opt_abstract, binputs)
    elif shape.kind == "prefill":
        step = M.make_prefill_step(cfg, mesh)
        jitted = jax.jit(step, in_shardings=(pshard, bshard),
                         out_shardings=None)
        args = (abstract, binputs)
    else:  # decode
        state_abstract = M.abstract_decode_state(
            cfg, shape.global_batch, shape.seq_len)
        sspecs = M.decode_state_specs(
            cfg, shape.global_batch, dp=dp_axes(mesh), dp_size=dp_size(mesh),
            cache_layout=os.environ.get("REPRO_CACHE_LAYOUT", "auto"),
            tp_size=mesh.shape["model"])
        sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                              is_leaf=lambda s: isinstance(s, P))
        step = M.make_serve_step(cfg, mesh)
        jitted = jax.jit(step, in_shardings=(pshard, sshard, bshard),
                         out_shardings=(None, None, sshard))
        args = (abstract, state_abstract, binputs)

    with mesh:
        lowered = jitted.lower(*args)
        result["lower_s"] = round(time.time() - t0, 2)
        if not compile_:
            result["status"] = "lowered"
            return result
        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        from ..jax_compat import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        result["memory"] = _mem_dict(mem)
        result["cost_analysis_raw"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and _keep_cost(k)}
        hlo = compiled.as_text()
        # trip-count-aware walk (XLA cost_analysis counts while bodies once)
        walked = hlo_walk.walk(hlo)
        result["walk"] = walked.as_dict()
        result["cost"] = {"flops": walked.flops, "bytes accessed": walked.bytes}
        result["collectives"] = dict(walked.collectives,
                                     total_bytes=walked.collective_bytes)
        result["hlo_ops"] = op_histogram(hlo)
        result["status"] = "ok"
        result.update(roofline_terms(result, cfg, shape, mesh))
    return result


def _keep_cost(k: str) -> bool:
    return k in ("flops", "bytes accessed", "transcendentals",
                 "utilization") or k.startswith("bytes accessed")


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("generated_code_size_in_bytes",
                 "argument_size_in_bytes", "output_size_in_bytes",
                 "alias_size_in_bytes", "temp_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    out["total_per_device"] = (out.get("argument_size_in_bytes", 0)
                               + out.get("output_size_in_bytes", 0)
                               + out.get("temp_size_in_bytes", 0)
                               - out.get("alias_size_in_bytes", 0))
    return out


_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
    re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in the optimized HLO.
    (Result shape ≈ operand shape for AR/AG outputs; a consistent proxy
    across ops — the §Roofline collective term divides by chip count so
    only relative magnitudes across candidate layouts matter.)"""
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo):
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def op_histogram(hlo: str) -> dict:
    """Counts of interesting ops (fusion inspection for §Perf)."""
    ops = {}
    for name in ("fusion", "dot", "convolution", "scatter", "gather",
                 "while", "sort", "rng", "copy", "transpose", "reshape"):
        ops[name] = len(re.findall(rf"= \S+ {name}\(", hlo))
    return ops


def roofline_terms(result: dict, cfg: ModelConfig, shape: M.ShapeSpec,
                   mesh) -> dict:
    chips = int(np.prod(list(mesh.shape.values())))
    flops = result["cost"].get("flops", 0.0)
    byts = result["cost"].get("bytes accessed", 0.0)
    coll = result["collectives"].get("total_bytes", 0)
    # cost_analysis is per-program (per device under SPMD)
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    mf = M.model_flops(cfg, shape)
    return {
        "roofline": {
            "chips": chips,
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops_total": mf,
            "model_flops_per_chip": mf / chips,
            "useful_flops_ratio": (mf / chips) / flops if flops else 0.0,
        }
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = ARTIFACTS) -> dict:
    multi = mesh_kind == "multi"
    try:
        res = lower_cell(arch, shape_name, multi)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        res = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    out_dir.mkdir(parents=True, exist_ok=True)
    mesh_name = res["mesh"]
    fn = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    fn.write_text(json.dumps(res, indent=1, default=str))
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    archs = all_arch_ids() if args.all or args.arch is None else [args.arch]
    shapes = list(M.SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    ok = bad = skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mk in meshes:
                mesh_name = "2x16x16" if mk == "multi" else "16x16"
                fn = ARTIFACTS / f"{arch}__{shape_name}__{mesh_name}.json"
                if args.skip_existing and fn.exists():
                    prev = json.loads(fn.read_text())
                    if prev.get("status") in ("ok", "skip"):
                        print(f"[cached] {arch} {shape_name} {mesh_name} "
                              f"{prev['status']}", flush=True)
                        ok += prev["status"] == "ok"
                        skip += prev["status"] == "skip"
                        continue
                t0 = time.time()
                res = run_cell(arch, shape_name, mk)
                dt = time.time() - t0
                st = res["status"]
                ok += st == "ok"
                bad += st == "error"
                skip += st == "skip"
                line = f"[{st:5s}] {arch:18s} {shape_name:12s} {mesh_name:8s} {dt:7.1f}s"
                if st == "ok":
                    r = res["roofline"]
                    line += (f" dom={r['dominant']:10s}"
                             f" tc={r['t_compute_s']:.3e}"
                             f" tm={r['t_memory_s']:.3e}"
                             f" tx={r['t_collective_s']:.3e}"
                             f" mem={res['memory']['total_per_device']/2**30:.1f}GiB")
                elif st == "error":
                    line += " " + res["error"][:160]
                print(line, flush=True)
    print(f"\nDRYRUN SUMMARY ok={ok} skip={skip} error={bad}", flush=True)
    return 0 if bad == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
