"""Serving launcher: build a wiki from a corpus, bring up the engine,
answer a query batch.

    PYTHONPATH=src python -m repro.launch.serve --queries 8
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.core.cache import TieredCache
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import ConstructionPipeline, PipelineConfig
from repro.data.corpus import AuthTraceConfig, generate_authtrace
from repro.data.tokenizer import HashTokenizer
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.runtime.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="wikikv-router")
    ap.add_argument("--queries", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    docs, questions = generate_authtrace(
        AuthTraceConfig(n_docs=60, n_questions=max(args.queries, 8),
                        seed=args.seed))
    oracle = HeuristicOracle()
    pipe = ConstructionPipeline(PipelineConfig(), oracle)
    pipe.bootstrap(docs)
    for i in range(0, len(docs), 16):
        pipe.ingest(docs[i:i + 16])

    cfg = get_config(args.arch)
    if cfg.d_model > 512:
        cfg = cfg.reduced()
    tok = HashTokenizer(vocab_size=cfg.vocab).fit([d["text"] for d in docs])
    params = M.init_params(cfg, seed=args.seed)
    cache = TieredCache(pipe.store, bus=pipe.bus)
    cache.prewarm()
    engine = ServingEngine(cfg, params, tok, pipe.store, oracle,
                           cache=cache, batch_size=args.batch_size,
                           max_len=256)
    reqs = [Request(rid=q.qid, query=q.text, max_new_tokens=8)
            for q in questions[: args.queries]]
    done = engine.run(reqs)
    for r in done:
        print(f"[{r.rid}] tool_calls={r.trace.tool_calls} "
              f"pages={r.trace.pages_read} nav={r.latency_s*1000:.1f}ms")
        print(f"    Q: {r.query}")
        print(f"    A: {r.answer[:160]}")
    print(f"cache hit-rate: {cache.stats.hit_rate():.2f}")
    return done


if __name__ == "__main__":
    main()
