"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization, and tests/benches must keep
seeing 1 device.

Mesh axes:
  single-pod : (16, 16)      → ("data", "model")
  multi-pod  : (2, 16, 16)   → ("pod", "data", "model")

"pod" is an extra data-parallel axis by default (gradient reduce crosses
the inter-pod links once per step — the cheapest thing to put there; see
EXPERIMENTS.md §Perf for the measured alternative of pipelining over it).
"""
from __future__ import annotations

import jax

from ..jax_compat import make_mesh as make_mesh_compat  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return make_mesh_compat((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
