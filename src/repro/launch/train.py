"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch wikikv-router \
        --steps 200 --batch 8 --seq 128

On this container it trains reduced/CPU-sized configs for real (the
examples use it); on a TPU pod the same entry point takes
``--mesh single|multi`` and the production mesh + shardings from
launch/mesh.py — the code path is identical, only the mesh differs.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.data.corpus import AuthTraceConfig, generate_authtrace
from repro.data.pipeline import DataPipeline
from repro.data.tokenizer import HashTokenizer
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def build_pipeline(vocab: int, seq_len: int, global_batch: int,
                   seed: int = 0):
    docs, _ = generate_authtrace(AuthTraceConfig(n_docs=200, seed=seed))
    tok = HashTokenizer(vocab_size=vocab).fit([d["text"] for d in docs])
    token_docs = [tok.encode(d["text"]) for d in docs]
    return DataPipeline(token_docs, seq_len=seq_len,
                        global_batch=global_batch, seed=seed), tok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="wikikv-router")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) config")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--checkpoint-dir", default="checkpoints/train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--opt-dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    pipeline, _ = build_pipeline(cfg.vocab, args.seq, args.batch)
    loop = TrainLoop(
        cfg,
        AdamWConfig(lr=3e-4, state_dtype=args.opt_dtype),
        TrainLoopConfig(total_steps=args.steps,
                        checkpoint_every=args.checkpoint_every,
                        checkpoint_dir=args.checkpoint_dir),
        pipeline, mesh=mesh)
    with mesh:
        metrics = loop.run()
    print(f"final loss {metrics.losses[-1]:.4f} "
          f"(first {metrics.losses[0]:.4f}) over {len(metrics.losses)} steps")
    return metrics


if __name__ == "__main__":
    main()
