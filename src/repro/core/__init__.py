"""WikiKV core: the paper's contribution as a composable library.

Import graph (bottom-up): paths → records → store → {backends, consistency,
cache, schema} → engine → {coldstart, evolution, errorbook} → pipeline →
navigate; tensorstore is the device-resident (JAX) realization of the same
contracts and engine.DeviceEngine the batched execution layer over it.
"""
from . import paths, records  # noqa: F401
from .store import DictKV, KVEngine, MemKV, PathStore  # noqa: F401
from .engine import (BatchPlanner, DeviceEngine, EngineStats,  # noqa: F401
                     HostEngine, QueryEngine, ShardedPathStore)
from .consistency import (CASConflict, ConsistentReader, Invalidation,  # noqa: F401
                          InvalidationBus, WikiWriter)
from .cache import TieredCache  # noqa: F401
from .schema import SchemaParams, schema_cost, structure_counts  # noqa: F401
from .oracle import HeuristicOracle, Oracle  # noqa: F401
from .coldstart import cold_start, ingestion_filter  # noqa: F401
from .evolution import AccessLog, CoAccessSketch, evolution_pass  # noqa: F401
from .errorbook import ErrorBook, run_errorbook  # noqa: F401
from .pipeline import ConstructionPipeline, PipelineConfig  # noqa: F401
from .navigate import (Navigator, NavResult, NavTrace, UnitBudget,  # noqa: F401
                       WallClockBudget, check_progressive)
