"""Three-tier path-keyed cache (paper §V-C).

L1 — in-process, tens of pages: the root index and every dimension node.
     Pre-warmed, never expired during process lifetime, refreshed on
     invalidation events.
L2 — shared tier (the paper's Redis), thousands of pages: full directory
     set + hot entities by ``access_count``.  LRU with TTL so displaced
     pages are reclaimed even without explicit invalidation.
L3 — the persistent PathStore: authoritative, no expiration (staleness is
     handled actively by invalidation + Error Book, not passive expiry).

TPU mapping (DESIGN.md §3): L1 = device-pinned tensor rows of the
tensorstore; L2 = host-RAM shared table; L3 = persistent store.  The
host-side implementation here is the protocol reference; the tensorstore
carries the same L1 contract on device.

Invalidation: subscribes to the ``InvalidationBus``; an event for path p
refreshes every cached entry whose key equals p or has p as a segment
prefix.  Because Theorem 2 rules out advertised-but-missing children in
the underlying store, a racing invalidation costs at most one extra L3
round trip and can never surface a partial write (paper §V-C).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Optional

from . import paths as P
from . import records as R
from .consistency import Invalidation, InvalidationBus
from .store import PathStore


@dataclass
class CacheStats:
    l1_hits: int = 0
    l2_hits: int = 0
    l3_hits: int = 0
    misses: int = 0
    invalidations: int = 0
    evictions: int = 0

    def hit_rate(self) -> float:
        total = self.l1_hits + self.l2_hits + self.l3_hits + self.misses
        return 0.0 if total == 0 else (self.l1_hits + self.l2_hits) / total


class LruTtl:
    """LRU + TTL map (the L2 policy)."""

    def __init__(self, capacity: int, ttl: float,
                 clock: Callable[[], float] = time.time):
        self.capacity = capacity
        self.ttl = ttl
        self.clock = clock
        self._d: "OrderedDict[str, tuple[float, bytes]]" = OrderedDict()
        self.evictions = 0

    def get(self, key: str) -> Optional[bytes]:
        item = self._d.get(key)
        if item is None:
            return None
        ts, val = item
        if self.clock() - ts > self.ttl:
            del self._d[key]
            return None
        self._d.move_to_end(key)
        return val

    def put(self, key: str, val: bytes) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = (self.clock(), val)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def drop(self, key: str) -> None:
        self._d.pop(key, None)

    def keys(self) -> list[str]:
        return list(self._d.keys())

    def __len__(self) -> int:
        return len(self._d)


class TieredCache:
    """L1/L2/L3 read path with path-keyed invalidation."""

    def __init__(self, store: PathStore, bus: InvalidationBus | None = None,
                 l1_capacity: int = 64, l2_capacity: int = 4096,
                 l2_ttl: float = 3600.0,
                 clock: Callable[[], float] = time.time):
        self.store = store
        self.l1: dict[str, bytes] = {}
        self.l1_capacity = l1_capacity
        self.l2 = LruTtl(l2_capacity, l2_ttl, clock=clock)
        self.stats = CacheStats()
        if bus is not None:
            bus.subscribe(self._on_invalidate)

    # ------------------------------------------------------------------
    def prewarm(self) -> int:
        """Load the root and every dimension node into L1 (paper: pre-warmed
        at process start)."""
        n = 0
        root = self.store.get(P.ROOT)
        if root is None:
            return 0
        self.l1[P.ROOT] = R.encode(root)
        n += 1
        if isinstance(root, R.DirRecord):
            for seg in root.children():
                dp = P.child(P.ROOT, seg)
                rec = self.store.get(dp)
                if rec is not None and len(self.l1) < self.l1_capacity:
                    self.l1[dp] = R.encode(rec)
                    n += 1
        return n

    def get(self, path: str) -> Optional[R.Record]:
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        raw = self.l1.get(path)
        if raw is not None:
            self.stats.l1_hits += 1
            return R.decode(raw)
        raw = self.l2.get(path)
        if raw is not None:
            self.stats.l2_hits += 1
            return R.decode(raw)
        rec = self.store.get(path)
        if rec is None:
            self.stats.misses += 1
            return None
        self.stats.l3_hits += 1
        self._promote(path, rec)
        return rec

    def ls(self, path: str) -> Optional[tuple[R.DirRecord, list[str]]]:
        rec = self.get(path)
        if rec is None or not isinstance(rec, R.DirRecord):
            return None
        return rec, [P.child(path, s) for s in rec.children()]

    # -- split read path for the batched engine (core/engine.py) ----------
    def peek(self, path: str) -> Optional[R.Record]:
        """L1/L2 probe only — never touches L3.  A ``None`` means "not
        cached": the caller routes the miss through its batched engine and
        reports the result back via ``admit``."""
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        raw = self.l1.get(path)
        if raw is not None:
            self.stats.l1_hits += 1
            return R.decode(raw)
        raw = self.l2.get(path)
        if raw is not None:
            self.stats.l2_hits += 1
            return R.decode(raw)
        return None

    def admit(self, path: str, rec: Optional[R.Record]) -> None:
        """Account + promote an engine-resolved read (the L3 half of
        ``get`` when the fetch itself ran through a batched engine)."""
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        if rec is None:
            self.stats.misses += 1
            return
        self.stats.l3_hits += 1
        self._promote(path, rec)

    def _promote(self, path: str, rec: R.Record) -> None:
        raw = R.encode(rec)
        # L1 is reserved for the root + dimension working set
        if P.depth(path) <= 1 and len(self.l1) < self.l1_capacity:
            self.l1[path] = raw
        else:
            self.l2.put(path, raw)

    # ------------------------------------------------------------------
    def _on_invalidate(self, ev: Invalidation) -> None:
        """Refresh any L1/L2 entry whose key equals, or is an ancestor of,
        the affected path; and drop descendants of the affected path."""
        self.stats.invalidations += 1
        affected = ev.path
        # exact + descendant keys in L1
        for key in list(self.l1.keys()):
            if key == affected or P.is_prefix(affected, key) or P.is_prefix(key, affected):
                rec = self.store.get(key)
                if rec is None:
                    del self.l1[key]
                else:
                    self.l1[key] = R.encode(rec)
        for key in self.l2.keys():
            if key == affected or P.is_prefix(affected, key):
                self.l2.drop(key)

    def memory_footprint(self) -> dict[str, int]:
        """Resident bytes per in-memory tier — the 'bounded footprint'
        claim of §V-C is asserted against these in tests."""
        l1 = sum(len(v) for v in self.l1.values())
        l2 = sum(len(v) for _, (_, v) in self.l2._d.items())
        return {"l1_bytes": l1, "l2_bytes": l2,
                "l1_entries": len(self.l1), "l2_entries": len(self.l2)}
