"""Schema design as constrained optimization (paper §III-B, Eq. 1).

    C(S;W) = α·|V| + β·Σ_v depth(v)·ρ(v) − γ·Q(S;W)

subject to depth(v) ≤ D and |children(v)| ≤ k_max.

* |V|            — size of the materialized KV namespace (storage term).
* Σ depth·ρ      — access-weighted traversal cost (online-latency term);
                   ρ is the access distribution estimated from the
                   ``access_count`` meta co-located with every record
                   (paper: "no external usage log required").
* Q(S;W)         — answer quality.  The *true* Q is end-to-end AC measured
                   by the workload (§VI); the Critic's surrogate Q̃ used
                   during evolution is the access-weighted confidence of
                   file records (paper Eq. 3).

The greedy local search of §III-D applies node-disjoint admissible
operators; Theorem 1 (monotone improvement) is property-tested in
tests/test_evolution.py against this exact cost function.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from . import paths as P
from . import records as R
from .store import PathStore


@dataclass(frozen=True)
class SchemaParams:
    """Deployment-time hyperparameters of Eq. 1 + structural constraints."""

    alpha: float = 1.0
    beta: float = 4.0
    gamma: float = 8.0
    depth_budget: int = P.DEFAULT_DEPTH_BUDGET
    k_max: int = 64           # per-node fan-out bound
    l_max: int = 4000         # PageSplit length trigger (chars)
    theta_merge: float = 0.08  # DimensionMerge MI threshold
    commit_cap: int = 4        # K: per-pass commit count cap


@dataclass
class CostBreakdown:
    storage: float = 0.0       # α|V|
    descent: float = 0.0       # βΣ depth·ρ
    quality: float = 0.0       # γQ̃
    n_nodes: int = 0
    violations: list[str] = field(default_factory=list)

    @property
    def total(self) -> float:
        return self.storage + self.descent - self.quality


def access_distribution(store: PathStore,
                        skip_sources: bool = True) -> dict[str, float]:
    """ρ(v) from co-located access_count meta; uniform fallback when the
    wiki has never been queried."""
    counts: dict[str, int] = {}
    for path in store.all_paths():
        if skip_sources and P.is_reserved(path):
            continue
        rec = store.get(path)
        if rec is None:
            continue
        counts[path] = rec.meta.access_count
    total = sum(counts.values())
    if total == 0:
        n = max(len(counts), 1)
        return {p: 1.0 / n for p in counts}
    return {p: c / total for p, c in counts.items()}


def quality_surrogate(store: PathStore, rho: dict[str, float]) -> float:
    """Q̃: access-weighted mean confidence over file records (Critic, Eq. 3)."""
    num = den = 0.0
    for path, w in rho.items():
        rec = store.get(path)
        if isinstance(rec, R.FileRecord):
            num += w * rec.meta.confidence
            den += w
    return num / den if den > 0 else 0.0


def schema_cost(store: PathStore, params: SchemaParams,
                quality: float | None = None) -> CostBreakdown:
    """Evaluate Eq. 1 over the materialized wiki (sources subtree excluded —
    it is hoisted shared storage, not schema shape; §IV-A)."""
    rho = access_distribution(store)
    n_nodes = 0
    descent = 0.0
    violations: list[str] = []
    for path in store.all_paths():
        if P.is_reserved(path):
            continue
        n_nodes += 1
        d = P.depth(path)
        if d > params.depth_budget:
            violations.append(f"depth({path})={d} > D={params.depth_budget}")
        descent += d * rho.get(path, 0.0)
        rec = store.get(path)
        if isinstance(rec, R.DirRecord):
            fan = len(rec.children())
            if fan > params.k_max:
                violations.append(f"fanout({path})={fan} > k_max={params.k_max}")
    q = quality if quality is not None else quality_surrogate(store, rho)
    return CostBreakdown(
        storage=params.alpha * n_nodes,
        descent=params.beta * descent,
        quality=params.gamma * q,
        n_nodes=n_nodes,
        violations=violations,
    )


def structure_counts(store: PathStore) -> dict[str, int]:
    """Directory/page/source counts (the Fig. 5(a) quantities)."""
    dirs = pages = digests = docs = 0
    for path in store.all_paths():
        if P.is_prefix(P.META_PREFIX, path):
            continue
        t = P.node_type(path)
        rec = store.get(path)
        if rec is None:
            continue
        if t == P.NODE_DIGEST:
            digests += 1
        elif t == P.NODE_DOCUMENT:
            docs += 1
        elif isinstance(rec, R.DirRecord):
            dirs += 1
        else:
            pages += 1
    return {"directories": dirs, "pages": pages,
            "digests": digests, "documents": docs}
