"""Value schema (paper §IV-B).

Internal nodes (Index, Dimension) are *directory records*; leaves (Entity,
Digest, Document) are *file records*.  Directory records co-locate the child
lists so that ``LS(π) ≡ GET(π)`` — a single point lookup, no prefix scan.

Meta counters (``access_count``, ``confidence``, ``last_verified``,
``version``) are unused by the storage operators but feed the
schema-evolution operators of core/evolution.py, exactly as §IV-B notes.

Records serialize to a compact, deterministic JSON encoding (sorted keys) so
that byte-level equality == logical equality, which the OCC tests rely on.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Any

DIR_TYPE = "dir"
FILE_TYPE = "file"


@dataclass
class DirMeta:
    updated_at: float = 0.0
    entry_count: int = 0
    access_count: int = 0

    def to_obj(self) -> dict[str, Any]:
        return {
            "updated_at": self.updated_at,
            "entry_count": self.entry_count,
            "access_count": self.access_count,
        }

    @classmethod
    def from_obj(cls, o: dict[str, Any]) -> "DirMeta":
        return cls(
            updated_at=float(o.get("updated_at", 0.0)),
            entry_count=int(o.get("entry_count", 0)),
            access_count=int(o.get("access_count", 0)),
        )


@dataclass
class FileMeta:
    version: int = 0
    confidence: float = 1.0
    sources: list[str] = field(default_factory=list)
    last_verified: float = 0.0
    access_count: int = 0

    def to_obj(self) -> dict[str, Any]:
        return {
            "version": self.version,
            "confidence": self.confidence,
            "sources": list(self.sources),
            "last_verified": self.last_verified,
            "access_count": self.access_count,
        }

    @classmethod
    def from_obj(cls, o: dict[str, Any]) -> "FileMeta":
        return cls(
            version=int(o.get("version", 0)),
            confidence=float(o.get("confidence", 1.0)),
            sources=list(o.get("sources", [])),
            last_verified=float(o.get("last_verified", 0.0)),
            access_count=int(o.get("access_count", 0)),
        )


@dataclass
class DirRecord:
    """type="dir": name + two parallel child arrays + meta statistics."""

    name: str
    sub_dirs: list[str] = field(default_factory=list)
    files: list[str] = field(default_factory=list)
    meta: DirMeta = field(default_factory=DirMeta)
    # optional summary payload shown at index/dimension level by NAV r1/r2
    summary: str = ""

    type: str = DIR_TYPE

    def children(self) -> list[str]:
        """Ordered child *segments* (dirs first, then files) — the directory
        listing contract of Q2."""
        return list(self.sub_dirs) + list(self.files)

    def with_child(self, segment: str, *, is_dir: bool) -> "DirRecord":
        """Functional append used by the parent-after-child writer."""
        sd, fl = list(self.sub_dirs), list(self.files)
        target = sd if is_dir else fl
        if segment not in target:
            target.append(segment)
        meta = replace(self.meta, entry_count=len(sd) + len(fl))
        return replace(self, sub_dirs=sd, files=fl, meta=meta)

    def without_child(self, segment: str) -> "DirRecord":
        sd = [s for s in self.sub_dirs if s != segment]
        fl = [s for s in self.files if s != segment]
        meta = replace(self.meta, entry_count=len(sd) + len(fl))
        return replace(self, sub_dirs=sd, files=fl, meta=meta)

    def to_bytes(self) -> bytes:
        return _enc({
            "type": DIR_TYPE,
            "name": self.name,
            "sub_dirs": self.sub_dirs,
            "files": self.files,
            "summary": self.summary,
            "meta": self.meta.to_obj(),
        })


@dataclass
class FileRecord:
    """type="file": name + UTF-8 payload + meta (version is the OCC token)."""

    name: str
    text: str = ""
    meta: FileMeta = field(default_factory=FileMeta)

    type: str = FILE_TYPE

    def to_bytes(self) -> bytes:
        return _enc({
            "type": FILE_TYPE,
            "name": self.name,
            "text": self.text,
            "meta": self.meta.to_obj(),
        })


Record = DirRecord | FileRecord


def _enc(obj: dict[str, Any]) -> bytes:
    return json.dumps(obj, sort_keys=True, ensure_ascii=False,
                      separators=(",", ":")).encode("utf-8")


def decode(data: bytes) -> Record:
    o = json.loads(data.decode("utf-8"))
    t = o.get("type")
    if t == DIR_TYPE:
        return DirRecord(
            name=o.get("name", ""),
            sub_dirs=list(o.get("sub_dirs", [])),
            files=list(o.get("files", [])),
            summary=o.get("summary", ""),
            meta=DirMeta.from_obj(o.get("meta", {})),
        )
    if t == FILE_TYPE:
        return FileRecord(
            name=o.get("name", ""),
            text=o.get("text", ""),
            meta=FileMeta.from_obj(o.get("meta", {})),
        )
    raise ValueError(f"unknown record type {t!r}")


def encode(rec: Record) -> bytes:
    return rec.to_bytes()
