"""Content-level self-correction: the Error Book (paper §III-D/§III-E).

While DIMENSIONMERGE / PAGESPLIT act on the structural shape of the
namespace, the Error Book acts on individual record contents.  Detected
error patterns accumulate as *constraint rules* that are (a) injected into
subsequent ingestion (the ingestor consults them to avoid re-introducing
known errors) and (b) repaired by a two-layer loop: deterministic
code-level fixes after every batch, plus a periodic oracle-based fix.

State is persisted at the reserved path ``/_meta/errorbook`` — the same
path-keyed records as everything else — so constraints accumulated in
earlier full/incremental runs keep taking effect in later ones (the
re-grounding this paper contributes).

Error patterns detected:
  * dangling_wikilink      — ``[[/path]]`` links whose target record is ⊥
  * malformed_citation     — meta.sources entries outside /sources/…
  * unsupported_fact       — ``fact: k=v`` lines on a page with no sources
  * cross_page_contradiction — the same fact key bound to different values
                               on different pages
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field, replace

from . import paths as P
from . import records as R
from .consistency import WikiWriter
from .oracle import Oracle
from .store import PathStore

ERRORBOOK_PATH = "/_meta/errorbook"

_WIKILINK_RE = re.compile(r"\[\[(/[^\]\s]+)\]\]")
# value stops at whitespace/;/. so sentence punctuation never becomes part
# of the binding ("=twelve" vs "=twelve." is not a contradiction)
_FACT_RE = re.compile(r"fact:\s*([a-z0-9_]+)\s*=\s*([^\s;.]+)", re.I)


@dataclass
class ErrorBook:
    """Constraint rules + error tallies, persisted across runs."""

    rules: list[str] = field(default_factory=list)
    bad_link_targets: list[str] = field(default_factory=list)
    fact_bindings: dict[str, str] = field(default_factory=dict)
    tallies: dict[str, int] = field(default_factory=dict)
    repairs: dict[str, int] = field(default_factory=dict)

    def add_rule(self, rule: str) -> None:
        if rule not in self.rules:
            self.rules.append(rule)

    def tally(self, kind: str, n: int = 1) -> None:
        self.tallies[kind] = self.tallies.get(kind, 0) + n

    def repaired(self, kind: str, n: int = 1) -> None:
        self.repairs[kind] = self.repairs.get(kind, 0) + n

    # -- persistence ----------------------------------------------------
    # ``store`` may be a PathStore or a WikiWriter; the writer path also
    # publishes the invalidation so the device mirror/cache stay fresh.
    def save(self, store) -> None:
        store.put_record(ERRORBOOK_PATH, R.FileRecord(
            name="errorbook",
            text=json.dumps({
                "rules": self.rules,
                "bad_link_targets": self.bad_link_targets,
                "fact_bindings": self.fact_bindings,
                "tallies": self.tallies,
                "repairs": self.repairs,
            }, sort_keys=True)))

    @classmethod
    def load(cls, store: PathStore) -> "ErrorBook":
        rec = store.get(ERRORBOOK_PATH)
        if rec is None or not isinstance(rec, R.FileRecord) or not rec.text:
            return cls()
        o = json.loads(rec.text)
        return cls(rules=o.get("rules", []),
                   bad_link_targets=o.get("bad_link_targets", []),
                   fact_bindings=o.get("fact_bindings", {}),
                   tallies=o.get("tallies", {}),
                   repairs=o.get("repairs", {}))

    # -- ingestion-prompt injection --------------------------------------
    def ingestion_constraints(self) -> list[str]:
        """Rules surfaced to the ingestor (the paper injects these into
        subsequent ingestion prompts)."""
        return list(self.rules)


@dataclass
class ErrorReport:
    found: dict[str, list[str]] = field(default_factory=dict)

    def add(self, kind: str, where: str) -> None:
        self.found.setdefault(kind, []).append(where)

    @property
    def total(self) -> int:
        return sum(len(v) for v in self.found.values())


def detect_errors(store: PathStore, book: ErrorBook) -> ErrorReport:
    report = ErrorReport()
    fact_seen: dict[str, tuple[str, str]] = dict()  # key -> (value, path)
    for path in store.all_paths():
        if P.is_prefix(P.META_PREFIX, path):
            continue
        rec = store.get(path)
        if not isinstance(rec, R.FileRecord):
            continue
        # dangling wikilinks
        for target in _WIKILINK_RE.findall(rec.text):
            try:
                tnorm = P.normalize(target, depth_budget=None)
            except P.PathError:
                report.add("dangling_wikilink", f"{path} -> {target}")
                continue
            if store.get(tnorm) is None:
                report.add("dangling_wikilink", f"{path} -> {tnorm}")
        # malformed citations
        for src in rec.meta.sources:
            if not P.is_prefix(P.SOURCES_PREFIX, src):
                report.add("malformed_citation", f"{path} :: {src}")
        # unsupported facts
        facts = _FACT_RE.findall(rec.text)
        if facts and not rec.meta.sources and not P.is_prefix(P.SOURCES_PREFIX, path):
            report.add("unsupported_fact", path)
        # cross-page contradictions
        for k, v in facts:
            if k in fact_seen and fact_seen[k][0] != v:
                report.add("cross_page_contradiction",
                           f"{k}: {fact_seen[k][1]}={fact_seen[k][0]} vs {path}={v}")
            else:
                fact_seen.setdefault(k, (v, path))
    for kind, items in report.found.items():
        book.tally(kind, len(items))
    return report


def deterministic_repair(writer: WikiWriter, book: ErrorBook,
                         report: ErrorReport) -> int:
    """Code-level fixes, run after every ingestion batch (paper §III-E)."""
    store = writer.store
    fixed = 0
    # drop dangling links + record constraint rules
    for item in report.found.get("dangling_wikilink", []):
        path, _, target = item.partition(" -> ")
        rec = store.get(path)
        if not isinstance(rec, R.FileRecord):
            continue
        new_text = rec.text.replace(f"[[{target}]]", target.rsplit("/", 1)[-1])
        if new_text != rec.text:
            writer.put_record(path, replace(rec, text=new_text))
            fixed += 1
        if target not in book.bad_link_targets:
            book.bad_link_targets.append(target)
        book.add_rule(f"do-not-link:{target}")
    # strip malformed citations
    for item in report.found.get("malformed_citation", []):
        path, _, src = item.partition(" :: ")
        rec = store.get(path)
        if not isinstance(rec, R.FileRecord):
            continue
        writer.put_record(path, replace(
            rec, meta=replace(rec.meta,
                              sources=[s for s in rec.meta.sources
                                       if P.is_prefix(P.SOURCES_PREFIX, s)])))
        book.add_rule("citations-must-be-source-paths")
        fixed += 1
    # unsupported facts: demote confidence (repair happens at LLM layer)
    for path in report.found.get("unsupported_fact", []):
        rec = store.get(path)
        if not isinstance(rec, R.FileRecord):
            continue
        writer.put_record(path, replace(
            rec, meta=replace(rec.meta,
                              confidence=min(rec.meta.confidence, 0.3))))
        book.add_rule("facts-require-citations")
        fixed += 1
    book.repaired("deterministic", fixed)
    return fixed


def llm_repair(writer: WikiWriter, oracle: Oracle, book: ErrorBook,
               report: ErrorReport) -> int:
    """Periodic oracle-based fix loop: resolve contradictions by re-deriving
    the fact from the cited sources (majority of source support wins)."""
    store = writer.store
    fixed = 0
    for item in report.found.get("cross_page_contradiction", []):
        # "k: p1=v1 vs p2=v2" — keep the binding supported by more sources
        head, _, rest = item.partition(": ")
        left, _, right = rest.partition(" vs ")
        p1, v1 = left.rsplit("=", 1)
        p2, v2 = right.rsplit("=", 1)
        r1, r2 = store.get(p1), store.get(p2)
        if not (isinstance(r1, R.FileRecord) and isinstance(r2, R.FileRecord)):
            continue
        keep_first = len(r1.meta.sources) >= len(r2.meta.sources)
        loser_path, loser, good_v = (
            (p2, r2, v1) if keep_first else (p1, r1, v2))
        bad_v = v2 if keep_first else v1
        new_text = loser.text.replace(
            f"fact: {head}={bad_v}", f"fact: {head}={good_v}")
        if new_text != loser.text:
            def _mut(r, t=new_text):
                return replace(r, text=t)
            writer.update_file(loser_path, _mut)
            fixed += 1
        book.fact_bindings[head] = good_v
        book.add_rule(f"fact-binding:{head}={good_v}")
    book.repaired("llm", fixed)
    return fixed


def run_errorbook(writer: WikiWriter, oracle: Oracle,
                  with_llm_pass: bool = False) -> tuple[ErrorBook, ErrorReport]:
    """One Error Book cycle: load persisted state, detect, repair, persist."""
    book = ErrorBook.load(writer.store)
    report = detect_errors(writer.store, book)
    deterministic_repair(writer, book, report)
    if with_llm_pass:
        llm_repair(writer, oracle, book, report)
    book.save(writer)
    return book, report
