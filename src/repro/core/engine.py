"""Unified batched query-execution layer: PathStore → Pallas kernels.

One engine abstraction serves every Q1–Q4 operation of the online tier,
batched (DESIGN goal: the paper's "O(1) storage round trips per query"
realized as "O(1) engine calls per *batch* of queries"):

* ``QueryEngine``   — the batched operator contract.  Every method takes a
  whole batch and counts as ONE round trip regardless of batch size; the
  per-call batch sizes are tracked in ``EngineStats`` so benchmarks can
  report amortization directly.

* ``HostEngine``    — wraps a ``PathStore`` (or the digest-range
  ``ShardedPathStore`` below).  Round trips execute on the host against
  the LSM engine(s); batching amortizes the python/op dispatch overhead
  and gives the planner a single choke point to count.

* ``DeviceEngine``  — wraps a frozen ``TensorWiki``: Q1 point lookups and
  Q4 prefix scans dispatch through ``kernels.ops`` to the Pallas kernels
  (pure-jnp reference off-TPU), Q2 is one batched lookup whose child
  listing derives from the resolved directory record, Q3 flattens the
  whole batch's ancestor chains into one lookup launch, and keyword
  containment runs as a Q1-style lookup into a device token-digest
  table + CSR slice — the inverted index, tensorized.  Record payloads
  live in a host-side row table (the stand-in for HBM payload rows).

* ``BatchPlanner``  — collects the operations of many concurrent
  navigation sessions into per-operator batches; ``flush()`` executes each
  operator's pending batch in one engine call and resolves the futures.
  This is continuous batching for storage ops, mirroring the serving
  engine's token batching.

* **Online writes** (ISSUE 2) ride the same waves: ``planner.admit/
  update/unlink`` → batched ``admit_many``/``update_many``/``unlink_many``
  round trips through the §IV-C ``WikiWriter`` (CAS + invalidation).  A
  flush runs reads before writes and ``refresh()`` commits between waves,
  so every read wave pins one epoch (staleness Δ = 1 wave); the
  ``DeviceEngine`` refreshes incrementally via ``tensorstore.apply_delta``
  instead of re-freezing.

Parity contract (tested in tests/test_engine.py): for any store state
reachable through the §IV-C write protocol, ``HostEngine`` and
``DeviceEngine`` frozen from the same store return identical results for
every Q1–Q4 batch, including misses and unadvertised orphans.
"""
from __future__ import annotations

import bisect
import heapq
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional, Sequence

import numpy as np

from .. import obs
from . import paths as P
from . import records as R
from .consistency import (CASConflict, InvalidationBus, WikiWriter,
                          attach_journal)
from .executor import CommitSequencer, ShardExecutor, resolve_commit_pipeline
from .store import KVEngine, MemKV, PathStore, _segment_tokens

# operator names used for stats keys
Q1, Q2, Q3, Q4, Q4C = "q1_get", "q2_ls", "q3_navigate", "q4_search", "q4_contains"
# write operators (batched through the same planner/engine round trips)
W_ADMIT, W_UPDATE, W_UNLINK = "w_admit", "w_update", "w_unlink"
# epoch refresh accounting (rows applied per refresh)
REFRESH = "refresh"
READ_OPS = (Q1, Q2, Q3, Q4, Q4C)
WRITE_OPS = (W_ADMIT, W_UPDATE, W_UNLINK)
# durable-tier read-path counters surfaced through QueryEngine.stats
# (``stats.ops[...]`` is the running count; fed by sync_durable_stats)
D_BLOOM_NEG = "d_bloom_neg"     # segment probes skipped by a bloom negative
D_CACHE_HIT = "d_cache_hit"     # block-cache hits on segment point reads
D_CACHE_MISS = "d_cache_miss"   # block-cache misses (block parsed off mmap)
D_SEG_PROBE = "d_seg_probe"     # segments considered per point read (the
                                # partitioned-level acceptance counter)
D_COMPACT_DEBT = "d_compact_debt"   # GAUGE, not a counter: outstanding
                                    # merge bytes — the backpressure signal
D_PIPELINE_DEPTH = "d_commit_pipeline_depth"  # GAUGE: sealed-but-not-durable
                                              # commit waves in flight (0/1)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------
@dataclass
class EngineStats:
    """Per-operator accounting — the amortization evidence.

    ``calls``/``ops``/``max_batch`` count *unique keys per engine call*
    (what the engine actually executed).  ``served``/``max_served`` count
    *logical operations resolved per call* as reported by the planner:
    identical ops from concurrent sessions share one batch slot, so one
    engine call can serve far more lookups than it executes keys."""

    calls: dict[str, int] = field(default_factory=dict)
    ops: dict[str, int] = field(default_factory=dict)
    max_batch: dict[str, int] = field(default_factory=dict)
    served: dict[str, int] = field(default_factory=dict)
    max_served: dict[str, int] = field(default_factory=dict)

    def record(self, op: str, batch: int) -> None:
        if batch <= 0:
            return
        self.calls[op] = self.calls.get(op, 0) + 1
        self.ops[op] = self.ops.get(op, 0) + batch
        self.max_batch[op] = max(self.max_batch.get(op, 0), batch)

    def record_served(self, op: str, n: int) -> None:
        if n <= 0:
            return
        self.served[op] = self.served.get(op, 0) + n
        self.max_served[op] = max(self.max_served.get(op, 0), n)

    def total_calls(self) -> int:
        return sum(self.calls.values())

    def total_ops(self) -> int:
        return sum(self.ops.values())

    def reset(self) -> None:
        for d in (self.calls, self.ops, self.max_batch,
                  self.served, self.max_served):
            d.clear()


# ---------------------------------------------------------------------------
# the batched operator contract
# ---------------------------------------------------------------------------
class QueryEngine:
    """Batched Q1–Q4 execution plus the batched write path.

    One method call == one storage round trip.  Write batches route
    through a ``WikiWriter`` (parent-after-child admission, reverse-order
    unlink, OCC CAS updates, invalidation publishes), so every §IV-C
    guarantee holds for engine-mediated writes too.

    **Epoch contract** — ``epoch`` is a monotone counter of committed
    write generations.  The planner executes a wave's read batches before
    its write batches, and ``refresh()`` (called by the wave driver
    *between* waves) commits visibility.  Upper bound both tiers share:
    a write admitted in wave k is visible to every read of wave k+1
    (Δ = 1 wave).  The lower bound (no read of wave k sees wave-k
    writes) is snapshot-exact on ``DeviceEngine`` — its tensors are
    frozen until ``refresh()``, so even a multi-round wave pins one
    epoch.  ``HostEngine`` reads hit the live store, so the lower bound
    holds per *flush* (round) only: a later round of the same wave may
    already observe an earlier round's admissions.  That is the paper's
    host-tier semantics — Theorem 2 (no partial reads) still holds for
    every interleaving via the write protocol itself, which is what the
    host-side property tests assert.
    """

    def __init__(self):
        self.stats = EngineStats()
        self.epoch = 0
        self.writer: WikiWriter | None = None
        self._pending_writes = 0

    # -- reads -------------------------------------------------------------
    def q1_get(self, paths: Sequence[str]) -> list[Optional[R.Record]]:
        """Point lookup: one record (or None) per path, order-preserving."""
        raise NotImplementedError

    def q2_ls(self, paths: Sequence[str]
              ) -> list[Optional[tuple[R.DirRecord, list[str]]]]:
        """Directory listing: (dir record, sorted child names) per path,
        None where the path is absent or not a directory."""
        raise NotImplementedError

    def q3_navigate(self, paths: Sequence[str]) -> list[list[R.Record]]:
        """Ancestor chain root→leaf per path (empty if the leaf is absent)."""
        raise NotImplementedError

    def q4_search(self, prefixes: Sequence[str],
                  limit: int | None = None) -> list[list[str]]:
        """Prefix scan over the ordered path namespace, ``limit`` per prefix."""
        raise NotImplementedError

    def q4_contains(self, tokens: Sequence[str],
                    limit: int | None = None) -> list[list[str]]:
        """Inverted-index token search: matching paths per token."""
        raise NotImplementedError

    # -- writes ------------------------------------------------------------
    def _require_writer(self) -> WikiWriter:
        if self.writer is None:
            raise RuntimeError(
                f"{type(self).__name__} has no writer attached — "
                "construct it with a backing store to enable writes")
        return self.writer

    def admit_many(self, items: Sequence[tuple[str, R.Record]]
                   ) -> list[R.Record | Exception]:
        """One batched admission round trip.  Items apply parents-first
        (depth order, stable) so a parent and its child admitted in the
        same wave never race the auto-created parent chain.  A per-item
        validation failure (depth budget, malformed path, non-directory
        parent) resolves to the exception instead of poisoning the batch."""
        w = self._require_writer()
        self.stats.record(W_ADMIT, len(items))
        budget = w.store.depth_budget
        out: list[R.Record | Exception] = [rec for _, rec in items]
        order = sorted(range(len(items)), key=lambda i: P.depth(items[i][0]))
        for i in order:
            path, rec = items[i]
            try:
                if P.normalize(path, depth_budget=budget) == P.ROOT:
                    w.put_record(P.ROOT, rec)
                else:
                    w.admit(path, rec)
            except (P.PathError, ValueError) as e:
                out[i] = e
        self._note_writes(len(items))
        return out

    def update_many(self, updates: Sequence[
            tuple[str, Callable[[R.FileRecord], R.FileRecord]]],
            max_retries: int = 8) -> list[R.Record | CASConflict]:
        """One batched OCC round trip: each (path, mutate) runs the
        writer's version-CAS loop; a conflict that exhausts its retries
        resolves to the ``CASConflict`` instance instead of raising, so
        one stale page never poisons the rest of the batch."""
        w = self._require_writer()
        self.stats.record(W_UPDATE, len(updates))
        out: list[R.Record | Exception] = []
        for path, mutate in updates:
            try:
                out.append(w.update_file(path, mutate,
                                         max_retries=max_retries))
            except (CASConflict, KeyError, P.PathError) as e:
                # KeyError: no file record at the path (e.g. unlinked by
                # an earlier run of this same wave) — a per-item outcome,
                # like an exhausted CAS, not a batch failure
                out.append(e)
        self._note_writes(len(updates))
        return out

    def unlink_many(self, paths: Sequence[str]
                    ) -> list[bool | P.PathError]:
        """One batched unlink round trip, deepest-first so a subtree and
        its root unlinked in the same wave stay parent-link-consistent.
        Returns, per path, whether a record existed; an invalid unlink
        (the root — it has no parent to unlink from) resolves to the
        ``PathError`` instead of poisoning the batch."""
        w = self._require_writer()
        self.stats.record(W_UNLINK, len(paths))
        out: list[bool | P.PathError] = [False] * len(paths)
        order = sorted(range(len(paths)), key=lambda i: -P.depth(paths[i]))
        for i in order:
            try:
                out[i] = w.get(paths[i]) is not None
                w.unlink(paths[i])
            except P.PathError as e:
                out[i] = e
        self._note_writes(len(paths))
        return out

    # -- epoch refresh -----------------------------------------------------
    def _note_writes(self, n: int) -> None:
        if n > 0:
            self._pending_writes += n

    def _backing_store(self):
        store = getattr(self, "store", None)
        if store is None and self.writer is not None:
            store = self.writer.store
        return store

    def _restore_epoch(self) -> None:
        """Rehydrate the epoch counter from a durable store's last WAL
        commit (0 on volatile stores) — called at construction so an
        engine reopened over an existing directory resumes the committed
        epoch sequence instead of restarting at 0."""
        store = self._backing_store()
        last = getattr(store, "last_epoch", None)
        if last is not None:
            self.epoch = last()

    def _commit_durable(self) -> None:
        """Group-commit the wave at the (just bumped) epoch: one WAL
        flush per planner wave on a durable store, so WAL batch
        boundaries align with epoch boundaries.  No-op on volatile
        stores."""
        store = self._backing_store()
        commit = getattr(store, "commit_epoch", None)
        if commit is not None:
            commit(self.epoch)

    def refresh(self, force: bool = False) -> int:
        """Commit admitted writes to the read view and return the new
        epoch.  Called by wave drivers between waves; a no-op (same
        epoch) when nothing was written since the last refresh.
        ``force`` overrides a DeviceEngine refresh cadence > 1 — drain
        paths (snapshot, shutdown) use it to guarantee full visibility."""
        if self._pending_writes:
            self._pending_writes = 0
            self.epoch += 1
            self._commit_durable()
        return self.epoch


# ---------------------------------------------------------------------------
# digest-range sharded host store
# ---------------------------------------------------------------------------
class ShardedPathStore:
    """``PathStore`` facade sharded by digest range across S shards.

    Shard s owns the digest interval [s·2⁶⁴/S, (s+1)·2⁶⁴/S): point ops
    route by ``H(π)``; namespace scans (Q4 prefix / token index) fan out to
    every shard and merge in path order.  Each shard runs its own
    ``MemKV`` — private memtable, private runs, private compaction — so
    write pressure on one digest range never stalls reads on another
    (the per-shard memtable/compaction isolation of a real LSM fleet).

    Duck-types the ``PathStore`` surface used by the writer, cache,
    tensorstore freeze and engines.

    ``engine_factory`` (shard index → ``KVEngine``) is how the durable
    tier plugs in: ``storage.durable_engine_factory(root)`` gives every
    digest-range shard its own WAL + segment directory, so group commit,
    spill and compaction stay per-shard on disk exactly as the memtables
    are in memory.

    **Fan-out execution** (ISSUE 10): every multi-shard operation routes
    through one :class:`~repro.core.executor.ShardExecutor` —
    ``shard_workers`` (None → ``REPRO_SHARD_WORKERS``, default 0) picks
    serial loops (bit-identical to the pre-executor behavior) or a
    thread pool, so wave latency is the *max* of per-shard work, not the
    sum.  ``commit_pipeline`` (None → ``REPRO_COMMIT_PIPELINE``) makes
    durable group commits depth-1 pipelined: wave e's per-shard WAL
    fsyncs run concurrently on a commit sequencer while wave e+1
    computes; :meth:`durable_epoch` advertises only landed fsyncs.
    """

    def __init__(self, n_shards: int = 4,
                 engines: Sequence[KVEngine] | None = None,
                 depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET,
                 memtable_limit: int = 4096,
                 engine_factory: Callable[[int], KVEngine] | None = None,
                 executor: ShardExecutor | None = None,
                 shard_workers: int | None = None,
                 commit_pipeline: bool | None = None):
        if engines is not None:
            self.shards = [PathStore(e, depth_budget=depth_budget)
                           for e in engines]
        elif engine_factory is not None:
            self.shards = [PathStore(engine_factory(i),
                                     depth_budget=depth_budget)
                           for i in range(max(1, n_shards))]
        else:
            self.shards = [PathStore(MemKV(memtable_limit=memtable_limit),
                                     depth_budget=depth_budget)
                           for _ in range(max(1, n_shards))]
        self.depth_budget = depth_budget
        self._own_executor = executor is None
        self.executor = executor if executor is not None \
            else ShardExecutor(workers=shard_workers)
        self._pipeline = resolve_commit_pipeline(commit_pipeline)
        self._sequencer: CommitSequencer | None = None

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, path: str) -> int:
        """Digest-range routing: floor(H(π) / 2⁶⁴ · S)."""
        return (P.path_hash(path) * len(self.shards)) >> 64

    def _route(self, path: str) -> tuple[PathStore, str]:
        p = P.normalize(path, depth_budget=self.depth_budget)
        return self.shards[self.shard_of(p)], p

    # -- writes -------------------------------------------------------------
    def put_record(self, path: str, rec: R.Record) -> None:
        shard, p = self._route(path)
        shard.put_record(p, rec)

    def delete_record(self, path: str) -> None:
        shard, p = self._route(path)
        shard.delete_record(p)

    # -- Q1–Q4 (unbatched PathStore surface) --------------------------------
    def get(self, path: str) -> Optional[R.Record]:
        shard, p = self._route(path)
        return shard.get(p)

    def ls(self, path: str) -> Optional[tuple[R.DirRecord, list[str]]]:
        shard, p = self._route(path)
        return shard.ls(p)

    def navigate(self, path: str) -> list[R.Record]:
        p = P.normalize(path, depth_budget=self.depth_budget)
        out: list[R.Record] = []
        for anc in list(P.ancestors(p)) + [p]:
            rec = self.get(anc)
            if rec is None:
                break
            out.append(rec)
        return out

    # -- batched point fan-outs (one scatter task per owning shard) ---------
    def _fan_out_points(self, paths: Sequence[str], per_shard, serial_one):
        """Route a batch of paths: normalize once, group by owning shard,
        ONE executor task per shard, results re-assembled in input order.
        Serial mode short-circuits to the literal per-path loop so the
        call order (and thus op-counter/engine state) is bit-identical
        to the unbatched facade."""
        if self.executor.workers == 0 or len(paths) <= 1:
            return [serial_one(p) for p in paths]
        by_shard: dict[int, list[tuple[int, str]]] = {}
        for i, raw in enumerate(paths):
            p = P.normalize(raw, depth_budget=self.depth_budget)
            by_shard.setdefault(self.shard_of(p), []).append((i, p))
        groups = sorted(by_shard.items())

        def run(_, group):
            si, pairs = group
            return per_shard(self.shards[si], [p for _, p in pairs])

        out = [None] * len(paths)
        for (_, pairs), res in zip(groups,
                                   self.executor.scatter(run, groups)):
            for (i, _), v in zip(pairs, res):
                out[i] = v
        return out

    def get_many(self, paths: Sequence[str]) -> list[Optional[R.Record]]:
        """Batched Q1: ``[self.get(p) for p in paths]``, fanned out as
        one task per owning shard when the executor has workers."""
        return self._fan_out_points(
            paths, lambda shard, ps: [shard.get(p) for p in ps], self.get)

    def ls_many(self, paths: Sequence[str]
                ) -> list[Optional[tuple[R.DirRecord, list[str]]]]:
        """Batched Q2 (same fan-out shape as :meth:`get_many`)."""
        return self._fan_out_points(
            paths, lambda shard, ps: [shard.ls(p) for p in ps], self.ls)

    def navigate_many(self, paths: Sequence[str]) -> list[list[R.Record]]:
        """Batched Q3: flatten every ancestor chain into ONE batched get
        fan-out, then truncate each chain at its first miss — the same
        flatten-then-truncate shape the device engine uses."""
        if self.executor.workers == 0 or len(paths) <= 1:
            return [self.navigate(p) for p in paths]
        norm = [P.normalize(p, depth_budget=self.depth_budget)
                for p in paths]
        chains = [list(P.ancestors(p)) + [p] for p in norm]
        recs = self.get_many([a for chain in chains for a in chain])
        out: list[list[R.Record]] = []
        i = 0
        for chain in chains:
            hit: list[R.Record] = []
            alive = True
            for _ in chain:
                rec = recs[i]
                i += 1
                if alive and rec is not None:
                    hit.append(rec)
                else:
                    alive = False
            out.append(hit)
        return out

    # -- namespace fan-outs (scatter + ordered k-way merge) -----------------
    def _scatter(self, fn: Callable[[int, PathStore], object]) -> list:
        """Fan one callable out across every shard via the executor
        (serial loop in shard order when ``workers == 0``)."""
        return self.executor.scatter(fn, self.shards)

    def search(self, prefix: str, limit: int | None = None) -> list[str]:
        # per-shard results are already in path order, so the global first
        # `limit` paths are contained in the union of per-shard first
        # `limit` — fan out WITH the limit, then k-way merge (O(n log k),
        # the shards are sorted runs) + truncate
        per = self._scatter(lambda i, s: s.search(prefix, limit=limit))
        merged = list(heapq.merge(*per))
        return merged if limit is None else merged[:limit]

    def search_contains(self, token: str, limit: int | None = None) -> list[str]:
        per = self._scatter(
            lambda i, s: s.search_contains(token, limit=limit))
        merged = list(heapq.merge(*per))
        return merged if limit is None else merged[:limit]

    # -- namespace / maintenance -------------------------------------------
    def all_paths(self) -> list[str]:
        return list(heapq.merge(*self._scatter(lambda i, s: s.all_paths())))

    def count(self) -> int:
        return sum(self._scatter(lambda i, s: s.count()))

    def flush(self) -> None:
        self._drain_pipeline()
        self._scatter(lambda i, s: s.flush())

    def compact(self) -> None:
        self._drain_pipeline()
        self._scatter(lambda i, s: s.compact())

    def op_counts(self) -> dict[str, int]:
        total: dict[str, int] = {}
        for s in self.shards:
            for k, v in s.engine.op_counts().items():
                total[k] = total.get(k, 0) + v
        return total

    # -- durable-tier fan-out (see PathStore for the single-shard forms) ----
    @property
    def durable(self) -> bool:
        return any(s.durable for s in self.shards)

    def close(self) -> None:
        """Drain the commit pipeline, close every shard, then release
        the execution resources this store owns."""
        try:
            self._drain_pipeline()
        finally:
            self._scatter(lambda i, s: s.close())
            if self._sequencer is not None:
                self._sequencer.close()
                self._sequencer = None
            if self._own_executor:
                self.executor.close()

    def commit_epoch(self, epoch: int) -> None:
        """Fan the group commit out across shards.  Pipelined (durable
        stores with ``commit_pipeline`` on): join wave e-1's in-flight
        fsync, seal every shard synchronously, hand the durability work
        to the sequencer and return — wave e's fsync overlaps the
        caller's next wave.  Otherwise: scatter synchronous per-shard
        commits (concurrent per-shard fsyncs when the executor has
        workers, the serial loop when not)."""
        if self._pipeline and self.durable:
            self._commit_pipelined(epoch)
        else:
            self._scatter(lambda i, s: s.commit_epoch(epoch))

    def _commit_pipelined(self, epoch: int) -> None:
        seq = self._sequencer
        if seq is None:
            seq = self._sequencer = CommitSequencer(
                self.executor, durable_epoch=self.last_epoch())
        seq.wait()                      # depth 1: join wave e-1 first
        completes = [c for c in (s.seal_commit(epoch) for s in self.shards)
                     if c is not None]
        seq.submit(epoch, completes)

    def _drain_pipeline(self) -> None:
        """Join any sealed-but-not-durable wave.  Every path that writes
        segment files or reads WAL durability state directly (flush,
        compact, close) must drain first, or its own WAL commit could
        overtake the sealed wave's bytes."""
        if self._sequencer is not None:
            self._sequencer.wait()

    def durable_epoch(self) -> int:
        """The advertised durable epoch: the newest epoch whose WAL
        fsync has LANDED on every shard.  Trails :meth:`last_epoch` by
        at most the one in-flight pipelined wave; equal to it whenever
        the pipeline is off or drained."""
        if self._sequencer is not None:
            return self._sequencer.durable_epoch()
        return self.last_epoch()

    def commit_pipeline_depth(self) -> int:
        """Sealed-but-not-yet-durable waves in flight (0 or 1)."""
        return 0 if self._sequencer is None else self._sequencer.depth()

    def compact_debt(self) -> int | None:
        """Fleet-wide outstanding merge bytes (None if no shard is
        durable): one shard's backlog is enough to raise backpressure,
        so the shards sum rather than average."""
        debts = [d for d in self._scatter(lambda i, s: s.compact_debt())
                 if d is not None]
        return sum(debts) if debts else None

    def last_epoch(self) -> int:
        return max((s.last_epoch() for s in self.shards), default=0)

    def journal_invalidation(self, path: str) -> None:
        """Journal into the owning shard's WAL — the publish is recovered
        by the shard that also holds the record bytes."""
        shard, p = self._route(path)
        shard.journal_invalidation(p)

    def mark_device_epoch(self, epoch: int) -> None:
        self._scatter(lambda i, s: s.mark_device_epoch(epoch))

    def pending_invalidations(self) -> list[str]:
        out: list[str] = []
        for res in self._scatter(lambda i, s: s.pending_invalidations()):
            out.extend(res)
        return out


# ---------------------------------------------------------------------------
# host engine
# ---------------------------------------------------------------------------
class HostEngine(QueryEngine):
    """Batched operators over a (possibly sharded) host PathStore.

    Writes route through a ``WikiWriter`` over the same store; pass an
    existing writer (or bus) to share its invalidation stream with other
    tiers (cache, device mirror).  ``refresh()`` drains the bus, so cache
    invalidations are delivered at wave cadence — the same Δ = 1 wave
    bound the device engine gives its tensor mirror."""

    def __init__(self, store: "PathStore | ShardedPathStore",
                 writer: WikiWriter | None = None,
                 bus: InvalidationBus | None = None):
        super().__init__()
        self.store = store
        self.writer = writer if writer is not None else WikiWriter(store, bus=bus)
        # NOTE: no attach_journal here — the WAL invalidation journal
        # exists solely for device-tier rehydration, and only a
        # DeviceEngine (whose refresh DEVMARKs clear it) may attach it;
        # a host-only attach would grow the pending list forever
        self._restore_epoch()

    @property
    def store(self) -> "PathStore | ShardedPathStore":
        return self._store

    @store.setter
    def store(self, store: "PathStore | ShardedPathStore") -> None:
        """(Re)attach the backing store.  The durable-counter high-water
        marks reset with it: a swapped-in store (``ServingEngine.
        reopen_store`` and friends) restarts its op counters at 0, so
        stale marks from the previous store would silently drop its
        telemetry until the new counts re-passed the old highs."""
        self._store = store
        self._durable_seen: dict[str, int] = {}

    def refresh(self, force: bool = False) -> int:
        """Drain the invalidation bus, commit the wave (see base class),
        and fold the durable tier's read-path counters into ``stats``."""
        with obs.span("host.refresh"):
            if self.writer.bus is not None:
                self.writer.bus.drain()
            out = super().refresh(force)
            self.sync_durable_stats()
        return out

    #: (engine-level op counter, stats key) pairs mirrored by
    #: :meth:`sync_durable_stats` — the DurableKV read-path telemetry
    _DURABLE_COUNTERS = (("bloom_neg", D_BLOOM_NEG),
                         ("cache_hit", D_CACHE_HIT),
                         ("cache_miss", D_CACHE_MISS),
                         ("seg_probe", D_SEG_PROBE))

    def sync_durable_stats(self) -> None:
        """Surface the durable tier's read-path counters through
        ``self.stats`` (delta'd, so repeated calls never double-count).

        ``stats.ops[D_BLOOM_NEG]`` then reads as "segment probes skipped
        by a bloom negative so far", ``stats.ops[D_CACHE_HIT]`` /
        ``[D_CACHE_MISS]`` as block-cache accounting, and
        ``stats.ops[D_SEG_PROBE]`` as "segments considered across all
        point reads" — the counter that proves partitioned levels probe
        exactly one segment per level — summed across shards on a
        ``ShardedPathStore``.  ``stats.ops[D_COMPACT_DEBT]`` is a gauge
        (assigned, not accumulated): the store's current outstanding
        merge bytes, the compaction backpressure signal.  Called
        automatically at every ``refresh()``; benchmarks/tests call it
        directly after a read-only burst (reads never trigger a
        refresh).  No-op over volatile stores (MemKV counts none of
        these)."""
        oc = getattr(self.store, "op_counts", None)
        if oc is None:
            return
        counts = oc()
        for src, dst in self._DURABLE_COUNTERS:
            cur = counts.get(src, 0)
            prev = self._durable_seen.get(src, 0)
            if cur > prev:
                self.stats.record(dst, cur - prev)
                self._durable_seen[src] = cur
        debt_fn = getattr(self.store, "compact_debt", None)
        debt = debt_fn() if debt_fn is not None else None
        if debt is not None:
            self.stats.ops[D_COMPACT_DEBT] = debt
            obs.gauge("lsm.compact_debt").set(debt)
        depth_fn = getattr(self.store, "commit_pipeline_depth", None)
        if depth_fn is not None:
            self.stats.ops[D_PIPELINE_DEPTH] = depth_fn()

    def q1_get(self, paths):
        self.stats.record(Q1, len(paths))
        with obs.span("host.q1_get"):
            batched = getattr(self.store, "get_many", None)
            if batched is not None:
                return batched(paths)
            return [self.store.get(p) for p in paths]

    def q2_ls(self, paths):
        self.stats.record(Q2, len(paths))
        with obs.span("host.q2_ls"):
            batched = getattr(self.store, "ls_many", None)
            if batched is not None:
                return batched(paths)
            return [self.store.ls(p) for p in paths]

    def q3_navigate(self, paths):
        self.stats.record(Q3, len(paths))
        with obs.span("host.q3_navigate"):
            batched = getattr(self.store, "navigate_many", None)
            if batched is not None:
                return batched(paths)
            return [self.store.navigate(p) for p in paths]

    def q4_search(self, prefixes, limit=None):
        self.stats.record(Q4, len(prefixes))
        with obs.span("host.q4_search"):
            return [self.store.search(p, limit=limit) for p in prefixes]

    def q4_contains(self, tokens, limit=None):
        self.stats.record(Q4C, len(tokens))
        with obs.span("host.q4_contains"):
            return [self.store.search_contains(t, limit=limit) for t in tokens]


# ---------------------------------------------------------------------------
# device engine
# ---------------------------------------------------------------------------
def _token_hash(token: str) -> int:
    """FNV-1a of the token bytes — the same digest function as the path
    keys (``paths.path_hash`` hashes raw UTF-8 without normalizing, and
    tokens never contain '/', so the namespaces cannot collide)."""
    return P.path_hash(token)


class _EpochView:
    """One epoch's immutable device-side state — the read buffer of the
    double-buffered swap.

    Every read method captures ``st = self._st`` exactly once, so an
    in-flight batch keeps reading epoch e even if ``refresh()`` installs
    e+1 concurrently: installing is a single reference assignment, and no
    field of an installed view is ever written again (patch refreshes
    build the successor with jax functional updates / fresh overlay
    dicts, never in-place writes to the previous view's buffers).
    """

    __slots__ = ("wiki", "records", "paths", "khi", "klo", "view_rows",
                 "ptoks", "pinned", "tok_hi", "tok_lo", "tok_offsets",
                 "tok_rows", "tok_patch", "tok_extra")


class DeviceEngine(QueryEngine):
    """Batched operators over the epoch-versioned tensor index.

    Q1/Q3/keyword routing run through ``kernels.ops.path_lookup`` (Pallas
    on TPU, binary-search reference elsewhere); Q4 prefix scans run
    through ``kernels.ops.prefix_search``.  Record payloads are resolved
    from a host-side row table — the row id IS the payload pointer, so the
    device op does all the addressing work.

    **Incremental refresh** — when constructed over a backing store, the
    engine's writes (and any other writer sharing its ``InvalidationBus``,
    e.g. evolution passes and errorbook repairs) accumulate as dirty-path
    invalidations.  ``refresh()`` drains the bus, materializes ONE
    ``TensorDelta`` (O(|dirty|) point gets against the store — no
    full-store re-freeze pass), applies it via ``tensorstore.
    apply_delta_ex`` and bumps ``epoch``.  Small deltas take the in-place
    **patch** path (O(|Δ|): scatter the touched token rows, reuse every
    other device buffer of the previous epoch); large ones rebuild.

    **Double-buffered epoch swap** — all derived read state lives in one
    immutable ``_EpochView``; ``refresh()`` constructs epoch e+1's view
    off to the side and installs it with a single reference assignment.
    Readers that captured epoch e's view (every method does, once) are
    unaffected mid-batch — the snapshot-exactness the epoch contract
    promises, now preserved *through* the swap instead of by forbidding
    concurrent refreshes.

    **Refresh cadence** — ``refresh_cadence=k`` commits only every k-th
    refresh request (``force=True`` overrides, e.g. snapshot drains), so
    refresh cost amortizes over k waves at the price of staleness Δ = k
    waves (property-tested; benchmarks/table5_online.py reports the lag
    distribution).  ``refresh_mode`` pins ``apply_delta_ex``'s mode —
    benchmarks use "patch"/"rebuild" to isolate the two cost curves.

    The pinned hot set ("/" + dimensions) is staged per epoch as
    (hi, lo, sorted-view position) triples for the kernel's VMEM level-0
    probe — see kernels/path_lookup.py.
    """

    #: refresh history retained for diagnostics/benchmarks
    DELTA_LOG_KEEP = 16

    def __init__(self, wiki, records: list[Optional[R.Record]],
                 depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET,
                 store: "PathStore | ShardedPathStore | None" = None,
                 writer: WikiWriter | None = None,
                 bus: InvalidationBus | None = None,
                 refresh_cadence: int = 1,
                 refresh_mode: str = "auto"):
        super().__init__()
        self.depth_budget = depth_budget
        self.store = store
        self.delta_log: list = []
        self.refresh_cadence = max(1, int(refresh_cadence))
        self.refresh_mode = refresh_mode
        #: how the last committed refresh was applied ("materialize" |
        #: "patch" | "rebuild") — benchmarks assert the mode they measure
        self.last_refresh_kind = "materialize"
        self._deferred_waves = 0
        self._dirty: set[str] = set()
        #: dirty paths rehydrated from the durable tier's committed
        #: invalidation journal at construction (diagnostics/tests)
        self.rehydrated_paths: list[str] = []
        if store is not None:
            if writer is not None:
                self.writer = writer
                if self.writer.bus is None:
                    self.writer.bus = bus if bus is not None else InvalidationBus()
            else:
                self.writer = WikiWriter(
                    store, bus=bus if bus is not None else InvalidationBus())
            self.writer.bus.subscribe(self._note_dirty)
            attach_journal(self.writer.bus, store)
            self._restore_epoch()
        self._install(wiki, records)

    def _note_dirty(self, ev) -> None:
        self._dirty.add(ev.path)

    # -- epoch views ---------------------------------------------------
    @property
    def wiki(self):
        return self._st.wiki

    @property
    def records(self) -> list[Optional[R.Record]]:
        return self._st.records

    def epoch_view(self) -> _EpochView:
        """The current epoch's immutable snapshot (tests/benchmarks pin
        it the same way reads do: capture once, use throughout)."""
        return self._st

    def _install(self, wiki, records: list[Optional[R.Record]]) -> None:
        """Full (re)build of every derived device structure for a fresh
        materialized/rebuilt snapshot.  Called at construction and per
        committed rebuild refresh; patch refreshes take ``_patch_install``
        (O(|Δ|)) instead."""
        import jax.numpy as jnp
        from ..kernels.ops import pad_keys
        st = _EpochView()
        st.wiki = wiki
        st.records = records
        st.paths = wiki.paths
        # pad the digest view once so the Pallas kernel path is eligible
        khi_v, klo_v, view_rows = wiki.search_view()
        khi, klo = pad_keys(np.asarray(khi_v), np.asarray(klo_v))
        st.khi = jnp.asarray(khi)
        st.klo = jnp.asarray(klo)
        st.view_rows = np.asarray(view_rows)
        # explicit copy: jnp.asarray can zero-copy a host numpy array, and
        # the patch path mutates wiki.path_tokens in place — the epoch
        # view's device buffer must not alias the mutable master
        st.ptoks = jnp.asarray(np.array(wiki.path_tokens))
        st.pinned = self._stage_pinned(wiki, khi_v, klo_v)
        self._max_path_bytes = int(wiki.path_tokens.shape[1])
        # device token-digest table: sorted FNV digests of every segment
        # token + CSR of matching path rows (rows pre-sorted by path bytes,
        # the same order the host token-index scan yields).  The master
        # token map lives on the engine so patches can maintain it; the
        # packed arrays live on the view and are immutable per epoch.
        tok_map: dict[str, list[int]] = {}
        for path, row in wiki.row_of.items():
            for tok in _segment_tokens(path):
                tok_map.setdefault(tok, []).append(row)
        for rows in tok_map.values():
            rows.sort(key=lambda r: wiki.paths[r])
        self._tok_map = tok_map
        toks = sorted(tok_map, key=_token_hash)
        self._tok_idx = {t: i for i, t in enumerate(toks)}
        tdig = np.array([_token_hash(t) for t in toks], dtype=np.uint64)
        t_off = np.zeros((len(toks) + 1,), dtype=np.int32)
        t_rows: list[int] = []
        for i, t in enumerate(toks):
            t_rows.extend(tok_map[t])
            t_off[i + 1] = len(t_rows)
        thi, tlo = pad_keys(
            (tdig >> np.uint64(32)).astype(np.uint32),
            (tdig & np.uint64(0xFFFFFFFF)).astype(np.uint32))
        st.tok_hi = jnp.asarray(thi)
        st.tok_lo = jnp.asarray(tlo)
        st.tok_offsets = t_off
        st.tok_rows = np.asarray(t_rows, dtype=np.int32)
        st.tok_patch = {}
        st.tok_extra = {}
        self._st = st
        self.last_refresh_kind = wiki.refresh_kind

    def _stage_pinned(self, wiki, khi_view: np.ndarray, klo_view: np.ndarray):
        """Stage the pinned hot set ("/" + every dimension — the paper's
        L1 tier) for the kernel's VMEM level-0 probe: (hi, lo, position)
        where position is the row's rank in the sorted search view — the
        value the HBM binary search would produce."""
        import jax.numpy as jnp
        from ..kernels.ops import pad_pinned
        prow = wiki.pinned_rows()
        phi = np.asarray(wiki.keys_hi[prow])
        plo = np.asarray(wiki.keys_lo[prow])
        k64 = (khi_view.astype(np.uint64) << np.uint64(32)) | klo_view.astype(np.uint64)
        p64 = (phi.astype(np.uint64) << np.uint64(32)) | plo.astype(np.uint64)
        pos = np.searchsorted(k64, p64).astype(np.int32)
        phi_p, plo_p, pos_p = pad_pinned(phi, plo, pos)
        return (jnp.asarray(phi_p), jnp.asarray(plo_p), jnp.asarray(pos_p))

    def _patch_install(self, prev: _EpochView, wiki,
                       records: list[Optional[R.Record]], info) -> None:
        """O(|Δ|) successor view for an in-place patch refresh: reuse the
        previous epoch's device buffers wherever the patch left them
        valid, functionally update the rest.  Epoch e's buffers are never
        written — jax ``.at[].set`` allocates the successor, and overlay
        dicts are copied — so readers holding e keep a consistent view
        through the swap."""
        import jax.numpy as jnp
        from ..kernels.ops import pad_keys
        st = _EpochView()
        st.wiki = wiki
        st.records = records
        st.paths = wiki.paths
        if info.keys_changed:
            khi_v, klo_v, view_rows = wiki.search_view()
            khi, klo = pad_keys(np.asarray(khi_v), np.asarray(klo_v))
            st.khi = jnp.asarray(khi)
            st.klo = jnp.asarray(klo)
            st.view_rows = np.asarray(view_rows)
            # any membership change shifts sorted-view ranks → restage
            st.pinned = self._stage_pinned(wiki, khi_v, klo_v)
        else:
            st.khi, st.klo = prev.khi, prev.klo
            st.view_rows = prev.view_rows
            st.pinned = prev.pinned
        touched = list(info.new_rows) + list(info.removed_rows)
        if touched:
            idx = np.asarray(touched, dtype=np.int32)
            st.ptoks = prev.ptoks.at[jnp.asarray(idx)].set(
                jnp.asarray(wiki.path_tokens[idx]))
        else:
            st.ptoks = prev.ptoks
        # token table: the packed base (digests + CSR) is immutable; rows
        # of changed tokens move to copy-on-write overlays, folded back
        # into the base at the next rebuild.  The engine-level master map
        # is maintained incrementally (prev's view never reads it).
        st.tok_hi, st.tok_lo = prev.tok_hi, prev.tok_lo
        st.tok_offsets, st.tok_rows = prev.tok_offsets, prev.tok_rows
        tok_patch = dict(prev.tok_patch)
        tok_extra = dict(prev.tok_extra)

        def _overlay(tok: str) -> None:
            rows = tuple(self._tok_map.get(tok) or ())
            i = self._tok_idx.get(tok)
            if i is not None:
                tok_patch[i] = rows
            else:
                tok_extra[tok] = rows

        for row, path in zip(info.removed_rows, info.removed_paths):
            for tok in _segment_tokens(path):
                lst = self._tok_map.get(tok)
                if lst is not None and row in lst:
                    lst.remove(row)
                _overlay(tok)
        for row, path in zip(info.new_rows, info.new_paths):
            for tok in _segment_tokens(path):
                lst = self._tok_map.setdefault(tok, [])
                bisect.insort(lst, row, key=wiki.paths.__getitem__)
                _overlay(tok)
        st.tok_patch = tok_patch
        st.tok_extra = tok_extra
        self._st = st          # the swap: one assignment, atomic in python
        self.last_refresh_kind = "patch"

    # ------------------------------------------------------------------
    @classmethod
    def from_store(cls, store: "PathStore | ShardedPathStore",
                   writer: WikiWriter | None = None,
                   bus: InvalidationBus | None = None, *,
                   refresh_cadence: int = 1,
                   refresh_mode: str = "auto") -> "DeviceEngine":
        """Freeze the store into the device layout + host payload table
        (the offline pipeline's snapshot step) — one store pass.  The
        engine stays attached to the store: subsequent writes flow
        through its writer and land in the tensor index via incremental
        ``refresh()`` deltas, never another full freeze."""
        from . import tensorstore as TS
        wiki, recs = TS.freeze_with_records(store)
        eng = cls(wiki, recs, depth_budget=store.depth_budget,
                  store=store, writer=writer, bus=bus,
                  refresh_cadence=refresh_cadence, refresh_mode=refresh_mode)
        # Epoch-consistent rehydration over a durable store: the freeze
        # just read the *current* store, which already includes every
        # committed-but-unapplied dirty path in the WAL journal — record
        # them (the TensorDelta work list a snapshot-based reopen would
        # replay) and mark the journal applied through the restored epoch.
        pending = getattr(store, "pending_invalidations", None)
        if pending is not None:
            eng.rehydrated_paths = pending()
            mark = getattr(store, "mark_device_epoch", None)
            if mark is not None and getattr(store, "durable", False):
                mark(eng.epoch)
        return eng

    # ------------------------------------------------------------------
    def refresh(self, force: bool = False) -> int:
        """Apply all writes since the last refresh as one ``TensorDelta``.

        Storage cost is O(|dirty paths|) point gets; applying the delta is
        pure in-memory host work with zero store round trips (contrast
        ``from_store``: a full namespace scan + N gets).  Small deltas
        patch the resident snapshot in place (O(|Δ|) — stable row ids,
        device buffers reused); compaction-triggering ones rebuild.
        No-op when the bus is clean.

        With ``refresh_cadence=k > 1``, only every k-th dirty refresh
        request commits (the deferral counter only advances while writes
        are pending, so idle waves don't consume the cadence); the
        durable group commit rides the committed refresh, so both
        visibility and durability arrive within k waves of the admitting
        wave.  ``force=True`` (snapshot/shutdown drains) commits
        immediately."""
        if self.writer is not None and self.writer.bus is not None:
            self.writer.bus.drain()
        if not self._dirty:
            return self.epoch
        self._deferred_waves += 1
        if not force and self._deferred_waves < self.refresh_cadence:
            return self.epoch
        self._deferred_waves = 0
        from . import tensorstore as TS
        with obs.span("device.refresh", dirty=len(self._dirty)) as sp:
            with obs.span("device.refresh.delta"):
                resident = self.wiki.row_of
                upserts: list[tuple[str, R.Record]] = []
                unlinks: list[str] = []
                for p in sorted(self._dirty):
                    rec = self.store.get(p)
                    if rec is not None:
                        upserts.append((p, rec))
                    elif p in resident:
                        unlinks.append(p)
                self._dirty.clear()
            had_writes = self._pending_writes > 0
            self._pending_writes = 0
            if not upserts and not unlinks:
                # no visible tensor change, but the wave's WAL records
                # (e.g. an admit+unlink that cancelled out) still need
                # their commit
                if had_writes:
                    self._commit_durable()
                return self.epoch
            delta = TS.TensorDelta(epoch=self.epoch + 1,
                                   upserts=upserts, unlinks=unlinks)
            prev = self._st
            t_apply = time.perf_counter() if obs.enabled() else 0.0
            with obs.span("device.refresh.apply", rows=len(delta)):
                wiki, recs, info = TS.apply_delta_ex(
                    self.wiki, self.records, delta, mode=self.refresh_mode)
                if info.kind == "patch":
                    self._patch_install(prev, wiki, recs, info)
                else:
                    self._install(wiki, recs)
            if t_apply:
                # patch-vs-rebuild cost curves, separately addressable
                obs.histogram(f"device.refresh.{info.kind}").record(
                    (time.perf_counter() - t_apply) * 1e3)
            sp.set(kind=info.kind, epoch=self.epoch + 1)
            self.delta_log.append(delta)
            del self.delta_log[:-self.DELTA_LOG_KEEP]
            self.epoch += 1
            self.stats.record(REFRESH, len(delta))
            self.stats.record(f"{REFRESH}_{info.kind}", len(delta))
            obs.set_context(epoch=self.epoch)
            # durable wave boundary: DEVMARK (journal applied through this
            # epoch) rides the same WAL commit as the wave it closes
            mark = getattr(self.store, "mark_device_epoch", None)
            if mark is not None and getattr(self.store, "durable", False):
                mark(self.epoch)
            self._commit_durable()
        return self.epoch

    # ------------------------------------------------------------------
    @staticmethod
    def _pad_pow2(n: int, floor: int = 8) -> int:
        """Bucket batch sizes to powers of two so the jitted lookup sees
        O(log Q) distinct shapes instead of one compile per batch size."""
        p = floor
        while p < n:
            p <<= 1
        return p

    def _lookup_rows(self, st: _EpochView, digest_pairs: np.ndarray,
                     table=None) -> np.ndarray:
        """One batched device lookup: (Q, 2) uint64 pairs → (Q,) row ids.
        Main-table lookups (table=None) probe the epoch's pinned VMEM
        sub-table first, then map sorted-view positions back to stable
        row ids through ``view_rows``."""
        import jax.numpy as jnp
        from ..kernels.ops import path_lookup
        q = digest_pairs.shape[0]
        if q == 0:
            return np.zeros((0,), dtype=np.int32)
        if table is None:
            khi, klo, pinned = st.khi, st.klo, st.pinned
        else:
            (khi, klo), pinned = table, None
        qp = self._pad_pow2(q)
        if qp != q:
            # (0, 0) can never collide with an FNV digest of a non-empty
            # path; the padded tail is sliced off regardless
            pad = np.zeros((qp - q, 2), dtype=np.uint64)
            digest_pairs = np.concatenate([digest_pairs, pad])
        rows = path_lookup(
            khi, klo,
            jnp.asarray(digest_pairs[:, 0].astype(np.uint32)),
            jnp.asarray(digest_pairs[:, 1].astype(np.uint32)),
            pinned=pinned)
        rows = np.asarray(rows)[:q]
        if table is None:
            # sorted-view position → row id, clipped against the padded
            # key-table tail
            n_view = len(st.view_rows)
            valid = (rows >= 0) & (rows < n_view)
            safe = np.clip(rows, 0, max(n_view - 1, 0))
            return np.where(valid, st.view_rows[safe], -1).astype(np.int32)
        n_rows = len(st.tok_offsets) - 1
        return np.where(rows >= n_rows, -1, rows)

    def _digests(self, paths: list[str]) -> np.ndarray:
        out = np.zeros((len(paths), 2), dtype=np.uint64)
        for i, p in enumerate(paths):
            h = P.path_hash(p)
            out[i] = ((h >> 32) & 0xFFFFFFFF, h & 0xFFFFFFFF)
        return out

    def _norm(self, paths: Sequence[str]) -> list[str]:
        return [P.normalize(p, depth_budget=self.depth_budget) for p in paths]

    # ------------------------------------------------------------------
    def q1_get(self, paths):
        self.stats.record(Q1, len(paths))
        with obs.span("device.q1_get"):
            st = self._st
            norm = self._norm(paths)
            rows = self._lookup_rows(st, self._digests(norm))
            return [st.records[r] if r >= 0 else None for r in rows]

    def q2_ls(self, paths):
        """One batched lookup; children come co-located in the resolved
        directory record ("children co-located with the parent"), so no
        second device op is needed.  (TensorWiki's CSR serves row-level
        traversal in core/tensorstore.py; the engine's record table
        already carries the same lists.)"""
        self.stats.record(Q2, len(paths))
        with obs.span("device.q2_ls"):
            st = self._st
            norm = self._norm(paths)
            rows = self._lookup_rows(st, self._digests(norm))
            out = []
            for p, r in zip(norm, rows):
                rec = st.records[r] if r >= 0 else None
                if rec is None or not isinstance(rec, R.DirRecord):
                    out.append(None)
                    continue
                out.append((rec, [P.child(p, s) for s in rec.children()]))
            return out

    def q3_navigate(self, paths):
        """The whole batch's ancestor chains flatten into ONE lookup
        launch — step compression applied to the storage layer itself."""
        self.stats.record(Q3, len(paths))
        with obs.span("device.q3_navigate"):
            st = self._st
            norm = self._norm(paths)
            chains = [list(P.ancestors(p)) + [p] for p in norm]
            flat = [a for chain in chains for a in chain]
            rows = self._lookup_rows(st, self._digests(flat))
            # the flat lookup resolves every level even past a miss (the
            # batch is issued before results are known); the per-path
            # result still truncates at the first miss, matching
            # PathStore.navigate
            return self._q3_truncate(st, chains, rows)

    @staticmethod
    def _q3_truncate(st: _EpochView, chains, rows) -> list[list[R.Record]]:
        out: list[list[R.Record]] = []
        i = 0
        for chain in chains:
            recs: list[R.Record] = []
            stopped = False
            for _ in chain:
                r = rows[i]
                i += 1
                if stopped:
                    continue
                rec = st.records[r] if r >= 0 else None
                if rec is None:
                    stopped = True
                else:
                    recs.append(rec)
            out.append(recs)
        return out

    def q4_search(self, prefixes, limit=None):
        """One prefix_search launch for the whole prefix batch: every
        pending prefix is compared against each resident path tile.  The
        scan runs over the row-order token matrix (free slots are zeros,
        tombstones 255s — neither can match a real prefix), so a patch
        refresh only re-uploads the touched rows."""
        self.stats.record(Q4, len(prefixes))
        if not prefixes:
            return []
        with obs.span("device.q4_search"):
            return self._q4_search(prefixes, limit)

    def _q4_search(self, prefixes, limit):
        import jax.numpy as jnp
        from . import tensorstore as TS
        from ..kernels.ops import prefix_search
        st = self._st
        fixed = [p if p.startswith(P.SEP) else P.SEP + p for p in prefixes]
        L = self._max_path_bytes
        qp = self._pad_pow2(len(fixed), floor=4)
        # pad with unmatchable prefixes (0xFF never occurs in a path) so
        # the jitted scan sees bucketed shapes
        pref_mat = np.full((qp, L), 255, dtype=np.uint8)
        lens = np.full((qp,), 1, dtype=np.int32)
        long_idx: set[int] = set()
        for i, p in enumerate(fixed):
            blen = len(p.encode("utf-8"))
            if blen >= L:
                # the packed token matrix truncates at L bytes, so the
                # kernel cannot decide these exactly — resolve them from
                # the untruncated host-side path list instead (rare: the
                # depth budget keeps normal prefixes far below L)
                long_idx.add(i)
            else:
                pref_mat[i] = TS.pack_path(p, L)
                lens[i] = blen
        bitmap = np.asarray(prefix_search(
            st.ptoks, jnp.asarray(pref_mat), jnp.asarray(lens)))
        n_paths = len(st.paths)
        out: list[list[str]] = []
        for qi in range(len(fixed)):
            if qi in long_idx:
                seg_pref = fixed[qi].rstrip(P.SEP) or P.ROOT
                matches = sorted(
                    p for p in st.wiki.row_of
                    if p.startswith(fixed[qi])
                    and (P.is_prefix(seg_pref, p) or p == fixed[qi]))
                out.append(matches if limit is None else matches[:limit])
                continue
            hits = np.nonzero(bitmap[:n_paths, qi])[0]
            matches = sorted(st.paths[r] for r in hits)
            out.append(matches if limit is None else matches[:limit])
        return out

    def q4_contains(self, tokens, limit=None):
        """Keyword routing: the segment-token inverted index as a device
        lookup — token digests through the SAME Pallas path_lookup kernel,
        then a CSR slice of matching path rows (or the epoch's
        copy-on-write overlay for tokens a patch refresh touched).  Exact
        segment-token semantics, identical to PathStore.search_contains."""
        self.stats.record(Q4C, len(tokens))
        if not tokens:
            return []
        with obs.span("device.q4_contains"):
            st = self._st
            norm_toks = [t.lower() for t in tokens]
            dig = np.zeros((len(norm_toks), 2), dtype=np.uint64)
            for i, t in enumerate(norm_toks):
                h = _token_hash(t)
                dig[i] = ((h >> 32) & 0xFFFFFFFF, h & 0xFFFFFFFF)
            rows = self._lookup_rows(st, dig, table=(st.tok_hi, st.tok_lo))
            out: list[list[str]] = []
            for t, r in zip(norm_toks, rows):
                if r >= 0:
                    over = st.tok_patch.get(int(r))
                    if over is not None:
                        prows = over
                    else:
                        lo, hi = st.tok_offsets[r], st.tok_offsets[r + 1]
                        prows = st.tok_rows[lo:hi]
                else:
                    # token absent from the packed table — it may have
                    # been introduced by a patch refresh since the last
                    # rebuild
                    prows = st.tok_extra.get(t, ())
                matches = [st.paths[i] for i in prows]
                out.append(matches if limit is None else matches[:limit])
            return out


# ---------------------------------------------------------------------------
# batch planner
# ---------------------------------------------------------------------------
class OpFuture:
    """Handle for one pending engine operation.  ``value`` is valid after
    the planner flush that executed its batch."""

    __slots__ = ("op", "arg", "value", "done")

    def __init__(self, op: str, arg):
        self.op = op
        self.arg = arg
        self.value = None
        self.done = False

    def result(self):
        if not self.done:
            raise RuntimeError(
                f"operation {self.op}({self.arg!r}) not flushed yet")
        return self.value


class BatchPlanner:
    """Collects Q1–Q4 operations — and now writes — from many concurrent
    sessions and executes each operator's pending set in ONE engine call
    per flush.

    Identical read operations from different sessions are deduplicated
    into a single batch slot (they share the result), so a flush costs at
    most five read round trips — one per live operator — regardless of
    how many sessions are in flight.  Writes are collected in enqueue
    order and are never deduplicated (two admissions of the same path are
    two intents, applied in order): the flush batches them as maximal
    same-kind runs, preserving cross-kind order, so unlink-then-readmit
    keeps its meaning.

    **Wave semantics** (the epoch contract of ``QueryEngine``): a flush
    executes all read batches FIRST, then the write batches.  Reads of a
    flush therefore never observe that flush's writes; visibility arrives
    at the driver's ``engine.refresh()`` between waves (Δ = 1 wave).
    """

    def __init__(self, engine: QueryEngine):
        self.engine = engine
        self._pending: dict[str, dict[object, list[OpFuture]]] = {}
        self._writes: list[tuple[str, object, OpFuture]] = []
        self._lock = threading.Lock()
        self.flushes = 0

    # -- operation futures --------------------------------------------------
    def _enqueue(self, op: str, key, arg) -> OpFuture:
        fut = OpFuture(op, arg)
        with self._lock:
            self._pending.setdefault(op, {}).setdefault(key, []).append(fut)
        return fut

    def get(self, path: str) -> OpFuture:
        return self._enqueue(Q1, path, path)

    def ls(self, path: str) -> OpFuture:
        return self._enqueue(Q2, path, path)

    def navigate(self, path: str) -> OpFuture:
        return self._enqueue(Q3, path, path)

    def search(self, prefix: str, limit: int | None = None) -> OpFuture:
        return self._enqueue(Q4, (prefix, limit), prefix)

    def contains(self, token: str, limit: int | None = None) -> OpFuture:
        return self._enqueue(Q4C, (token, limit), token)

    # -- write futures ------------------------------------------------------
    def _enqueue_write(self, op: str, payload) -> OpFuture:
        fut = OpFuture(op, payload)
        with self._lock:
            self._writes.append((op, payload, fut))
        return fut

    def admit(self, path: str, rec: R.Record) -> OpFuture:
        """Batched §IV-C admission; resolves to the admitted record."""
        return self._enqueue_write(W_ADMIT, (path, rec))

    def update(self, path: str,
               mutate: Callable[[R.FileRecord], R.FileRecord]) -> OpFuture:
        """Batched OCC update; resolves to the new record, or to the
        ``CASConflict`` instance if retries were exhausted."""
        return self._enqueue_write(W_UPDATE, (path, mutate))

    def unlink(self, path: str) -> OpFuture:
        """Batched reverse-order unlink; resolves to existed: bool."""
        return self._enqueue_write(W_UNLINK, path)

    def pending_ops(self) -> int:
        return (sum(len(futs) for by_key in self._pending.values()
                    for futs in by_key.values())
                + len(self._writes))

    def pending_writes(self) -> int:
        return len(self._writes)

    # -- execution ----------------------------------------------------------
    def flush(self) -> int:
        """Execute every pending batch; one engine call per operator kind,
        reads before writes.  Returns the number of futures resolved."""
        with self._lock:
            pending, self._pending = self._pending, {}
            writes, self._writes = self._writes, []
        if not pending and not writes:
            return 0
        depth = (sum(len(futs) for by_key in pending.values()
                     for futs in by_key.values()) + len(writes))
        self.flushes += 1
        obs.set_context(wave=self.flushes)
        obs.gauge("planner.queue_depth").set(depth)
        resolved = 0
        with obs.span("planner.flush", depth=depth,
                      writes=len(writes)) as sp:
            # reads first — every read of this wave sees the epoch pinned
            # at wave start, untouched by this wave's writes
            for op in READ_OPS:
                by_key = pending.get(op)
                if not by_key:
                    continue
                keys = list(by_key)
                if op == Q1:
                    results = self.engine.q1_get(keys)
                elif op == Q2:
                    results = self.engine.q2_ls(keys)
                elif op == Q3:
                    results = self.engine.q3_navigate(keys)
                elif op == Q4:
                    # group by limit so one call covers each limit class
                    results = self._ranged(self.engine.q4_search, keys)
                else:
                    results = self._ranged(self.engine.q4_contains, keys)
                n_served = 0
                for key, value in zip(keys, results):
                    for fut in by_key[key]:
                        fut.value = value
                        fut.done = True
                        n_served += 1
                self.engine.stats.record_served(op, n_served)
                resolved += n_served
            resolved += self._flush_writes(writes)
            sp.set(resolved=resolved)
        return resolved

    def _flush_writes(self, writes) -> int:
        """Execute the ordered write log as maximal same-kind runs: one
        engine call per run, cross-kind enqueue order preserved.  A
        homogeneous wave (the common case) still costs one round trip;
        an unlink-then-readmit of the same path keeps its meaning."""
        methods = {W_ADMIT: self.engine.admit_many,
                   W_UPDATE: self.engine.update_many,
                   W_UNLINK: self.engine.unlink_many}
        resolved = 0
        i = 0
        while i < len(writes):
            op = writes[i][0]
            j = i
            while j < len(writes) and writes[j][0] == op:
                j += 1
            batch = writes[i:j]
            try:
                results = methods[op]([payload for _, payload, _ in batch])
            except Exception as e:
                # the engines resolve expected per-item failures to
                # exception values; anything that still escapes must not
                # leave this wave's futures dangling forever — resolve
                # them to the failure and keep the wave going
                results = [e] * len(batch)
            for (_, _, fut), value in zip(batch, results):
                fut.value = value
                fut.done = True
            self.engine.stats.record_served(op, len(batch))
            resolved += len(batch)
            i = j
        return resolved

    @staticmethod
    def _grouped_by_limit(keys):
        groups: dict[int | None, list] = {}
        for k in keys:
            groups.setdefault(k[1], []).append(k)
        return groups

    def _ranged(self, method, keys):
        """Execute (arg, limit) keyed scans: one engine call per distinct
        limit (usually exactly one)."""
        by_limit = self._grouped_by_limit(keys)
        res: dict[object, list[str]] = {}
        for limit, ks in by_limit.items():
            outs = method([k[0] for k in ks], limit=limit)
            for k, o in zip(ks, outs):
                res[k] = o
        return [res[k] for k in keys]


def drive(gen, planner: BatchPlanner):
    """Run one session generator to completion, flushing the planner at
    every yield point (the single-session degenerate case of the
    multi-session scheduler in navigate.run_sessions).  The session is
    one wave: any writes it admitted become visible at the closing
    ``refresh()``."""
    try:
        while True:
            next(gen)
            planner.flush()
    except StopIteration as e:
        planner.engine.refresh()
        return e.value


def admit_wave(planner: BatchPlanner,
               items: Sequence[tuple[str, R.Record]]) -> list[OpFuture]:
    """Writer-session helper: enqueue a batch of admissions that will ride
    the next wave's flush exactly like reader sessions' ops do."""
    return [planner.admit(p, rec) for p, rec in items]


def unlink_wave(planner: BatchPlanner, paths: Sequence[str]) -> list[OpFuture]:
    """Writer-session helper for batched unlinks."""
    return [planner.unlink(p) for p in paths]


__all__ = ["QueryEngine", "HostEngine", "DeviceEngine", "ShardedPathStore",
           "BatchPlanner", "OpFuture", "EngineStats", "drive",
           "admit_wave", "unlink_wave",
           "Q1", "Q2", "Q3", "Q4", "Q4C",
           "W_ADMIT", "W_UPDATE", "W_UNLINK", "REFRESH"]
