"""Unified batched query-execution layer: PathStore → Pallas kernels.

One engine abstraction serves every Q1–Q4 operation of the online tier,
batched (DESIGN goal: the paper's "O(1) storage round trips per query"
realized as "O(1) engine calls per *batch* of queries"):

* ``QueryEngine``   — the batched operator contract.  Every method takes a
  whole batch and counts as ONE round trip regardless of batch size; the
  per-call batch sizes are tracked in ``EngineStats`` so benchmarks can
  report amortization directly.

* ``HostEngine``    — wraps a ``PathStore`` (or the digest-range
  ``ShardedPathStore`` below).  Round trips execute on the host against
  the LSM engine(s); batching amortizes the python/op dispatch overhead
  and gives the planner a single choke point to count.

* ``DeviceEngine``  — wraps a frozen ``TensorWiki``: Q1 point lookups and
  Q4 prefix scans dispatch through ``kernels.ops`` to the Pallas kernels
  (pure-jnp reference off-TPU), Q2 is one batched lookup whose child
  listing derives from the resolved directory record, Q3 flattens the
  whole batch's ancestor chains into one lookup launch, and keyword
  containment runs as a Q1-style lookup into a device token-digest
  table + CSR slice — the inverted index, tensorized.  Record payloads
  live in a host-side row table (the stand-in for HBM payload rows).

* ``BatchPlanner``  — collects the operations of many concurrent
  navigation sessions into per-operator batches; ``flush()`` executes each
  operator's pending batch in one engine call and resolves the futures.
  This is continuous batching for storage ops, mirroring the serving
  engine's token batching.

Parity contract (tested in tests/test_engine.py): for any store state
reachable through the §IV-C write protocol, ``HostEngine`` and
``DeviceEngine`` frozen from the same store return identical results for
every Q1–Q4 batch, including misses and unadvertised orphans.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

import numpy as np

from . import paths as P
from . import records as R
from .store import KVEngine, MemKV, PathStore, _segment_tokens

# operator names used for stats keys
Q1, Q2, Q3, Q4, Q4C = "q1_get", "q2_ls", "q3_navigate", "q4_search", "q4_contains"


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------
@dataclass
class EngineStats:
    """Per-operator accounting — the amortization evidence.

    ``calls``/``ops``/``max_batch`` count *unique keys per engine call*
    (what the engine actually executed).  ``served``/``max_served`` count
    *logical operations resolved per call* as reported by the planner:
    identical ops from concurrent sessions share one batch slot, so one
    engine call can serve far more lookups than it executes keys."""

    calls: dict[str, int] = field(default_factory=dict)
    ops: dict[str, int] = field(default_factory=dict)
    max_batch: dict[str, int] = field(default_factory=dict)
    served: dict[str, int] = field(default_factory=dict)
    max_served: dict[str, int] = field(default_factory=dict)

    def record(self, op: str, batch: int) -> None:
        if batch <= 0:
            return
        self.calls[op] = self.calls.get(op, 0) + 1
        self.ops[op] = self.ops.get(op, 0) + batch
        self.max_batch[op] = max(self.max_batch.get(op, 0), batch)

    def record_served(self, op: str, n: int) -> None:
        if n <= 0:
            return
        self.served[op] = self.served.get(op, 0) + n
        self.max_served[op] = max(self.max_served.get(op, 0), n)

    def total_calls(self) -> int:
        return sum(self.calls.values())

    def total_ops(self) -> int:
        return sum(self.ops.values())

    def reset(self) -> None:
        for d in (self.calls, self.ops, self.max_batch,
                  self.served, self.max_served):
            d.clear()


# ---------------------------------------------------------------------------
# the batched operator contract
# ---------------------------------------------------------------------------
class QueryEngine:
    """Batched Q1–Q4 execution.  One method call == one storage round trip."""

    def __init__(self):
        self.stats = EngineStats()

    def q1_get(self, paths: Sequence[str]) -> list[Optional[R.Record]]:
        raise NotImplementedError

    def q2_ls(self, paths: Sequence[str]
              ) -> list[Optional[tuple[R.DirRecord, list[str]]]]:
        raise NotImplementedError

    def q3_navigate(self, paths: Sequence[str]) -> list[list[R.Record]]:
        raise NotImplementedError

    def q4_search(self, prefixes: Sequence[str],
                  limit: int | None = None) -> list[list[str]]:
        raise NotImplementedError

    def q4_contains(self, tokens: Sequence[str],
                    limit: int | None = None) -> list[list[str]]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# digest-range sharded host store
# ---------------------------------------------------------------------------
class ShardedPathStore:
    """``PathStore`` facade sharded by digest range across S shards.

    Shard s owns the digest interval [s·2⁶⁴/S, (s+1)·2⁶⁴/S): point ops
    route by ``H(π)``; namespace scans (Q4 prefix / token index) fan out to
    every shard and merge in path order.  Each shard runs its own
    ``MemKV`` — private memtable, private runs, private compaction — so
    write pressure on one digest range never stalls reads on another
    (the per-shard memtable/compaction isolation of a real LSM fleet).

    Duck-types the ``PathStore`` surface used by the writer, cache,
    tensorstore freeze and engines.
    """

    def __init__(self, n_shards: int = 4,
                 engines: Sequence[KVEngine] | None = None,
                 depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET,
                 memtable_limit: int = 4096):
        if engines is not None:
            self.shards = [PathStore(e, depth_budget=depth_budget)
                           for e in engines]
        else:
            self.shards = [PathStore(MemKV(memtable_limit=memtable_limit),
                                     depth_budget=depth_budget)
                           for _ in range(max(1, n_shards))]
        self.depth_budget = depth_budget

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def shard_of(self, path: str) -> int:
        """Digest-range routing: floor(H(π) / 2⁶⁴ · S)."""
        return (P.path_hash(path) * len(self.shards)) >> 64

    def _route(self, path: str) -> tuple[PathStore, str]:
        p = P.normalize(path, depth_budget=self.depth_budget)
        return self.shards[self.shard_of(p)], p

    # -- writes -------------------------------------------------------------
    def put_record(self, path: str, rec: R.Record) -> None:
        shard, p = self._route(path)
        shard.put_record(p, rec)

    def delete_record(self, path: str) -> None:
        shard, p = self._route(path)
        shard.delete_record(p)

    # -- Q1–Q4 (unbatched PathStore surface) --------------------------------
    def get(self, path: str) -> Optional[R.Record]:
        shard, p = self._route(path)
        return shard.get(p)

    def ls(self, path: str) -> Optional[tuple[R.DirRecord, list[str]]]:
        shard, p = self._route(path)
        return shard.ls(p)

    def navigate(self, path: str) -> list[R.Record]:
        p = P.normalize(path, depth_budget=self.depth_budget)
        out: list[R.Record] = []
        for anc in list(P.ancestors(p)) + [p]:
            rec = self.get(anc)
            if rec is None:
                break
            out.append(rec)
        return out

    def search(self, prefix: str, limit: int | None = None) -> list[str]:
        # per-shard results are already in path order, so the global first
        # `limit` paths are contained in the union of per-shard first
        # `limit` — fan out WITH the limit, then merge + truncate
        merged: list[str] = []
        for shard in self.shards:
            merged.extend(shard.search(prefix, limit=limit))
        merged.sort()
        return merged if limit is None else merged[:limit]

    def search_contains(self, token: str, limit: int | None = None) -> list[str]:
        merged: list[str] = []
        for shard in self.shards:
            merged.extend(shard.search_contains(token, limit=limit))
        merged.sort()
        return merged if limit is None else merged[:limit]

    # -- namespace / maintenance -------------------------------------------
    def all_paths(self) -> list[str]:
        out: list[str] = []
        for shard in self.shards:
            out.extend(shard.all_paths())
        out.sort()
        return out

    def count(self) -> int:
        return sum(s.count() for s in self.shards)

    def flush(self) -> None:
        for s in self.shards:
            s.engine.flush()

    def compact(self) -> None:
        for s in self.shards:
            eng = s.engine
            if hasattr(eng, "compact"):
                eng.compact()

    def op_counts(self) -> dict[str, int]:
        total: dict[str, int] = {}
        for s in self.shards:
            for k, v in s.engine.op_counts().items():
                total[k] = total.get(k, 0) + v
        return total


# ---------------------------------------------------------------------------
# host engine
# ---------------------------------------------------------------------------
class HostEngine(QueryEngine):
    """Batched operators over a (possibly sharded) host PathStore."""

    def __init__(self, store: "PathStore | ShardedPathStore"):
        super().__init__()
        self.store = store

    def q1_get(self, paths):
        self.stats.record(Q1, len(paths))
        return [self.store.get(p) for p in paths]

    def q2_ls(self, paths):
        self.stats.record(Q2, len(paths))
        return [self.store.ls(p) for p in paths]

    def q3_navigate(self, paths):
        self.stats.record(Q3, len(paths))
        return [self.store.navigate(p) for p in paths]

    def q4_search(self, prefixes, limit=None):
        self.stats.record(Q4, len(prefixes))
        return [self.store.search(p, limit=limit) for p in prefixes]

    def q4_contains(self, tokens, limit=None):
        self.stats.record(Q4C, len(tokens))
        return [self.store.search_contains(t, limit=limit) for t in tokens]


# ---------------------------------------------------------------------------
# device engine
# ---------------------------------------------------------------------------
def _token_hash(token: str) -> int:
    """FNV-1a of the token bytes — the same digest function as the path
    keys (``paths.path_hash`` hashes raw UTF-8 without normalizing, and
    tokens never contain '/', so the namespaces cannot collide)."""
    return P.path_hash(token)


class DeviceEngine(QueryEngine):
    """Batched operators over the frozen tensor index.

    Q1/Q3/keyword routing run through ``kernels.ops.path_lookup`` (Pallas
    on TPU, binary-search reference elsewhere); Q4 prefix scans run
    through ``kernels.ops.prefix_search``.  Record payloads are resolved
    from a host-side row table — the row id IS the payload pointer, so the
    device op does all the addressing work.
    """

    def __init__(self, wiki, records: list[Optional[R.Record]],
                 depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET):
        super().__init__()
        import jax.numpy as jnp
        from ..kernels.ops import pad_keys
        self.wiki = wiki
        self.records = records
        self.depth_budget = depth_budget
        # pad the digest table once so the Pallas kernel path is eligible
        khi, klo = pad_keys(np.asarray(wiki.keys_hi), np.asarray(wiki.keys_lo))
        self._khi = jnp.asarray(khi)
        self._klo = jnp.asarray(klo)
        self._lex_order = np.asarray(wiki.lex_order)
        self._max_path_bytes = int(wiki.lex_tokens.shape[1])
        # device token-digest table: sorted FNV digests of every segment
        # token + CSR of matching path rows (rows pre-sorted by path bytes,
        # the same order the host token-index scan yields)
        tok_paths: dict[str, list[int]] = {}
        for row, path in enumerate(wiki.paths):
            for tok in _segment_tokens(path):
                tok_paths.setdefault(tok, []).append(row)
        toks = sorted(tok_paths, key=_token_hash)
        tdig = np.array([_token_hash(t) for t in toks], dtype=np.uint64)
        t_off = np.zeros((len(toks) + 1,), dtype=np.int32)
        t_rows: list[int] = []
        for i, t in enumerate(toks):
            rows = sorted(tok_paths[t], key=lambda r: wiki.paths[r])
            t_rows.extend(rows)
            t_off[i + 1] = len(t_rows)
        thi, tlo = pad_keys(
            (tdig >> np.uint64(32)).astype(np.uint32),
            (tdig & np.uint64(0xFFFFFFFF)).astype(np.uint32))
        self._tok_hi = jnp.asarray(thi)
        self._tok_lo = jnp.asarray(tlo)
        self._tok_offsets = t_off
        self._tok_rows = np.asarray(t_rows, dtype=np.int32)

    # ------------------------------------------------------------------
    @classmethod
    def from_store(cls, store: "PathStore | ShardedPathStore") -> "DeviceEngine":
        """Freeze the store into the device layout + host payload table
        (the offline pipeline's snapshot step) — one store pass."""
        from . import tensorstore as TS
        wiki, recs = TS.freeze_with_records(store)
        return cls(wiki, recs, depth_budget=store.depth_budget)

    # ------------------------------------------------------------------
    @staticmethod
    def _pad_pow2(n: int, floor: int = 8) -> int:
        """Bucket batch sizes to powers of two so the jitted lookup sees
        O(log Q) distinct shapes instead of one compile per batch size."""
        p = floor
        while p < n:
            p <<= 1
        return p

    def _lookup_rows(self, digest_pairs: np.ndarray,
                     table=None) -> np.ndarray:
        """One batched device lookup: (Q, 2) uint64 pairs → (Q,) row ids."""
        import jax.numpy as jnp
        from ..kernels.ops import path_lookup
        q = digest_pairs.shape[0]
        if q == 0:
            return np.zeros((0,), dtype=np.int32)
        khi, klo = table if table is not None else (self._khi, self._klo)
        qp = self._pad_pow2(q)
        if qp != q:
            # (0, 0) can never collide with an FNV digest of a non-empty
            # path; the padded tail is sliced off regardless
            pad = np.zeros((qp - q, 2), dtype=np.uint64)
            digest_pairs = np.concatenate([digest_pairs, pad])
        rows = path_lookup(
            khi, klo,
            jnp.asarray(digest_pairs[:, 0].astype(np.uint32)),
            jnp.asarray(digest_pairs[:, 1].astype(np.uint32)))
        rows = np.asarray(rows)[:q]
        # clip defensively against the padded key-table tail
        n_rows = (len(self.records) if table is None
                  else len(self._tok_offsets) - 1)
        return np.where(rows >= n_rows, -1, rows)

    def _digests(self, paths: list[str]) -> np.ndarray:
        out = np.zeros((len(paths), 2), dtype=np.uint64)
        for i, p in enumerate(paths):
            h = P.path_hash(p)
            out[i] = ((h >> 32) & 0xFFFFFFFF, h & 0xFFFFFFFF)
        return out

    def _norm(self, paths: Sequence[str]) -> list[str]:
        return [P.normalize(p, depth_budget=self.depth_budget) for p in paths]

    # ------------------------------------------------------------------
    def q1_get(self, paths):
        self.stats.record(Q1, len(paths))
        norm = self._norm(paths)
        rows = self._lookup_rows(self._digests(norm))
        return [self.records[r] if r >= 0 else None for r in rows]

    def q2_ls(self, paths):
        """One batched lookup; children come co-located in the resolved
        directory record ("children co-located with the parent"), so no
        second device op is needed.  (TensorWiki's CSR serves row-level
        traversal in core/tensorstore.py; the engine's record table
        already carries the same lists.)"""
        self.stats.record(Q2, len(paths))
        norm = self._norm(paths)
        rows = self._lookup_rows(self._digests(norm))
        out = []
        for p, r in zip(norm, rows):
            rec = self.records[r] if r >= 0 else None
            if rec is None or not isinstance(rec, R.DirRecord):
                out.append(None)
                continue
            out.append((rec, [P.child(p, s) for s in rec.children()]))
        return out

    def q3_navigate(self, paths):
        """The whole batch's ancestor chains flatten into ONE lookup
        launch — step compression applied to the storage layer itself."""
        self.stats.record(Q3, len(paths))
        norm = self._norm(paths)
        chains = [list(P.ancestors(p)) + [p] for p in norm]
        flat = [a for chain in chains for a in chain]
        rows = self._lookup_rows(self._digests(flat))
        # the flat lookup resolves every level even past a miss (the batch
        # is issued before results are known); the per-path result still
        # truncates at the first miss, matching PathStore.navigate
        return self._q3_truncate(chains, rows)

    def _q3_truncate(self, chains, rows) -> list[list[R.Record]]:
        out: list[list[R.Record]] = []
        i = 0
        for chain in chains:
            recs: list[R.Record] = []
            stopped = False
            for _ in chain:
                r = rows[i]
                i += 1
                if stopped:
                    continue
                rec = self.records[r] if r >= 0 else None
                if rec is None:
                    stopped = True
                else:
                    recs.append(rec)
            out.append(recs)
        return out

    def q4_search(self, prefixes, limit=None):
        """One prefix_search launch for the whole prefix batch: every
        pending prefix is compared against each resident path tile."""
        import jax.numpy as jnp
        from . import tensorstore as TS
        from ..kernels.ops import prefix_search
        self.stats.record(Q4, len(prefixes))
        if not prefixes:
            return []
        fixed = [p if p.startswith(P.SEP) else P.SEP + p for p in prefixes]
        L = self._max_path_bytes
        qp = self._pad_pow2(len(fixed), floor=4)
        # pad with unmatchable prefixes (0xFF never occurs in a path) so
        # the jitted scan sees bucketed shapes
        pref_mat = np.full((qp, L), 255, dtype=np.uint8)
        lens = np.full((qp,), 1, dtype=np.int32)
        long_idx: set[int] = set()
        for i, p in enumerate(fixed):
            blen = len(p.encode("utf-8"))
            if blen >= L:
                # the packed token matrix truncates at L bytes, so the
                # kernel cannot decide these exactly — resolve them from
                # the untruncated host-side path list instead (rare: the
                # depth budget keeps normal prefixes far below L)
                long_idx.add(i)
            else:
                pref_mat[i] = TS.pack_path(p, L)
                lens[i] = blen
        bitmap = np.asarray(prefix_search(
            self.wiki.lex_tokens, jnp.asarray(pref_mat), jnp.asarray(lens)))
        out: list[list[str]] = []
        for qi in range(len(fixed)):
            if qi in long_idx:
                seg_pref = fixed[qi].rstrip(P.SEP) or P.ROOT
                matches = sorted(
                    p for p in self.wiki.paths
                    if p.startswith(fixed[qi])
                    and (P.is_prefix(seg_pref, p) or p == fixed[qi]))
                out.append(matches if limit is None else matches[:limit])
                continue
            hits = np.nonzero(bitmap[:, qi])[0]
            matches = [self.wiki.paths[self._lex_order[i]] for i in hits]
            out.append(matches if limit is None else matches[:limit])
        return out

    def q4_contains(self, tokens, limit=None):
        """Keyword routing: the segment-token inverted index as a device
        lookup — token digests through the SAME Pallas path_lookup kernel,
        then a CSR slice of matching path rows.  Exact segment-token
        semantics, identical to PathStore.search_contains."""
        self.stats.record(Q4C, len(tokens))
        if not tokens:
            return []
        dig = np.zeros((len(tokens), 2), dtype=np.uint64)
        for i, t in enumerate(tokens):
            h = _token_hash(t.lower())
            dig[i] = ((h >> 32) & 0xFFFFFFFF, h & 0xFFFFFFFF)
        rows = self._lookup_rows(dig, table=(self._tok_hi, self._tok_lo))
        out: list[list[str]] = []
        for r in rows:
            if r < 0:
                out.append([])
                continue
            lo, hi = self._tok_offsets[r], self._tok_offsets[r + 1]
            prows = self._tok_rows[lo:hi]
            matches = [self.wiki.paths[i] for i in prows]
            out.append(matches if limit is None else matches[:limit])
        return out


# ---------------------------------------------------------------------------
# batch planner
# ---------------------------------------------------------------------------
class OpFuture:
    """Handle for one pending engine operation.  ``value`` is valid after
    the planner flush that executed its batch."""

    __slots__ = ("op", "arg", "value", "done")

    def __init__(self, op: str, arg):
        self.op = op
        self.arg = arg
        self.value = None
        self.done = False

    def result(self):
        if not self.done:
            raise RuntimeError(
                f"operation {self.op}({self.arg!r}) not flushed yet")
        return self.value


class BatchPlanner:
    """Collects Q1–Q4 operations from many concurrent sessions and
    executes each operator's pending set in ONE engine call per flush.

    Identical operations from different sessions are deduplicated into a
    single batch slot (they share the result), so a flush costs at most
    five engine round trips — one per live operator — regardless of how
    many sessions are in flight.
    """

    def __init__(self, engine: QueryEngine):
        self.engine = engine
        self._pending: dict[str, dict[object, list[OpFuture]]] = {}
        self._lock = threading.Lock()
        self.flushes = 0

    # -- operation futures --------------------------------------------------
    def _enqueue(self, op: str, key, arg) -> OpFuture:
        fut = OpFuture(op, arg)
        with self._lock:
            self._pending.setdefault(op, {}).setdefault(key, []).append(fut)
        return fut

    def get(self, path: str) -> OpFuture:
        return self._enqueue(Q1, path, path)

    def ls(self, path: str) -> OpFuture:
        return self._enqueue(Q2, path, path)

    def navigate(self, path: str) -> OpFuture:
        return self._enqueue(Q3, path, path)

    def search(self, prefix: str, limit: int | None = None) -> OpFuture:
        return self._enqueue(Q4, (prefix, limit), prefix)

    def contains(self, token: str, limit: int | None = None) -> OpFuture:
        return self._enqueue(Q4C, (token, limit), token)

    def pending_ops(self) -> int:
        return sum(len(futs) for by_key in self._pending.values()
                   for futs in by_key.values())

    # -- execution ----------------------------------------------------------
    def flush(self) -> int:
        """Execute every pending batch; one engine call per operator kind.
        Returns the number of futures resolved."""
        with self._lock:
            pending, self._pending = self._pending, {}
        if not pending:
            return 0
        self.flushes += 1
        resolved = 0
        for op, by_key in pending.items():
            keys = list(by_key)
            if op == Q1:
                results = self.engine.q1_get(keys)
            elif op == Q2:
                results = self.engine.q2_ls(keys)
            elif op == Q3:
                results = self.engine.q3_navigate(keys)
            elif op == Q4:
                # group by limit so one call covers each limit class
                results = self._ranged(self.engine.q4_search, keys)
            else:
                results = self._ranged(self.engine.q4_contains, keys)
            n_served = 0
            for key, value in zip(keys, results):
                for fut in by_key[key]:
                    fut.value = value
                    fut.done = True
                    n_served += 1
            self.engine.stats.record_served(op, n_served)
            resolved += n_served
        return resolved

    @staticmethod
    def _grouped_by_limit(keys):
        groups: dict[int | None, list] = {}
        for k in keys:
            groups.setdefault(k[1], []).append(k)
        return groups

    def _ranged(self, method, keys):
        """Execute (arg, limit) keyed scans: one engine call per distinct
        limit (usually exactly one)."""
        by_limit = self._grouped_by_limit(keys)
        res: dict[object, list[str]] = {}
        for limit, ks in by_limit.items():
            outs = method([k[0] for k in ks], limit=limit)
            for k, o in zip(ks, outs):
                res[k] = o
        return [res[k] for k in keys]


def drive(gen, planner: BatchPlanner):
    """Run one session generator to completion, flushing the planner at
    every yield point (the single-session degenerate case of the
    multi-session scheduler in navigate.run_sessions)."""
    try:
        while True:
            next(gen)
            planner.flush()
    except StopIteration as e:
        return e.value


__all__ = ["QueryEngine", "HostEngine", "DeviceEngine", "ShardedPathStore",
           "BatchPlanner", "OpFuture", "EngineStats", "drive",
           "Q1", "Q2", "Q3", "Q4", "Q4C"]
