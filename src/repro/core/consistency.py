"""Consistency protocol (paper §IV-C).

* **Write protocol (parent-after-child)** — to admit node v at π(v)=/d/e:
  (1) ``PUT(π(v), c(v))`` writes the child record;
  (2) ``UPDATE(π(parent(v)))`` appends the segment to the parent's child list.
  If (2) fails, v is an unadvertised orphan — harmless.

* **Read protocol (skip-on-miss)** — ``LS(π)`` fetches the directory record,
  then GETs each advertised child; a child GET that returns ⊥ is silently
  dropped.  Theorem 2: under write-order + monotonic cross-key visibility no
  reader ever returns an advertised-but-missing child.

* **OCC** — every file record carries a monotone ``version`` used as a
  compare-and-swap token.  The engine-level CAS atomicity (which TABLEKV
  provides natively) is modeled by a per-store mutex around the
  compare+put pair; writers that observe a stale version abort and retry.

* **Invalidation stream** — every completed parent-after-child write
  publishes a path-keyed event; the cache tier (core/cache.py) subscribes
  and refreshes any entry whose key is a prefix of (or equal to) the
  affected path.  Bounded staleness R3: Δ = max queue-drain delay.

The writer exposes *stepwise* primitives (``admit_steps``) so property
tests can interleave reader operations between step 1 and step 2 and check
Theorem 2 under every schedule.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, replace
from typing import Callable, Iterator, Optional

from . import paths as P
from . import records as R
from .store import PathStore


@dataclass(frozen=True)
class Invalidation:
    """Path-keyed cache-invalidation event (paper §V-C)."""

    path: str
    seq: int


class InvalidationBus:
    """In-process pub/sub with an explicit drain step.

    Events are queued at publish time and delivered on ``drain()`` —
    making the staleness window Δ an explicit, testable quantity instead
    of a thread-timing accident.  ``subscribe`` callbacks receive each
    event exactly once, in publish order.

    ``journal`` (optional) is the durable-tier hook: every publish is
    also appended to the write-ahead log (``storage.DurableKV
    .journal_invalidation``), making the bus a *crash-safe* complete
    dirty-path log — after a restart the device tier rehydrates its
    pending ``TensorDelta`` work list from the journaled, committed
    publishes (see docs/STORAGE.md).
    """

    def __init__(self, journal: Callable[[str], None] | None = None):
        self._subs: list[Callable[[Invalidation], None]] = []
        self._queue: list[Invalidation] = []
        self._seq = 0
        self._lock = threading.Lock()
        self.journal = journal

    def subscribe(self, fn: Callable[[Invalidation], None]) -> None:
        self._subs.append(fn)

    def publish(self, path: str) -> Invalidation:
        with self._lock:
            self._seq += 1
            ev = Invalidation(path=path, seq=self._seq)
            self._queue.append(ev)
        if self.journal is not None:
            self.journal(path)
        return ev

    def drain(self) -> int:
        """Deliver all pending events; returns the number delivered."""
        with self._lock:
            batch, self._queue = self._queue, []
        for ev in batch:
            for fn in self._subs:
                fn(ev)
        return len(batch)

    def pending(self) -> int:
        return len(self._queue)


def attach_journal(bus: InvalidationBus | None, store) -> bool:
    """Wire a bus's publishes into a durable store's WAL (no-op for
    volatile stores or when a journal is already attached).  Returns
    whether the bus now journals."""
    if bus is None:
        return False
    if bus.journal is not None:
        return True
    if getattr(store, "durable", False):
        bus.journal = store.journal_invalidation
        return True
    return False


class CASConflict(RuntimeError):
    """An OCC update observed a stale version and exhausted its retries."""


class WikiWriter:
    """The single offline writer for one subtree (paper §IV-C).

    Multi-process construction partitions by author subtree; within one
    subtree the pipeline is serial, so one ``WikiWriter`` per subtree with
    no cross-writer coordination reproduces the deployment's model.
    """

    def __init__(self, store: PathStore, bus: InvalidationBus | None = None,
                 clock: Callable[[], float] = time.time):
        self.store = store
        self.bus = bus
        self.clock = clock
        # models engine-native CAS atomicity; reentrant because parent-chain
        # auto-creation recurses while holding the lock
        self._cas_lock = threading.RLock()

    # ------------------------------------------------------------------
    # parent-after-child admission
    # ------------------------------------------------------------------
    def admit_steps(self, path: str, rec: R.Record) -> Iterator[str]:
        """Generator yielding after each protocol step, for interleaving
        tests.  Step order is the theorem's: child first, parent second."""
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        par = P.parent(path)
        is_dir = isinstance(rec, R.DirRecord)
        # step 1: child write
        self.store.put_record(path, rec)
        yield "child-written"
        # step 2: parent update (append segment)
        self._link_parent(par, P.basename(path), is_dir=is_dir)
        if self.bus is not None:
            self.bus.publish(path)
            self.bus.publish(par)
        yield "parent-updated"

    def admit(self, path: str, rec: R.Record) -> None:
        for _ in self.admit_steps(path, rec):
            pass

    def admit_subtree(self, items: list[tuple[str, R.Record]]) -> None:
        """Admit many nodes, parents-first in path depth order so every
        ``_link_parent`` finds its directory record present."""
        for path, rec in sorted(items, key=lambda it: P.depth(it[0])):
            if path == P.ROOT:
                self.put_record(path, rec)  # publishes like every write
                continue
            self.admit(path, rec)

    def ensure_root(self, summary: str = "") -> None:
        if self.store.get(P.ROOT) is None:
            self.put_record(
                P.ROOT, R.DirRecord(name="", summary=summary,
                                    meta=R.DirMeta(updated_at=self.clock())))

    # ------------------------------------------------------------------
    # raw write-through primitives (publish on every touched path)
    # ------------------------------------------------------------------
    # Every store mutation that flows through the writer publishes an
    # invalidation for the exact path it touched.  This is what makes the
    # bus a COMPLETE dirty-path log: the cache tier refreshes from it, and
    # engine.DeviceEngine materializes its per-epoch TensorDelta from it —
    # so evolution passes and errorbook repairs (which write through these
    # primitives) reach the device-resident index at the next refresh.
    def put_record(self, path: str, rec: R.Record) -> None:
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        self.store.put_record(path, rec)
        if self.bus is not None:
            self.bus.publish(path)

    def delete_record(self, path: str) -> None:
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        self.store.delete_record(path)
        if self.bus is not None:
            self.bus.publish(path)

    def get(self, path: str) -> Optional[R.Record]:
        return self.store.get(path)

    def _link_parent(self, par: str, segment: str, *, is_dir: bool) -> None:
        with self._cas_lock:
            prec = self.store.get(par)
            if prec is None:
                # auto-create the parent directory chain (bottom-up linking
                # preserves parent-after-child per level)
                prec = R.DirRecord(name=P.basename(par),
                                   meta=R.DirMeta(updated_at=self.clock()))
                self.store.put_record(par, prec)
                if par != P.ROOT:
                    self._link_parent(P.parent(par), P.basename(par), is_dir=True)
            if not isinstance(prec, R.DirRecord):
                raise ValueError(f"parent {par!r} is not a directory record")
            updated = prec.with_child(segment, is_dir=is_dir)
            updated = replace(updated, meta=replace(
                updated.meta, updated_at=self.clock()))
            self.store.put_record(par, updated)
            # publish every auto-created/updated ancestor level, not just
            # the immediate parent — the device delta must see the whole
            # chain of directory records whose child lists changed
            if self.bus is not None:
                self.bus.publish(par)

    # ------------------------------------------------------------------
    # page-level in-place rewrite under OCC (version CAS)
    # ------------------------------------------------------------------
    def update_file(self, path: str,
                    mutate: Callable[[R.FileRecord], R.FileRecord],
                    max_retries: int = 8) -> R.FileRecord:
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        for _ in range(max_retries):
            rec = self.store.get(path)
            if rec is None or not isinstance(rec, R.FileRecord):
                raise KeyError(f"no file record at {path!r}")
            expected = rec.meta.version
            new = mutate(rec)
            new = replace(new, meta=replace(new.meta, version=expected + 1))
            with self._cas_lock:
                cur = self.store.get(path)
                if (isinstance(cur, R.FileRecord)
                        and cur.meta.version == expected):
                    self.store.put_record(path, new)
                    if self.bus is not None:
                        self.bus.publish(path)
                    return new
            # stale — retry with the latest value
        raise CASConflict(f"CAS retries exhausted for {path!r}")

    def unlink(self, path: str) -> None:
        """Remove a node: reverse order (parent first, child second) so a
        concurrent reader sees at worst an unadvertised orphan, never an
        advertised-but-missing child."""
        path = P.normalize(path, depth_budget=self.store.depth_budget)
        par = P.parent(path)
        with self._cas_lock:
            prec = self.store.get(par)
            if isinstance(prec, R.DirRecord):
                self.store.put_record(par, prec.without_child(P.basename(path)))
        self.store.delete_record(path)
        if self.bus is not None:
            self.bus.publish(path)
            self.bus.publish(par)


class ConsistentReader:
    """Skip-on-miss read protocol (paper §IV-C)."""

    def __init__(self, store: PathStore):
        self.store = store

    def get(self, path: str) -> Optional[R.Record]:
        return self.store.get(path)

    def ls(self, path: str) -> Optional[tuple[R.DirRecord, list[tuple[str, R.Record]]]]:
        """Directory listing that GETs every advertised child and silently
        drops ⊥ entries (the skip-on-miss discipline)."""
        out = self.store.ls(path)
        if out is None:
            return None
        rec, child_paths = out
        resolved: list[tuple[str, R.Record]] = []
        for cp in child_paths:
            crec = self.store.get(cp)
            if crec is None:
                continue  # skip-on-miss: drop advertised-but-missing entries
            resolved.append((cp, crec))
        return rec, resolved
