"""Shard fan-out executor + pipelined commit sequencer (ISSUE 10).

Two small concurrency primitives that take the serial loops off the
sharded hot path while keeping ``REPRO_SHARD_WORKERS=0`` (the default)
bit-identical to the pre-executor for-loops:

* :class:`ShardExecutor` — the scatter/gather seam between
  ``ShardedPathStore`` and its shards.  ``scatter(fn, items)`` calls
  ``fn(index, item)`` for every item and gathers the results *in item
  order*; with ``workers == 0`` that is a plain list comprehension on
  the caller thread, with ``workers > 0`` the calls run on a shared
  thread pool so a slow shard no longer serializes behind its peers.
  The API is deliberately RPC-shaped — per-shard callables carry no
  shared mutable state and results come back positionally — so the
  future multi-process shard tier (ROADMAP) can replace the pool submit
  with a socket round trip without touching any call site.

* :class:`CommitSequencer` — depth-1 pipelined group commit.  A wave's
  WAL bytes are *sealed* synchronously under the shard locks (cheap
  buffer swap), then written + fsynced off-thread while the caller
  returns to compute the next wave; ``wait()`` joins the in-flight wave
  before the next seal, re-raising any worker failure on the caller
  thread.  Invariant: at most ONE sealed-but-not-yet-durable wave
  exists, and the *advertised* durable epoch (:meth:`durable_epoch`)
  advances only when that wave's fsync has landed — so the Δ = 1
  visibility contract never claims durability it does not have.

Observability: ``executor.queue_depth`` / ``executor.utilization``
gauges track scatter load; ``commit.pipeline_depth`` is 1 while a
sealed wave is in flight and 0 once it is durable.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterable, Sequence, TypeVar

from .. import obs

#: ``REPRO_SHARD_WORKERS`` — thread-pool size for shard fan-outs
#: (default 0 = serial on the caller thread, bit-compatible)
WORKERS_ENV = "REPRO_SHARD_WORKERS"
#: ``REPRO_COMMIT_PIPELINE`` — overlap wave e's WAL fsync with wave
#: e+1's compute (default 0 = synchronous group commit)
PIPELINE_ENV = "REPRO_COMMIT_PIPELINE"

_TRUTHY = ("1", "true", "on", "yes")

T = TypeVar("T")
R = TypeVar("R")


def resolve_shard_workers(explicit: int | None = None) -> int:
    """Resolve the fan-out pool size (arg > env > default 0 = serial)."""
    val = explicit if explicit is not None else \
        int(os.environ.get(WORKERS_ENV, "0"))
    if val < 0:
        raise ValueError(f"shard workers must be >= 0, got {val}")
    return val


def resolve_commit_pipeline(explicit: bool | None = None) -> bool:
    """Resolve the pipelined-commit switch (arg > env > default off)."""
    if explicit is not None:
        return bool(explicit)
    return os.environ.get(PIPELINE_ENV, "0").strip().lower() in _TRUTHY


class ShardExecutor:
    """Scatter/gather fan-out over shard-indexed work items.

    ``workers == 0`` (or a 0/1-item scatter) runs inline on the caller
    thread — same call order, same exception propagation, bit-identical
    results to the serial loops it replaced.  ``workers > 0`` submits
    every item to one lazily created shared pool and gathers in item
    order; the first item failure is re-raised on the caller thread,
    but only after every sibling has finished, so a failed fan-out
    never leaves stray work mutating the shards behind the caller.
    """

    def __init__(self, workers: int | None = None):
        self.workers = resolve_shard_workers(workers)
        self._pool: ThreadPoolExecutor | None = None
        self._lock = threading.Lock()
        self._inflight = 0

    def _ensure_pool(self) -> ThreadPoolExecutor:
        pool = self._pool
        if pool is None:
            with self._lock:
                pool = self._pool
                if pool is None:
                    pool = self._pool = ThreadPoolExecutor(
                        max_workers=self.workers,
                        thread_name_prefix="shard-exec")
        return pool

    def scatter(self, fn: Callable[[int, T], R], items: Iterable[T]
                ) -> list[R]:
        """``[fn(0, items[0]), fn(1, items[1]), ...]`` — concurrently
        when the pool is on, always gathered in item order."""
        work: Sequence[T] = items if isinstance(items, (list, tuple)) \
            else list(items)
        if self.workers == 0 or len(work) <= 1:
            return [fn(i, item) for i, item in enumerate(work)]
        pool = self._ensure_pool()
        with self._lock:
            self._inflight += len(work)
            depth = self._inflight
        obs.gauge("executor.queue_depth").set(depth)
        obs.gauge("executor.utilization").set(
            round(min(1.0, depth / self.workers), 4))
        try:
            futs = [pool.submit(fn, i, item) for i, item in enumerate(work)]
            out: list[R] = []
            first: BaseException | None = None
            for f in futs:
                try:
                    out.append(f.result())
                except BaseException as e:          # noqa: BLE001 - re-raised
                    if first is None:
                        first = e
                    out.append(None)                # type: ignore[arg-type]
            if first is not None:
                raise first
            return out
        finally:
            with self._lock:
                self._inflight -= len(work)
                depth = self._inflight
            obs.gauge("executor.queue_depth").set(depth)

    def close(self) -> None:
        """Shut the pool down (idempotent; a later scatter re-creates it)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)


class CommitSequencer:
    """Depth-1 commit pipeline: fsync of wave e overlaps compute of e+1.

    ``submit(epoch, completes)`` hands the sealed wave's deferred
    durability closures (WAL write + fsync + frozen-memtable spill per
    shard) to a dedicated single worker thread, which fans them out
    through the owning store's :class:`ShardExecutor`; ``wait()`` joins
    the in-flight wave and only then advances the advertised durable
    epoch.  A worker failure (IO error, injected crash) is re-raised by
    the next ``wait()`` on the caller thread — the epoch it carried is
    never advertised as durable.
    """

    def __init__(self, executor: ShardExecutor, durable_epoch: int = 0):
        self._exec = executor
        self._worker = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix="commit-seq")
        self._pending: tuple[int, Future] | None = None
        self._durable = durable_epoch

    def durable_epoch(self) -> int:
        """Newest epoch whose fsync has LANDED (never the sealed one)."""
        return self._durable

    def depth(self) -> int:
        """Sealed-but-not-yet-durable waves in flight (0 or 1)."""
        return 0 if self._pending is None else 1

    def wait(self) -> None:
        """Join the in-flight wave; re-raises its failure here.  The
        durable epoch advances exactly when this returns cleanly."""
        pending, self._pending = self._pending, None
        if pending is None:
            return
        epoch, fut = pending
        obs.gauge("commit.pipeline_depth").set(0)
        fut.result()
        self._durable = max(self._durable, epoch)

    def submit(self, epoch: int,
               completes: Sequence[Callable[[], None]]) -> None:
        """Launch the sealed wave's durability work off-thread.  An
        empty wave (every shard skipped the commit) is durable by
        definition — the epoch advances immediately."""
        assert self._pending is None, \
            "commit pipeline is depth-1: wait() before the next submit"
        if not completes:
            self._durable = max(self._durable, epoch)
            return
        fut = self._worker.submit(
            self._exec.scatter, lambda i, c: c(), list(completes))
        self._pending = (epoch, fut)
        obs.gauge("commit.pipeline_depth").set(1)

    # drain is wait by another name — call sites read better with it
    drain = wait

    def close(self) -> None:
        """Drain the in-flight wave (propagating its failure) and stop
        the worker thread."""
        try:
            self.wait()
        finally:
            self._worker.shutdown(wait=True)
