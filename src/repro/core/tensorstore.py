"""Device-resident tensorized path index — the TPU-native WikiKV core.

The paper's LevelDB point lookup becomes a *batched* device operation: the
whole online navigation tier resolves thousands of concurrent GET/LS/SEARCH
operations in one kernel launch (DESIGN.md §3).

Layout (frozen from a PathStore snapshot by the offline pipeline).  Row
tables are allocated with *slack capacity* (a 128-row multiple, matching
the Pallas lookup tile) so small deltas patch rows in place instead of
re-materializing the whole table:

  keys_hi, keys_lo : (cap,) uint32 pairs — 64-bit FNV digests H(π) in
                     *row-id* order.  Rows 0..n_rows-1 are allocated
                     (live or tombstoned); free slots and tombstones hold
                     0xFFFFFFFF sentinels.  ``sort_perm`` lists the live
                     rows in (hi, lo) order — the view binary search and
                     the Pallas kernel run over.
  path_tokens      : (cap, L) uint8 — normalized path bytes, zero-padded.
                     ``lex_order`` lists live rows in lexicographic path
                     order for prefix range scans.
  kinds            : (cap,) int8   — 0 dir, 1 file.
  access/depth     : (cap,) int32/int8 — co-located meta for evolution.
  child_index      : CSR (N0+1,) offsets into ``child_rows`` (int32 row
                     ids), packed at the last materialize; rows whose
                     child lists changed since then live in the
                     ``child_patch`` overlay (row -> tuple of child rows).
                     LS(π) = one lookup + one slice either way, no scan.
  dead             : (cap,) bool tombstone bitmap; ``row_of`` maps live
                     path -> row id.  A freshly materialized table has
                     sort_perm == lex-free identity, no tombstones and an
                     empty overlay.

Refresh modes (``apply_delta``): **patch** mutates rows in place for
small deltas (O(|Δ|) host work + O(N) memcpy-class array moves, stable
row ids); **rebuild/compact** is the full ``_materialize`` path —
entered when slack is exhausted, the tombstone fraction is high, the
overlay has grown past its bound, or the delta is a large fraction of
the table.  Patch ≡ rebuild is property-tested at the logical level
(tests/test_tensorstore.py).

Ownership: the patch path *consumes* its input snapshot — row tables are
mutated in place and returned in the successor ``TensorWiki``.  Reader
tiers must hold their own epoch view (engine.DeviceEngine snapshots the
device arrays + paths/records lists per epoch, so in-flight waves keep
reading epoch e while e+1 is patched — the double-buffered swap).

Query ops (pure-jnp reference here; ``kernels.path_lookup`` /
``kernels.prefix_search`` are the Pallas hot paths — ops.py dispatches):

  lookup(digests)       → row ids (−1 for miss)        [Q1, batched]
  ls_rows(row)          → child row ids                [Q2]
  prefix_search(prefix) → match bitmap over paths      [Q4, batched]

The L1 cache tier maps to the pinned row set: "/" and every dimension
"/d" (``depths <= 1``, ``n_pinned`` of them) stay VMEM-resident in the
serving engine — kernels/path_lookup.py probes them before touching the
HBM table.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field, replace

import numpy as np

import jax
import jax.numpy as jnp

from . import paths as P
from . import records as R
from .store import PathStore

MAX_PATH_BYTES = 96
#: row-table allocation granule — matches kernels.path_lookup.TILE so the
#: padded digest table is always kernel-eligible without re-padding
ROW_TILE = 128
#: digest value stored in free / tombstoned key slots: greater than every
#: real key (FNV of a non-empty path never yields 2^64−1), so the sorted
#: view stays searchable and sentinels can never satisfy a real query
KEY_SENTINEL = np.uint32(0xFFFFFFFF)

# -- patch-eligibility thresholds (apply_delta mode="auto") -----------------
#: deltas up to max(PATCH_MIN_DELTA, frac·n_live) rows patch in place
PATCH_MIN_DELTA = 16
PATCH_MAX_DELTA_FRAC = 0.25
#: compact (full rebuild) when tombstones would exceed this row fraction
PATCH_MAX_DEAD_FRAC = 0.25
#: compact when the children overlay outgrows max(64, n_live // 4) entries
PATCH_MIN_OVERLAY = 64


def _capacity(n: int) -> int:
    """Rows to allocate for n live rows: ≥ max(64, n/4) append slots,
    rounded up to the ROW_TILE granule."""
    want = n + max(64, n // 4)
    return -(-want // ROW_TILE) * ROW_TILE


def _digest_pair(path: str) -> tuple[int, int]:
    h = P.path_hash(path)
    return (h >> 32) & 0xFFFFFFFF, h & 0xFFFFFFFF


def pack_path(path: str, width: int = MAX_PATH_BYTES) -> np.ndarray:
    b = path.encode("utf-8")[:width]
    out = np.zeros((width,), dtype=np.uint8)
    out[: len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out


@dataclass
class TensorWiki:
    """Epoch snapshot of the device-resident wiki index (host master copy;
    the engine uploads/patches the device mirrors per epoch)."""

    keys_hi: np.ndarray         # (cap,) uint32 in row-id order (see module doc)
    keys_lo: np.ndarray         # (cap,) uint32
    path_tokens: np.ndarray     # (cap, L) uint8 in row-id order
    lex_order: np.ndarray       # (n_live,) int32 — live rows in lex path order
    lex_tokens: np.ndarray | None  # (n_live, L) uint8 lex-ordered; None after
                                   # a patch (derive via lex_token_matrix())
    kinds: np.ndarray           # (cap,) int8
    access: np.ndarray          # (cap,) int32
    depths: np.ndarray          # (cap,) int8
    child_offsets: np.ndarray   # (N0+1,) int32 CSR packed at last materialize
    child_rows: np.ndarray      # (E,) int32
    n_pinned: int               # live rows with depth <= 1 ("/" + dimensions)
    paths: list[str]            # row id -> path for rows 0..n_rows-1
    n_rows: int = 0             # allocated rows (live + tombstoned)
    sort_perm: np.ndarray | None = None   # (n_live,) int32, digest order
    dead: np.ndarray | None = None        # (cap,) bool tombstones
    n_dead: int = 0
    child_patch: dict = field(default_factory=dict)  # row -> tuple(child rows)
    row_of: dict = field(default_factory=dict)       # live path -> row id
    refresh_kind: str = "materialize"     # how this snapshot was produced

    @property
    def n(self) -> int:
        """Live row count (the logical table size)."""
        return self.n_rows - self.n_dead

    @property
    def cap(self) -> int:
        return int(self.keys_hi.shape[0])

    # -- views --------------------------------------------------------------
    def search_view(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(keys_hi, keys_lo, rows) of the live table in digest order —
        what binary search / the lookup kernel runs over.  A gather, not a
        sort: ``sort_perm`` is maintained incrementally by the patch path."""
        sp = self.sort_perm
        return self.keys_hi[sp], self.keys_lo[sp], sp

    def children_of(self, row: int) -> np.ndarray:
        """Child rows of a directory row: overlay entry if the row's list
        changed since the last materialize, packed CSR slice otherwise."""
        patched = self.child_patch.get(row)
        if patched is not None:
            return np.asarray(patched, dtype=np.int32)
        if row < len(self.child_offsets) - 1:
            lo, hi = int(self.child_offsets[row]), int(self.child_offsets[row + 1])
            return np.asarray(self.child_rows[lo:hi])
        return np.zeros((0,), dtype=np.int32)  # appended row, no overlay entry

    def live_mask(self) -> np.ndarray:
        return ~self.dead[: self.n_rows]

    def pinned_rows(self) -> np.ndarray:
        """Live rows of the L1 hot set ("/" + dimensions), row-id order."""
        return np.where((self.depths[: self.n_rows] <= 1)
                        & ~self.dead[: self.n_rows])[0].astype(np.int32)

    def lex_token_matrix(self) -> np.ndarray:
        """Lex-ordered token matrix; materialized lazily after a patch."""
        if self.lex_tokens is not None:
            return self.lex_tokens
        return self.path_tokens[self.lex_order]


def freeze(store: PathStore, max_path_bytes: int = MAX_PATH_BYTES) -> TensorWiki:
    """Snapshot a PathStore into the device-resident layout.

    Runs in the offline pipeline; the online tier swaps the frozen table
    atomically (the tensor-level analogue of the invalidation protocol —
    bounded staleness Δ = refresh cadence)."""
    return freeze_with_records(store, max_path_bytes)[0]


def freeze_with_records(store: PathStore,
                        max_path_bytes: int = MAX_PATH_BYTES
                        ) -> tuple[TensorWiki, list]:
    """``freeze`` plus the decoded records in row order — one store pass
    total, so engine.DeviceEngine snapshots don't pay 3×N point gets."""
    all_paths = sorted(store.all_paths())
    if not all_paths:
        raise ValueError("empty store")
    return _materialize(all_paths, [store.get(p) for p in all_paths],
                        max_path_bytes)


def _materialize(all_paths: list[str], all_recs: list,
                 max_path_bytes: int = MAX_PATH_BYTES
                 ) -> tuple[TensorWiki, list]:
    """Build the device layout from an in-memory (path, record) table —
    the shared tail of ``freeze_with_records`` (which sources records from
    a store pass) and ``apply_delta``'s rebuild/compact mode (which
    sources them from the previous snapshot + a TensorDelta, with zero
    store round trips)."""
    n = len(all_paths)
    if n == 0:
        raise ValueError("empty store")
    cap = _capacity(n)
    digests = np.zeros((n, 2), dtype=np.uint64)
    toks = np.zeros((cap, max_path_bytes), dtype=np.uint8)
    kinds = np.zeros((cap,), dtype=np.int8)
    access = np.zeros((cap,), dtype=np.int32)
    depths = np.zeros((cap,), dtype=np.int8)
    recs: list[R.Record | None] = list(all_recs)
    for i, p in enumerate(all_paths):
        hi, lo = _digest_pair(p)
        digests[i] = (hi, lo)
        toks[i] = pack_path(p, max_path_bytes)
        rec = recs[i]
        kinds[i] = 0 if isinstance(rec, R.DirRecord) else 1
        access[i] = 0 if rec is None else rec.meta.access_count
        depths[i] = P.depth(p)
    # sort rows by (hi, lo): row id == digest rank at materialize time
    order = np.lexsort((digests[:, 1], digests[:, 0]))
    digests = digests[order]
    toks[:n] = toks[order]
    kinds[:n] = kinds[order]
    access[:n] = access[order]
    depths[:n] = depths[order]
    keys_hi = np.full((cap,), KEY_SENTINEL, dtype=np.uint32)
    keys_lo = np.full((cap,), KEY_SENTINEL, dtype=np.uint32)
    keys_hi[:n] = digests[:, 0].astype(np.uint32)
    keys_lo[:n] = digests[:, 1].astype(np.uint32)
    sorted_paths = [all_paths[i] for i in order]
    sorted_recs = [recs[i] for i in order]
    row_of = {p: i for i, p in enumerate(sorted_paths)}
    # children CSR (reuses the records fetched above — no second pass)
    offsets = np.zeros((n + 1,), dtype=np.int32)
    rows: list[int] = []
    for i, p in enumerate(sorted_paths):
        rec = sorted_recs[i]
        kids: list[int] = []
        if isinstance(rec, R.DirRecord):
            for seg in rec.children():
                cp = P.child(p, seg)
                ci = row_of.get(cp)
                if ci is not None:
                    kids.append(ci)
        rows.extend(kids)
        offsets[i + 1] = len(rows)
    # lexicographic permutation over the live rows
    lex_perm = np.array(
        sorted(range(n), key=lambda i: sorted_paths[i]), dtype=np.int32)
    lex_toks = toks[lex_perm]
    # pinned hot set: "/" + dimensions == rows with depth <= 1; counted
    # straight off the depth column (no sort needed — the rows are
    # identified by depth, not by lex position)
    pinned = int(np.sum(depths[:n] <= 1))
    wiki = TensorWiki(
        keys_hi=keys_hi,
        keys_lo=keys_lo,
        path_tokens=toks,
        lex_order=lex_perm,
        lex_tokens=lex_toks,
        kinds=kinds,
        access=access,
        depths=depths,
        child_offsets=offsets,
        child_rows=np.asarray(rows, dtype=np.int32),
        n_pinned=pinned,
        paths=sorted_paths,
        n_rows=n,
        sort_perm=np.arange(n, dtype=np.int32),
        dead=np.zeros((cap,), dtype=bool),
        n_dead=0,
        child_patch={},
        row_of=row_of,
        refresh_kind="materialize",
    )
    return wiki, sorted_recs


# ---------------------------------------------------------------------------
# epoch-versioned incremental refresh
# ---------------------------------------------------------------------------
@dataclass
class TensorDelta:
    """One epoch's worth of row mutations against a ``TensorWiki``.

    ``upserts`` carries appended *and* overwritten rows (the row table is
    keyed by path, so one list covers both); ``unlinks`` lists removed
    paths.  ``epoch`` is the epoch this delta produces when applied.  The
    log of applied deltas is the device-tier analogue of the host
    invalidation stream: bounded staleness Δ = one refresh cadence.
    """

    epoch: int
    upserts: list[tuple[str, object]] = field(default_factory=list)
    unlinks: list[str] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.upserts) + len(self.unlinks)


@dataclass
class PatchInfo:
    """What ``apply_delta_ex`` did — the engine uses this to patch its
    device mirrors incrementally instead of re-uploading everything."""

    kind: str                   # "patch" | "rebuild"
    reason: str = ""            # why rebuild was chosen (mode="auto")
    new_rows: list[int] = field(default_factory=list)
    new_paths: list[str] = field(default_factory=list)
    removed_rows: list[int] = field(default_factory=list)
    removed_paths: list[str] = field(default_factory=list)
    overwritten_rows: list[int] = field(default_factory=list)
    keys_changed: bool = True   # digest table membership changed
    pinned_changed: bool = True # pinned (depth<=1) membership changed


def apply_delta(wiki: TensorWiki, records: list, delta: TensorDelta,
                *, mode: str = "auto") -> tuple[TensorWiki, list]:
    """Apply a ``TensorDelta`` to a snapshot, producing the next epoch's
    ``TensorWiki`` + row-aligned record table.  See ``apply_delta_ex``."""
    w, r, _ = apply_delta_ex(wiki, records, delta, mode=mode)
    return w, r


def apply_delta_ex(wiki: TensorWiki, records: list, delta: TensorDelta,
                   *, mode: str = "auto"
                   ) -> tuple[TensorWiki, list, PatchInfo]:
    """Incremental refresh: zero store round trips (contrast
    ``freeze_with_records``: full namespace scan + N point gets).

    mode="auto" patches rows in place when the delta is small and slack
    allows (O(|Δ|) host work), falling back to a full ``_materialize``
    compaction otherwise; "patch" demands the in-place path (raises if
    ineligible — benchmarks use this to isolate the two cost curves);
    "rebuild" forces the compaction path (row ids re-rank, tombstones and
    overlays fold away — byte-identical to a fresh freeze of the same
    logical table).

    The patch path consumes ``wiki``/``records`` (row tables are patched
    in place; see module docstring on ownership)."""
    ups: dict[str, object] = {}
    for p, rec in delta.upserts:
        ups[p] = rec                       # last write wins, like dict.update
    unl_eff = [p for p in dict.fromkeys(delta.unlinks)
               if p not in ups and p in wiki.row_of]
    n_new = sum(1 for p in ups if p not in wiki.row_of)
    if wiki.n - len(unl_eff) + n_new <= 0:
        # an empty TensorWiki is unrepresentable (same invariant as
        # freeze); surface the cause instead of _materialize's generic
        # "empty store" so a root-unlinking wave is debuggable
        raise ValueError(
            f"TensorDelta for epoch {delta.epoch} unlinks every resident "
            "row — refusing to commit an empty table")
    reason = "forced"
    if mode in ("auto", "patch"):
        patched, reason = _try_patch(wiki, records, delta, ups, unl_eff)
        if patched is not None:
            return patched
        if mode == "patch":
            raise ValueError(f"patch-mode refresh ineligible: {reason}")
    elif mode != "rebuild":
        raise ValueError(f"unknown apply_delta mode: {mode!r}")
    by_path: dict[str, object] = {p: records[r] for p, r in wiki.row_of.items()}
    for p in delta.unlinks:
        by_path.pop(p, None)
    for p, rec in delta.upserts:
        by_path[p] = rec
    paths = sorted(by_path)
    w2, r2 = _materialize(paths, [by_path[p] for p in paths],
                          int(wiki.path_tokens.shape[1]))
    w2 = replace(w2, refresh_kind="rebuild")
    return w2, r2, PatchInfo(kind="rebuild", reason=reason)


def _try_patch(wiki: TensorWiki, records: list, delta: TensorDelta,
               ups: dict, unl_eff: list[str]
               ) -> tuple[tuple[TensorWiki, list, PatchInfo] | None, str]:
    """In-place row patch, or (None, reason) when compaction is the right
    call.  O(|Δ|) python work + O(N) memcpy-class array moves (np.insert /
    np.delete on the int32 permutations)."""
    n_live = wiki.n
    new_paths = [p for p in ups if p not in wiki.row_of]
    n_delta = len(ups) + len(unl_eff)
    if n_delta > max(PATCH_MIN_DELTA, int(n_live * PATCH_MAX_DELTA_FRAC)):
        return None, f"delta too large ({n_delta} rows vs {n_live} live)"
    if wiki.n_rows + len(new_paths) > wiki.cap:
        return None, (f"row slack exhausted "
                      f"({wiki.n_rows}+{len(new_paths)} > cap {wiki.cap})")
    rows_after = wiki.n_rows + len(new_paths)
    if wiki.n_dead + len(unl_eff) > rows_after * PATCH_MAX_DEAD_FRAC:
        return None, (f"tombstone fraction "
                      f"({wiki.n_dead + len(unl_eff)}/{rows_after})")
    if (len(wiki.child_patch) + 2 * n_delta
            > max(PATCH_MIN_OVERLAY, n_live // 4)):
        return None, f"children overlay too large ({len(wiki.child_patch)})"

    L = int(wiki.path_tokens.shape[1])
    row_of = wiki.row_of                 # consumed: patched in place
    paths2 = list(wiki.paths)            # reader-visible: copy per epoch
    recs2 = list(records)
    keys_hi, keys_lo = wiki.keys_hi, wiki.keys_lo
    dead = wiki.dead
    touch_dirs: set[int] = set()

    def _touch_parent(p: str) -> None:
        if p == P.ROOT:
            return
        pr = row_of.get(P.parent(p))
        if pr is not None:
            touch_dirs.add(pr)

    # 1. tombstone unlinked rows (stable ids: no other row moves)
    removed_rows: list[int] = []
    for p in unl_eff:
        r = row_of.pop(p)
        dead[r] = True
        keys_hi[r] = KEY_SENTINEL
        keys_lo[r] = KEY_SENTINEL
        wiki.path_tokens[r] = 255        # unmatchable for prefix scans
        recs2[r] = None
        removed_rows.append(r)
        _touch_parent(p)
    # 2. append new rows into free slots
    new_rows: list[int] = []
    n_rows2 = wiki.n_rows
    for p in new_paths:
        r = n_rows2
        n_rows2 += 1
        hi, lo = _digest_pair(p)
        keys_hi[r] = hi
        keys_lo[r] = lo
        wiki.path_tokens[r] = pack_path(p, L)
        wiki.depths[r] = P.depth(p)
        paths2.append(p)
        recs2.append(None)               # set by the overwrite pass below
        row_of[p] = r
        new_rows.append(r)
        _touch_parent(p)
    # 3. overwrite row meta + payloads (covers new rows too)
    overwritten: list[int] = []
    child_patch2 = dict(wiki.child_patch)
    for r in removed_rows:
        child_patch2.pop(r, None)
    for p, rec in ups.items():
        r = row_of[p]
        wiki.kinds[r] = 0 if isinstance(rec, R.DirRecord) else 1
        wiki.access[r] = 0 if rec is None else rec.meta.access_count
        recs2[r] = rec
        if isinstance(rec, R.DirRecord):
            touch_dirs.add(r)
        else:
            child_patch2.pop(r, None)    # dir row overwritten by a file
        overwritten.append(r)
    # 4. recompute child lists for touched directories (parents of every
    #    appended/removed row + every upserted dir — re-admissions change
    #    a child's row id even when the parent record is byte-identical)
    for r in sorted(touch_dirs):
        if dead[r]:
            child_patch2.pop(r, None)
            continue
        rec = recs2[r]
        if not isinstance(rec, R.DirRecord):
            continue
        base = paths2[r]
        kids = [row_of[cp] for seg in rec.children()
                if (cp := P.child(base, seg)) in row_of]
        child_patch2[r] = tuple(kids)
    # 5. incremental permutation maintenance — np.delete/np.insert, not a
    #    re-sort: O(|Δ| log N) bisects + O(N) int32 moves
    lex2, sp2 = wiki.lex_order, wiki.sort_perm
    if removed_rows:
        gone = np.asarray(removed_rows, dtype=np.int32)
        lex2 = lex2[~np.isin(lex2, gone)]
        sp2 = sp2[~np.isin(sp2, gone)]
    if new_rows:
        by_lex = sorted(new_rows, key=paths2.__getitem__)
        pos_lex = [bisect.bisect_left(lex2, paths2[r],
                                      key=paths2.__getitem__)
                   for r in by_lex]
        lex2 = np.insert(lex2, pos_lex, by_lex).astype(np.int32, copy=False)

        def _key(r):
            return int(keys_hi[r]) << 32 | int(keys_lo[r])
        by_dig = sorted(new_rows, key=_key)
        pos_dig = [bisect.bisect_left(sp2, _key(r), key=_key) for r in by_dig]
        sp2 = np.insert(sp2, pos_dig, by_dig).astype(np.int32, copy=False)
    n_pinned2 = (wiki.n_pinned
                 - sum(1 for p in unl_eff if P.depth(p) <= 1)
                 + sum(1 for p in new_paths if P.depth(p) <= 1))
    info = PatchInfo(
        kind="patch",
        new_rows=new_rows,
        new_paths=new_paths,
        removed_rows=removed_rows,
        removed_paths=list(unl_eff),
        overwritten_rows=overwritten,
        keys_changed=bool(new_rows or removed_rows),
        pinned_changed=(n_pinned2 != wiki.n_pinned or any(
            P.depth(p) <= 1 for p in list(unl_eff) + new_paths)),
    )
    wiki2 = replace(
        wiki, lex_order=lex2, lex_tokens=None, sort_perm=sp2,
        paths=paths2, n_rows=n_rows2, n_dead=wiki.n_dead + len(removed_rows),
        n_pinned=n_pinned2, child_patch=child_patch2,
        refresh_kind="patch")
    return (wiki2, recs2, info), ""


def logical_state(wiki: TensorWiki, records: list) -> dict:
    """Canonical row-id-independent view of a snapshot — what patch ≡
    rebuild equivalence means (property-tested): per-path row contents +
    child lists, the lex view, the digest-sorted view, the pinned count."""
    rows = {}
    for p, r in wiki.row_of.items():
        rec = records[r]
        kids = tuple(sorted(wiki.paths[c] for c in wiki.children_of(r))) \
            if isinstance(rec, R.DirRecord) else ()
        rows[p] = (int(wiki.kinds[r]), int(wiki.access[r]),
                   int(wiki.depths[r]),
                   (int(wiki.keys_hi[r]), int(wiki.keys_lo[r])),
                   bytes(wiki.path_tokens[r]), kids, rec)
    return {
        "rows": rows,
        "lex": [wiki.paths[r] for r in wiki.lex_order],
        "digest": [wiki.paths[r] for r in wiki.sort_perm],
        "n_pinned": wiki.n_pinned,
    }


# ---------------------------------------------------------------------------
# pure-jnp reference ops (the Pallas kernels' oracles; ops.py dispatches to
# the kernels when the shapes warrant it)
# ---------------------------------------------------------------------------
@jax.jit
def lookup_ref(keys_hi: jax.Array, keys_lo: jax.Array,
               q_hi: jax.Array, q_lo: jax.Array) -> jax.Array:
    """Batched GET: vectorized binary search on sorted (hi, lo) uint32
    pairs, compared lexicographically.  Deliberately x64-free (TPUs have
    no native int64 either) — the same pair-comparison loop the Pallas
    kernel runs, ⌈log2 N⌉+1 steps for the whole query batch at once."""
    n = keys_hi.shape[0]
    lo = jnp.zeros(q_hi.shape, dtype=jnp.int32)
    hi = jnp.full(q_hi.shape, n, dtype=jnp.int32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, n - 1)
        khi = keys_hi[mid_c]
        klo = keys_lo[mid_c]
        lt = (khi < q_hi) | ((khi == q_hi) & (klo < q_lo))
        return (jnp.where(lt, mid + 1, lo), jnp.where(lt, hi, mid))

    steps = int(np.ceil(np.log2(max(int(n), 2)))) + 1
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    idx = jnp.clip(lo, 0, n - 1)
    hit = (keys_hi[idx] == q_hi) & (keys_lo[idx] == q_lo)
    return jnp.where(hit, idx, -1)


def batched_get(wiki: TensorWiki, query_paths: list[str]) -> np.ndarray:
    """Host convenience wrapper: paths → digests → lookup over the sorted
    live view → row ids (stable across patches)."""
    q = np.array([_digest_pair(p) for p in query_paths], dtype=np.uint64)
    khi, klo, view_rows = wiki.search_view()
    pos = np.asarray(lookup_ref(jnp.asarray(khi), jnp.asarray(klo),
                                jnp.asarray(q[:, 0].astype(np.uint32)),
                                jnp.asarray(q[:, 1].astype(np.uint32))))
    hit = pos >= 0
    safe = np.clip(pos, 0, max(len(view_rows) - 1, 0))
    return np.where(hit, view_rows[safe], -1)


@jax.jit
def prefix_match_ref(lex_tokens: jax.Array, prefix: jax.Array,
                     prefix_len: jax.Array) -> jax.Array:
    """Batched SEARCH: bitmap of rows whose path starts with ``prefix``.

    lex_tokens: (N, L) uint8; prefix: (L,) uint8; prefix_len: scalar int32.
    Segment-awareness (``/a`` must not match ``/ab``) is enforced by
    requiring the byte *after* the prefix to be 0 (end) or '/' when the
    prefix does not itself end in '/'."""
    L = lex_tokens.shape[1]
    pos = jnp.arange(L, dtype=jnp.int32)
    within = pos < prefix_len
    eq = (lex_tokens == prefix[None, :]) | ~within[None, :]
    starts = jnp.all(eq, axis=1)
    nxt = lex_tokens[:, jnp.minimum(prefix_len, L - 1)]
    last = prefix[jnp.maximum(prefix_len - 1, 0)]
    boundary_ok = (last == ord("/")) | (nxt == 0) | (nxt == ord("/"))
    exact_fits = prefix_len < L
    return starts & jnp.where(exact_fits, boundary_ok, True)


def search_prefix(wiki: TensorWiki, prefix: str) -> list[str]:
    """Prefix scan over the row-order token matrix (free slots are zeros
    and tombstones are 255s — neither can match a real prefix), results
    in lex order."""
    p = pack_path(prefix, int(wiki.path_tokens.shape[1]))
    bitmap = prefix_match_ref(
        jnp.asarray(wiki.path_tokens[: wiki.n_rows]), jnp.asarray(p),
        jnp.int32(len(prefix.encode("utf-8"))))
    hits = np.nonzero(np.asarray(bitmap) & wiki.live_mask())[0]
    return sorted(wiki.paths[r] for r in hits)


@jax.jit
def contains_match_ref(lex_tokens: jax.Array, needle: jax.Array,
                       needle_len: jax.Array) -> jax.Array:
    """Keyword containment over paths (NAV's EXTRACT routing): sliding
    window equality, vectorized over all rows and offsets."""
    N, L = lex_tokens.shape
    K = needle.shape[0]
    # windows: (N, L, K) via gather of shifted positions
    pos = jnp.arange(L, dtype=jnp.int32)[:, None] + jnp.arange(K, dtype=jnp.int32)[None, :]
    pos = jnp.minimum(pos, L - 1)
    windows = lex_tokens[:, pos]            # (N, L, K)
    within = jnp.arange(K, dtype=jnp.int32)[None, None, :] < needle_len
    eq = (windows == needle[None, None, :]) | ~within
    match_at = jnp.all(eq, axis=2)          # (N, L)
    valid_start = (jnp.arange(L, dtype=jnp.int32)[None, :]
                   + needle_len <= L)
    return jnp.any(match_at & valid_start, axis=1)


def ls_rows(wiki: TensorWiki, row: int) -> np.ndarray:
    return wiki.children_of(int(row))


def navigate_rows(wiki: TensorWiki, path: str) -> np.ndarray:
    """Q3 over the tensor index: one batched lookup resolves the whole
    ancestor chain at once — the step-compression idea applied to the
    storage layer itself (all D levels in one kernel launch)."""
    chain = list(P.ancestors(path)) + [path]
    return batched_get(wiki, chain)
