"""Device-resident tensorized path index — the TPU-native WikiKV core.

The paper's LevelDB point lookup becomes a *batched* device operation: the
whole online navigation tier resolves thousands of concurrent GET/LS/SEARCH
operations in one kernel launch (DESIGN.md §3).

Layout (frozen from a PathStore snapshot by the offline pipeline):

  keys_hi, keys_lo : (N,) uint32 pairs — the sorted 64-bit FNV digests
                     H(π) (sorted by (hi, lo), so binary search works on
                     the pair lexicographically).
  path_tokens      : (N, L) uint8 — normalized path bytes, zero-padded,
                     *sorted lexicographically* in a separate permutation
                     ``lex_order`` for prefix range scans.
  kinds            : (N,) int8   — 0 dir, 1 file.
  access/depth     : (N,) int32  — co-located meta for evolution operators.
  child_index      : CSR (N+1,) offsets into ``child_rows`` (int32 row ids)
                     — the "children co-located with the parent" contract:
                     LS(π) = one lookup + one CSR slice, no scan.

Query ops (pure-jnp reference here; ``kernels.path_lookup`` /
``kernels.prefix_search`` are the Pallas hot paths — ops.py dispatches):

  lookup(digests)       → row ids (−1 for miss)        [Q1, batched]
  ls_rows(row)          → child row ids                [Q2]
  prefix_search(prefix) → match bitmap over paths      [Q4, batched]

The L1 cache tier maps to the ``pinned`` row set: rows for "/" and every
"/d" are known at freeze time and stay resident (first rows of the table);
this is metadata (the whole table is device-resident anyway) but the
pinned prefix determines what the serving engine keeps in VMEM across
steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from . import paths as P
from . import records as R
from .store import PathStore

MAX_PATH_BYTES = 96


def _digest_pair(path: str) -> tuple[int, int]:
    h = P.path_hash(path)
    return (h >> 32) & 0xFFFFFFFF, h & 0xFFFFFFFF


def pack_path(path: str, width: int = MAX_PATH_BYTES) -> np.ndarray:
    b = path.encode("utf-8")[:width]
    out = np.zeros((width,), dtype=np.uint8)
    out[: len(b)] = np.frombuffer(b, dtype=np.uint8)
    return out


@dataclass
class TensorWiki:
    """Frozen, device-resident wiki index."""

    keys_hi: jax.Array          # (N,) uint32, sorted with keys_lo
    keys_lo: jax.Array          # (N,) uint32
    path_tokens: jax.Array      # (N, L) uint8 in hash-sorted row order
    lex_order: jax.Array        # (N,) int32 — rows in lexicographic path order
    lex_tokens: jax.Array       # (N, L) uint8 in lexicographic order
    kinds: jax.Array            # (N,) int8
    access: jax.Array           # (N,) int32
    depths: jax.Array           # (N,) int8
    child_offsets: jax.Array    # (N+1,) int32 CSR
    child_rows: jax.Array       # (E,) int32
    n_pinned: int               # rows 0..n_pinned-1 of lex order = "/" + dims
    paths: list[str]            # host-side row id -> logical path (debug/decode)

    @property
    def n(self) -> int:
        return int(self.keys_hi.shape[0])


def freeze(store: PathStore, max_path_bytes: int = MAX_PATH_BYTES) -> TensorWiki:
    """Snapshot a PathStore into the device-resident layout.

    Runs in the offline pipeline; the online tier swaps the frozen table
    atomically (the tensor-level analogue of the invalidation protocol —
    bounded staleness Δ = refresh cadence)."""
    return freeze_with_records(store, max_path_bytes)[0]


def freeze_with_records(store: PathStore,
                        max_path_bytes: int = MAX_PATH_BYTES
                        ) -> tuple[TensorWiki, list]:
    """``freeze`` plus the decoded records in row order — one store pass
    total, so engine.DeviceEngine snapshots don't pay 3×N point gets."""
    all_paths = sorted(store.all_paths())
    if not all_paths:
        raise ValueError("empty store")
    return _materialize(all_paths, [store.get(p) for p in all_paths],
                        max_path_bytes)


def _materialize(all_paths: list[str], all_recs: list,
                 max_path_bytes: int = MAX_PATH_BYTES
                 ) -> tuple[TensorWiki, list]:
    """Build the device layout from an in-memory (path, record) table —
    the shared tail of ``freeze_with_records`` (which sources records from
    a store pass) and ``apply_delta`` (which sources them from the
    previous snapshot + a TensorDelta, with zero store round trips)."""
    n = len(all_paths)
    if n == 0:
        raise ValueError("empty store")
    digests = np.zeros((n, 2), dtype=np.uint64)
    toks = np.zeros((n, max_path_bytes), dtype=np.uint8)
    kinds = np.zeros((n,), dtype=np.int8)
    access = np.zeros((n,), dtype=np.int32)
    depths = np.zeros((n,), dtype=np.int8)
    recs: list[R.Record | None] = list(all_recs)
    for i, p in enumerate(all_paths):
        hi, lo = _digest_pair(p)
        digests[i] = (hi, lo)
        toks[i] = pack_path(p, max_path_bytes)
        rec = recs[i]
        kinds[i] = 0 if isinstance(rec, R.DirRecord) else 1
        access[i] = 0 if rec is None else rec.meta.access_count
        depths[i] = P.depth(p)
    # sort rows by (hi, lo)
    order = np.lexsort((digests[:, 1], digests[:, 0]))
    digests = digests[order]
    toks_h = toks[order]
    kinds = kinds[order]
    access = access[order]
    depths = depths[order]
    sorted_paths = [all_paths[i] for i in order]
    sorted_recs = [recs[i] for i in order]
    row_of = {p: i for i, p in enumerate(sorted_paths)}
    # children CSR (reuses the records fetched above — no second pass)
    offsets = np.zeros((n + 1,), dtype=np.int32)
    rows: list[int] = []
    for i, p in enumerate(sorted_paths):
        rec = sorted_recs[i]
        kids: list[int] = []
        if isinstance(rec, R.DirRecord):
            for seg in rec.children():
                cp = P.child(p, seg)
                ci = row_of.get(cp)
                if ci is not None:
                    kids.append(ci)
        rows.extend(kids)
        offsets[i + 1] = len(rows)
    # lexicographic permutation over the *original sorted path list*
    lex_paths = sorted_paths  # row order is hash order; build lex view
    lex_perm = np.array(
        sorted(range(n), key=lambda i: lex_paths[i]), dtype=np.int32)
    lex_toks = toks_h[lex_perm]
    # pinned prefix: "/" + dimensions first in lex order (they sort early
    # because "/" < "/d/..." at equal prefixes — compute exactly)
    pinned = sum(1 for p in sorted(lex_paths) if P.depth(p) <= 1)
    wiki = TensorWiki(
        keys_hi=jnp.asarray(digests[:, 0].astype(np.uint32)),
        keys_lo=jnp.asarray(digests[:, 1].astype(np.uint32)),
        path_tokens=jnp.asarray(toks_h),
        lex_order=jnp.asarray(lex_perm),
        lex_tokens=jnp.asarray(lex_toks),
        kinds=jnp.asarray(kinds),
        access=jnp.asarray(access),
        depths=jnp.asarray(depths),
        child_offsets=jnp.asarray(offsets),
        child_rows=jnp.asarray(np.asarray(rows, dtype=np.int32)),
        n_pinned=int(pinned),
        paths=sorted_paths,
    )
    return wiki, sorted_recs


# ---------------------------------------------------------------------------
# epoch-versioned incremental refresh
# ---------------------------------------------------------------------------
@dataclass
class TensorDelta:
    """One epoch's worth of row mutations against a ``TensorWiki``.

    ``upserts`` carries appended *and* overwritten rows (the row table is
    keyed by path, so one list covers both); ``unlinks`` lists removed
    paths.  ``epoch`` is the epoch this delta produces when applied.  The
    log of applied deltas is the device-tier analogue of the host
    invalidation stream: bounded staleness Δ = one refresh cadence.
    """

    epoch: int
    upserts: list[tuple[str, object]] = field(default_factory=list)
    unlinks: list[str] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.upserts) + len(self.unlinks)


def apply_delta(wiki: TensorWiki, records: list,
                delta: TensorDelta) -> tuple[TensorWiki, list]:
    """Apply a ``TensorDelta`` to a snapshot, producing the next epoch's
    ``TensorWiki`` + row-aligned record table.

    This is the *incremental* refresh path: it never touches the backing
    store (contrast ``freeze_with_records``: one full namespace scan plus
    N point gets).  All inputs come from the previous snapshot and the
    delta itself; the array rebuild is pure in-memory host work, so the
    storage-layer cost of a refresh is exactly the O(|Δ|) point gets the
    caller spent materializing the delta."""
    by_path: dict[str, object] = dict(zip(wiki.paths, records))
    for p in delta.unlinks:
        by_path.pop(p, None)
    for p, rec in delta.upserts:
        by_path[p] = rec
    if not by_path:
        # an empty TensorWiki is unrepresentable (same invariant as
        # freeze); surface the cause instead of _materialize's generic
        # "empty store" so a root-unlinking wave is debuggable
        raise ValueError(
            f"TensorDelta for epoch {delta.epoch} unlinks every resident "
            "row — refusing to commit an empty table")
    paths = sorted(by_path)
    return _materialize(paths, [by_path[p] for p in paths],
                        int(wiki.path_tokens.shape[1]))


# ---------------------------------------------------------------------------
# pure-jnp reference ops (the Pallas kernels' oracles; ops.py dispatches to
# the kernels when the shapes warrant it)
# ---------------------------------------------------------------------------
@jax.jit
def lookup_ref(keys_hi: jax.Array, keys_lo: jax.Array,
               q_hi: jax.Array, q_lo: jax.Array) -> jax.Array:
    """Batched GET: vectorized binary search on sorted (hi, lo) uint32
    pairs, compared lexicographically.  Deliberately x64-free (TPUs have
    no native int64 either) — the same pair-comparison loop the Pallas
    kernel runs, ⌈log2 N⌉+1 steps for the whole query batch at once."""
    n = keys_hi.shape[0]
    lo = jnp.zeros(q_hi.shape, dtype=jnp.int32)
    hi = jnp.full(q_hi.shape, n, dtype=jnp.int32)

    def body(_, lh):
        lo, hi = lh
        mid = (lo + hi) // 2
        mid_c = jnp.clip(mid, 0, n - 1)
        khi = keys_hi[mid_c]
        klo = keys_lo[mid_c]
        lt = (khi < q_hi) | ((khi == q_hi) & (klo < q_lo))
        return (jnp.where(lt, mid + 1, lo), jnp.where(lt, hi, mid))

    steps = int(np.ceil(np.log2(max(int(n), 2)))) + 1
    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    idx = jnp.clip(lo, 0, n - 1)
    hit = (keys_hi[idx] == q_hi) & (keys_lo[idx] == q_lo)
    return jnp.where(hit, idx, -1)


def batched_get(wiki: TensorWiki, query_paths: list[str]) -> np.ndarray:
    """Host convenience wrapper: paths → digests → device lookup → row ids."""
    q = np.array([_digest_pair(p) for p in query_paths], dtype=np.uint64)
    rows = lookup_ref(wiki.keys_hi, wiki.keys_lo,
                      jnp.asarray(q[:, 0].astype(np.uint32)),
                      jnp.asarray(q[:, 1].astype(np.uint32)))
    return np.asarray(rows)


@jax.jit
def prefix_match_ref(lex_tokens: jax.Array, prefix: jax.Array,
                     prefix_len: jax.Array) -> jax.Array:
    """Batched SEARCH: bitmap of rows whose path starts with ``prefix``.

    lex_tokens: (N, L) uint8; prefix: (L,) uint8; prefix_len: scalar int32.
    Segment-awareness (``/a`` must not match ``/ab``) is enforced by
    requiring the byte *after* the prefix to be 0 (end) or '/' when the
    prefix does not itself end in '/'."""
    L = lex_tokens.shape[1]
    pos = jnp.arange(L, dtype=jnp.int32)
    within = pos < prefix_len
    eq = (lex_tokens == prefix[None, :]) | ~within[None, :]
    starts = jnp.all(eq, axis=1)
    nxt = lex_tokens[:, jnp.minimum(prefix_len, L - 1)]
    last = prefix[jnp.maximum(prefix_len - 1, 0)]
    boundary_ok = (last == ord("/")) | (nxt == 0) | (nxt == ord("/"))
    exact_fits = prefix_len < L
    return starts & jnp.where(exact_fits, boundary_ok, True)


def search_prefix(wiki: TensorWiki, prefix: str) -> list[str]:
    p = pack_path(prefix, int(wiki.lex_tokens.shape[1]))
    bitmap = prefix_match_ref(
        wiki.lex_tokens, jnp.asarray(p),
        jnp.int32(len(prefix.encode("utf-8"))))
    hits = np.nonzero(np.asarray(bitmap))[0]
    lex = np.asarray(wiki.lex_order)
    return [wiki.paths[lex[i]] for i in hits]


@jax.jit
def contains_match_ref(lex_tokens: jax.Array, needle: jax.Array,
                       needle_len: jax.Array) -> jax.Array:
    """Keyword containment over paths (NAV's EXTRACT routing): sliding
    window equality, vectorized over all rows and offsets."""
    N, L = lex_tokens.shape
    K = needle.shape[0]
    # windows: (N, L, K) via gather of shifted positions
    pos = jnp.arange(L, dtype=jnp.int32)[:, None] + jnp.arange(K, dtype=jnp.int32)[None, :]
    pos = jnp.minimum(pos, L - 1)
    windows = lex_tokens[:, pos]            # (N, L, K)
    within = jnp.arange(K, dtype=jnp.int32)[None, None, :] < needle_len
    eq = (windows == needle[None, None, :]) | ~within
    match_at = jnp.all(eq, axis=2)          # (N, L)
    valid_start = (jnp.arange(L, dtype=jnp.int32)[None, :]
                   + needle_len <= L)
    return jnp.any(match_at & valid_start, axis=1)


def ls_rows(wiki: TensorWiki, row: int) -> np.ndarray:
    off = np.asarray(wiki.child_offsets)
    lo, hi = int(off[row]), int(off[row + 1])
    return np.asarray(wiki.child_rows[lo:hi])


def navigate_rows(wiki: TensorWiki, path: str) -> np.ndarray:
    """Q3 over the tensor index: one batched lookup resolves the whole
    ancestor chain at once — the step-compression idea applied to the
    storage layer itself (all D levels in one kernel launch)."""
    chain = list(P.ancestors(path)) + [path]
    return batched_get(wiki, chain)
