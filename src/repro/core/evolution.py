"""Continuous evolution operators (paper §III-D).

* **AccessStats** — per-query co-access indicators.  The online tier is
  read-only, so NAV accumulates accessed-path sets into an in-memory log;
  the offline pipeline merges the log into (a) each record's
  ``access_count`` meta and (b) a sibling co-access sketch persisted at
  the reserved path ``/_meta/coaccess`` — keeping the paper's property
  that no external analytics warehouse is required: all statistics live
  in the same path-keyed store.

* **DIMENSIONMERGE** (Operator 1) — for sibling internal nodes v1, v2,
  estimate MI of the per-query co-access indicators (Eq. 2); when
  MI > θ_merge, merge: child list = union, access_count = sum, content =
  concatenation of summaries.

* **PAGESPLIT** (Operator 2) — Architect proposes candidates (length
  trigger or oracle adjudication of separable subtrees); Critic scores
  Δ̃C (Eq. 3) from co-located access/confidence statistics; Arbiter
  commits {e : Δ̃C<0 ∧ Safety(e)}, |C_t| ≤ K, node-disjoint.

**Theorem 1 discipline.**  The Critic's Δ̃C is an estimate; to make the
monotone-improvement guarantee *checkable* rather than assumed, the
Arbiter verifies each candidate exactly: apply → recompute C (Eq. 1) →
roll back if the measured ΔC > 0.  Estimation prunes, measurement admits.
This is strictly stronger than the paper's admissibility test and makes
the tests/test_evolution.py property (C non-increasing along the greedy
trajectory) hold by construction *and* by measurement.
"""
from __future__ import annotations

import itertools
import json
import math
from dataclasses import dataclass, field, replace

from . import paths as P
from . import records as R
from .consistency import WikiWriter
from .oracle import Oracle
from .schema import SchemaParams, schema_cost
from .store import PathStore

COACCESS_PATH = "/_meta/coaccess"


# ---------------------------------------------------------------------------
# access statistics
# ---------------------------------------------------------------------------
@dataclass
class AccessLog:
    """Per-query accessed-path sets recorded by the online tier."""

    queries: list[set[str]] = field(default_factory=list)

    def record(self, accessed: set[str]) -> None:
        self.queries.append(set(accessed))

    def __len__(self) -> int:
        return len(self.queries)


@dataclass
class CoAccessSketch:
    """n_queries, per-path marginals, sibling-pair joint counts."""

    n_queries: int = 0
    marginal: dict[str, int] = field(default_factory=dict)
    joint: dict[str, int] = field(default_factory=dict)  # "p1|p2" sorted key

    @staticmethod
    def pair_key(p1: str, p2: str) -> str:
        a, b = sorted((p1, p2))
        return f"{a}|{b}"

    def merge_log(self, log: AccessLog) -> None:
        for q in log.queries:
            self.n_queries += 1
            for p in q:
                self.marginal[p] = self.marginal.get(p, 0) + 1
            # only sibling pairs matter for DIMENSIONMERGE; cap quadratic blowup
            tops = sorted(p for p in q if P.depth(p) == 1)
            for p1, p2 in itertools.combinations(tops, 2):
                k = self.pair_key(p1, p2)
                self.joint[k] = self.joint.get(k, 0) + 1

    def mutual_information(self, p1: str, p2: str) -> float:
        """MI of the binary co-access indicators X1, X2 (paper Eq. 2)."""
        n = self.n_queries
        if n == 0:
            return 0.0
        c1 = self.marginal.get(p1, 0)
        c2 = self.marginal.get(p2, 0)
        c12 = self.joint.get(self.pair_key(p1, p2), 0)
        # joint table over {0,1}×{0,1}
        p11 = c12 / n
        p10 = max(c1 - c12, 0) / n
        p01 = max(c2 - c12, 0) / n
        p00 = max(n - c1 - c2 + c12, 0) / n
        m1 = c1 / n
        m2 = c2 / n
        mi = 0.0
        for pxy, px, py in (
            (p11, m1, m2), (p10, m1, 1 - m2),
            (p01, 1 - m1, m2), (p00, 1 - m1, 1 - m2),
        ):
            if pxy > 0 and px > 0 and py > 0:
                mi += pxy * math.log(pxy / (px * py))
        return mi

    # persistence in the same store (reserved, unadvertised).  ``store``
    # may be a PathStore or a WikiWriter — writing through the writer
    # additionally publishes the invalidation (device mirror + cache).
    def save(self, store) -> None:
        store.put_record(COACCESS_PATH, R.FileRecord(
            name="coaccess",
            text=json.dumps({"n": self.n_queries, "m": self.marginal,
                             "j": self.joint}, sort_keys=True)))

    @classmethod
    def load(cls, store: PathStore) -> "CoAccessSketch":
        rec = store.get(COACCESS_PATH)
        if rec is None or not isinstance(rec, R.FileRecord) or not rec.text:
            return cls()
        o = json.loads(rec.text)
        return cls(n_queries=o.get("n", 0), marginal=o.get("m", {}),
                   joint=o.get("j", {}))


def apply_access_log(writer: WikiWriter, log: AccessLog) -> CoAccessSketch:
    """Offline merge of the online access log into record meta + sketch."""
    counts: dict[str, int] = {}
    for q in log.queries:
        for p in q:
            counts[p] = counts.get(p, 0) + 1
    for path, c in counts.items():
        rec = writer.store.get(path)
        if rec is None:
            continue
        writer.put_record(path, replace(
            rec, meta=replace(rec.meta, access_count=rec.meta.access_count + c)))
    sketch = CoAccessSketch.load(writer.store)
    sketch.merge_log(log)
    sketch.save(writer)
    return sketch


# ---------------------------------------------------------------------------
# operator result bookkeeping
# ---------------------------------------------------------------------------
@dataclass
class OpResult:
    op: str
    target: str
    est_delta: float
    measured_delta: float
    committed: bool
    detail: str = ""


class _Snapshot:
    """Record-level undo log for exact Arbiter verification.

    ``store`` may be a ``PathStore`` or a ``WikiWriter`` (both expose
    get/put_record/delete_record); through a writer, rollback writes
    publish invalidations too — a rolled-back operator trial must reach
    the device mirror and cache just like a committed one."""

    def __init__(self, store):
        self.store = store
        self.saved: dict[str, R.Record | None] = {}

    def touch(self, path: str) -> None:
        if path not in self.saved:
            self.saved[path] = self.store.get(path)

    def rollback(self) -> None:
        for path, rec in self.saved.items():
            if rec is None:
                self.store.delete_record(path)
            else:
                self.store.put_record(path, rec)


# ---------------------------------------------------------------------------
# Operator 1: DIMENSIONMERGE
# ---------------------------------------------------------------------------
def merge_candidates(store: PathStore, sketch: CoAccessSketch,
                     params: SchemaParams) -> list[tuple[str, str, float]]:
    """Sibling dimension pairs with MI above θ_merge, highest first."""
    root = store.get(P.ROOT)
    if not isinstance(root, R.DirRecord):
        return []
    dims = [P.child(P.ROOT, s) for s in root.sub_dirs]
    out = []
    for d1, d2 in itertools.combinations(sorted(dims), 2):
        mi = sketch.mutual_information(d1, d2)
        if mi > params.theta_merge:
            out.append((d1, d2, mi))
    out.sort(key=lambda t: -t[2])
    return out


def _move_subtree(store, src: str, dst: str, snap: _Snapshot) -> None:
    """Rename src → dst by copy-then-delete, children-first writes so a
    concurrent reader never follows an advertised link to a missing record.
    ``store`` is a PathStore or WikiWriter (writer-mediated moves publish
    every touched path)."""
    rec = store.get(src)
    if rec is None:
        return
    snap.touch(dst)
    snap.touch(src)
    if isinstance(rec, R.DirRecord):
        existing = store.get(dst)
        if isinstance(existing, R.DirRecord):
            merged = existing
            for s in rec.sub_dirs:
                merged = merged.with_child(s, is_dir=True)
            for s in rec.files:
                merged = merged.with_child(s, is_dir=False)
            merged = replace(merged, summary=(existing.summary + " " + rec.summary).strip(),
                             meta=replace(merged.meta,
                                          access_count=existing.meta.access_count
                                          + rec.meta.access_count))
            store.put_record(dst, merged)
        else:
            store.put_record(dst, replace(rec, name=P.basename(dst)))
        for seg in rec.children():
            _move_subtree(store, P.child(src, seg), P.child(dst, seg), snap)
    else:
        existing = store.get(dst)
        if isinstance(existing, R.FileRecord):
            store.put_record(dst, replace(
                existing,
                text=(existing.text + "\n" + rec.text).strip(),
                meta=replace(existing.meta,
                             access_count=existing.meta.access_count
                             + rec.meta.access_count,
                             sources=sorted(set(existing.meta.sources)
                                            | set(rec.meta.sources)))))
        else:
            store.put_record(dst, replace(rec, name=P.basename(dst)))
    store.delete_record(src)


def apply_dimension_merge(writer: WikiWriter, d1: str, d2: str,
                          snap: _Snapshot) -> None:
    """Merge d2 into d1: child-list union, access sum, summary concat.
    The merged node keeps d1's segment so d1's paths stay stable; d2's
    subtree is rewritten under d1 (path-as-key means rename = rewrite)."""
    store = writer.store
    r1, r2 = store.get(d1), store.get(d2)
    if not isinstance(r1, R.DirRecord) or not isinstance(r2, R.DirRecord):
        return
    snap.touch(d1)
    snap.touch(d2)
    snap.touch(P.ROOT)
    # move children of d2 under d1 (children first); writer-mediated so
    # every rewritten path publishes an invalidation
    for seg in r2.children():
        _move_subtree(writer, P.child(d2, seg), P.child(d1, seg), snap)
    # refresh d1 record: union handled by _move_subtree linking below
    r1b = store.get(d1)
    assert isinstance(r1b, R.DirRecord)
    for seg in r2.sub_dirs:
        r1b = r1b.with_child(seg, is_dir=True)
    for seg in r2.files:
        r1b = r1b.with_child(seg, is_dir=False)
    r1b = replace(
        r1b,
        summary=(r1b.summary + " " + r2.summary).strip(),
        meta=replace(r1b.meta,
                     access_count=r1b.meta.access_count + r2.meta.access_count))
    writer.put_record(d1, r1b)
    # unlink d2 from the root, then delete its record (parent-first removal)
    root = store.get(P.ROOT)
    if isinstance(root, R.DirRecord):
        writer.put_record(P.ROOT, root.without_child(P.basename(d2)))
    writer.delete_record(d2)


# ---------------------------------------------------------------------------
# Operator 2: PAGESPLIT (Architect — Critic — Arbiter)
# ---------------------------------------------------------------------------
@dataclass
class SplitCandidate:
    path: str
    heads: list[str]
    est_delta: float = 0.0


def architect_propose(store: PathStore, oracle: Oracle,
                      params: SchemaParams) -> list[SplitCandidate]:
    """Rule-triggered proposals with the oracle as a local adjudicator:
    (i) length(e) > l_max, or (ii) the oracle finds separable subtrees."""
    out: list[SplitCandidate] = []
    for path in store.all_paths():
        if P.is_reserved(path) or P.node_type(path) != P.NODE_ENTITY:
            continue
        if P.depth(path) >= params.depth_budget - 1:
            continue  # a split would violate the depth budget — not proposable
        rec = store.get(path)
        if not isinstance(rec, R.FileRecord) or not rec.text:
            continue
        triggered = len(rec.text) > params.l_max
        heads = oracle.adjudicate_split(rec.text) if (
            triggered or len(rec.text) > params.l_max // 2) else None
        if heads and len(heads) >= 2:
            out.append(SplitCandidate(path=path, heads=heads))
    return out


def critic_score(store: PathStore, cand: SplitCandidate,
                 params: SchemaParams, total_access: int) -> float:
    """Δ̃C(e;W) = αΔ|V| + βΔ(depth·ρ) − γΔQ̃ (paper Eq. 3)."""
    rec = store.get(cand.path)
    assert isinstance(rec, R.FileRecord)
    k = len(cand.heads)
    d = P.depth(cand.path)
    rho = rec.meta.access_count / total_access if total_access else 0.0
    dV = k  # k new child pages; the hub page remains
    # post-split, the hub keeps a stub summary and the access mass lands one
    # level deeper on the specific sub-page the query wanted:
    d_depth = (d + 1) * rho - d * rho
    # quality surrogate: an over-long mixed page under-serves queries; each
    # sub-page is single-topic.  Gain ∝ access mass × (1 − confidence).
    dQ = rho * (1.0 - rec.meta.confidence) + 0.05 * rho
    return params.alpha * dV + params.beta * d_depth - params.gamma * dQ


def safety_check(store: PathStore, cand: SplitCandidate,
                 params: SchemaParams) -> bool:
    """Safety(e): every entity reachable in S_t remains reachable in S_{t+1}
    and the split respects the structural constraints."""
    if P.depth(cand.path) + 1 > params.depth_budget:
        return False
    if len(cand.heads) > params.k_max:
        return False
    rec = store.get(cand.path)
    return isinstance(rec, R.FileRecord)


def apply_page_split(writer: WikiWriter, cand: SplitCandidate,
                     snap: _Snapshot) -> None:
    """Split the entity page into per-head sub-pages under an entity hub.
    Write order: children first, then the hub directory record replaces the
    file record (parent-after-child at the sub-tree scale)."""
    store = writer.store
    rec = store.get(cand.path)
    assert isinstance(rec, R.FileRecord)
    snap.touch(cand.path)
    paras = [p for p in rec.text.split("\n\n") if p.strip()]
    buckets: dict[str, list[str]] = {h: [] for h in cand.heads}
    from .oracle import content_tokens
    for para in paras:
        ct = content_tokens(para)
        head = ct[0] if ct and ct[0] in buckets else cand.heads[0]
        buckets[head].append(para)
    per_access = rec.meta.access_count // max(len(cand.heads), 1)
    for head in cand.heads:
        sub = P.child(cand.path, head)
        snap.touch(sub)
        writer.put_record(sub, R.FileRecord(
            name=head, text="\n\n".join(buckets[head]),
            meta=replace(rec.meta, version=0, access_count=per_access,
                         confidence=min(1.0, rec.meta.confidence + 0.2))))
    hub = R.DirRecord(
        name=rec.name, files=list(cand.heads),
        summary=rec.text[:200],
        meta=R.DirMeta(updated_at=writer.clock(),
                       entry_count=len(cand.heads),
                       access_count=rec.meta.access_count))
    writer.put_record(cand.path, hub)


# ---------------------------------------------------------------------------
# one greedy evolution pass (Arbiter with exact verification)
# ---------------------------------------------------------------------------
def evolution_pass(writer: WikiWriter, oracle: Oracle, params: SchemaParams,
                   sketch: CoAccessSketch | None = None) -> list[OpResult]:
    store = writer.store
    sketch = sketch if sketch is not None else CoAccessSketch.load(store)
    results: list[OpResult] = []
    committed_supports: set[str] = set()
    before = schema_cost(store, params)
    budget = params.commit_cap

    # ---- merges (highest-MI first) ----
    for d1, d2, mi in merge_candidates(store, sketch, params):
        if budget <= 0:
            break
        if d1 in committed_supports or d2 in committed_supports:
            continue  # node-disjoint commit set (Theorem 1 requirement)
        snap = _Snapshot(writer)
        apply_dimension_merge(writer, d1, d2, snap)
        after = schema_cost(store, params)
        delta = after.total - before.total
        if delta <= 1e-9 and not after.violations:
            results.append(OpResult("merge", f"{d1}+{d2}", -mi, delta, True,
                                    detail=f"MI={mi:.4f}"))
            committed_supports.update({d1, d2})
            before = after
            budget -= 1
        else:
            snap.rollback()
            results.append(OpResult("merge", f"{d1}+{d2}", -mi, delta, False,
                                    detail=f"MI={mi:.4f} rejected"))

    # ---- splits (most-negative Δ̃C first) ----
    total_access = sum(
        (store.get(p).meta.access_count if store.get(p) is not None else 0)
        for p in store.all_paths() if not P.is_reserved(p))
    cands = architect_propose(store, oracle, params)
    for c in cands:
        c.est_delta = critic_score(store, c, params, total_access)
    cands = [c for c in cands
             if c.est_delta < 0 and safety_check(store, c, params)]
    cands.sort(key=lambda c: c.est_delta)
    for c in cands:
        if budget <= 0:
            break
        if any(P.is_prefix(s, c.path) or P.is_prefix(c.path, s)
               for s in committed_supports):
            continue
        snap = _Snapshot(writer)
        apply_page_split(writer, c, snap)
        after = schema_cost(store, params)
        delta = after.total - before.total
        if delta <= 1e-9 and not after.violations:
            results.append(OpResult("split", c.path, c.est_delta, delta, True,
                                    detail=f"heads={c.heads}"))
            committed_supports.add(c.path)
            before = after
            budget -= 1
        else:
            snap.rollback()
            results.append(OpResult("split", c.path, c.est_delta, delta, False))
    return results
