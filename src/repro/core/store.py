"""Storage engines and the path-indexed facade (paper §IV).

Two layers:

* ``KVEngine`` — a minimal Put/Get/Delete/Scan contract (the paper's
  TABLEKV/LevelDB abstraction).  ``MemKV`` is an LSM-ish realization:
  a mutable memtable over immutable sorted runs with size-triggered
  compaction, so point reads and range scans have realistic asymmetric
  costs for the Table II study.

* ``PathStore`` — the WikiKV path-as-key facade.  Logical addresses are
  normalized paths; physical keys are the 8-byte FNV digest H(π)
  (``paths.key_bytes``).  A second column family holds the ordered path
  namespace (path-bytes → empty) to serve Q4 prefix scans natively, the
  way an LSM column family would.

The four query operators (paper §II-B):
  Q1  get(π)        → Record | None             (one point lookup)
  Q2  ls(π)         → (DirRecord, [child paths]) (one point lookup — children
                       are co-located in the directory record)
  Q3  navigate(π)   → [Record]                   (descend root→π, one GET per level)
  Q4  search(p)     → [π]                        (prefix range scan)
"""
from __future__ import annotations

import bisect
import threading
from typing import Iterator, Optional

from . import paths as P
from . import records as R


# One process-wide lock for every engine's op-counter dict: shard
# fan-outs run engine calls on executor worker threads, and the unlocked
# ``d[k] = d.get(k, 0) + 1`` read-modify-write would drop increments
# under contention — the seg_probe/bloom/cache counters must stay exact
# (tests hammer them multi-threaded).  Counter bumps are rare relative
# to reads, so one shared lock beats a per-engine allocation.
_OPS_LOCK = threading.Lock()


class KVEngine:
    """Minimal KV contract: all keys/values are bytes."""

    def put(self, key: bytes, value: bytes) -> None:
        raise NotImplementedError

    def get(self, key: bytes) -> Optional[bytes]:
        raise NotImplementedError

    def delete(self, key: bytes) -> None:
        raise NotImplementedError

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        """Yield (key, value) for keys with byte-prefix ``prefix``, in order."""
        raise NotImplementedError

    def flush(self) -> None:  # pragma: no cover - engines may override
        pass

    # --- stats (fed to evolution operators and benches) ---
    def op_counts(self) -> dict[str, int]:
        with _OPS_LOCK:
            return dict(getattr(self, "_ops", {}))

    def _count(self, op: str) -> None:
        with _OPS_LOCK:
            ops = getattr(self, "_ops", None)
            if ops is None:
                ops = self._ops = {}
            ops[op] = ops.get(op, 0) + 1


_TOMBSTONE = object()


class MemKV(KVEngine):
    """LSM-ish in-process engine.

    Writes land in a dict memtable; when it exceeds ``memtable_limit``
    entries it is frozen into an immutable sorted run (parallel key/value
    lists).  Reads check the memtable, then runs newest-first via binary
    search.  ``compact()`` merges all runs.  Deletes write tombstones.
    This is deliberately the same read/write asymmetry as LevelDB so the
    Table II comparison is honest rather than a dict lookup in disguise.
    """

    def __init__(self, memtable_limit: int = 4096, auto_compact_runs: int = 8):
        self._mem: dict[bytes, object] = {}
        self._runs: list[tuple[list[bytes], list[object]]] = []  # newest last
        self._limit = memtable_limit
        self._auto = auto_compact_runs
        self._lock = threading.Lock()

    def put(self, key: bytes, value: bytes) -> None:
        self._count("put")
        with self._lock:
            self._mem[key] = value
            if len(self._mem) >= self._limit:
                self._freeze()

    def delete(self, key: bytes) -> None:
        self._count("delete")
        with self._lock:
            self._mem[key] = _TOMBSTONE
            if len(self._mem) >= self._limit:
                self._freeze()

    def get(self, key: bytes) -> Optional[bytes]:
        self._count("get")
        # snapshot under the lock: a concurrent put may _freeze() the
        # memtable mid-read (swap self._mem, append to self._runs)
        with self._lock:
            v = self._mem.get(key)
            runs = list(self._runs)
        if v is not None:
            return None if v is _TOMBSTONE else v  # type: ignore[return-value]
        for ks, vs in reversed(runs):
            i = bisect.bisect_left(ks, key)
            if i < len(ks) and ks[i] == key:
                v = vs[i]
                return None if v is _TOMBSTONE else v  # type: ignore[return-value]
        return None

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        self._count("scan")
        # snapshot the memtable + run list under the lock before merging:
        # iterating self._mem.items() unlocked races a put that triggers
        # _freeze() ("dict changed size during iteration")
        with self._lock:
            mem_items = [(k, v) for k, v in self._mem.items()
                         if k.startswith(prefix)]
            runs = list(self._runs)
        # merge runs + memtable snapshot; newest wins
        merged: dict[bytes, object] = {}
        for ks, vs in runs:
            lo = bisect.bisect_left(ks, prefix)
            for i in range(lo, len(ks)):
                if not ks[i].startswith(prefix):
                    break
                merged[ks[i]] = vs[i]
        for k, v in mem_items:
            merged[k] = v
        for k in sorted(merged):
            v = merged[k]
            if v is not _TOMBSTONE:
                yield k, v  # type: ignore[misc]

    def _freeze(self) -> None:
        if not self._mem:
            return
        items = sorted(self._mem.items())
        self._runs.append(([k for k, _ in items], [v for _, v in items]))
        self._mem = {}
        if len(self._runs) >= self._auto:
            self._compact_locked()

    def compact(self) -> None:
        with self._lock:
            self._freeze()
            self._compact_locked()

    def _compact_locked(self) -> None:
        merged: dict[bytes, object] = {}
        for ks, vs in self._runs:
            for k, v in zip(ks, vs):
                merged[k] = v
        items = sorted((k, v) for k, v in merged.items() if v is not _TOMBSTONE)
        self._runs = [([k for k, _ in items], [v for _, v in items])] if items else []

    def flush(self) -> None:
        with self._lock:
            self._freeze()


class DictKV(KVEngine):
    """Plain-dict engine (no LSM costs) — used where engine cost must not
    pollute a measurement (e.g. protocol property tests)."""

    def __init__(self):
        self._d: dict[bytes, bytes] = {}

    def put(self, key: bytes, value: bytes) -> None:
        self._count("put")
        self._d[key] = value

    def get(self, key: bytes) -> Optional[bytes]:
        self._count("get")
        return self._d.get(key)

    def delete(self, key: bytes) -> None:
        self._count("delete")
        self._d.pop(key, None)

    def scan(self, prefix: bytes) -> Iterator[tuple[bytes, bytes]]:
        self._count("scan")
        for k in sorted(self._d):
            if k.startswith(prefix):
                yield k, self._d[k]


# namespace column-family prefixes inside one engine keyspace
_CF_DATA = b"d:"   # d:<8-byte digest>           -> record bytes
_CF_PATH = b"p:"   # p:<utf-8 normalized path>   -> 8-byte digest (ordered namespace)
_CF_TOKEN = b"t:"  # t:<token>:<path>            -> b"" (segment-token inverted index)


def _segment_tokens(path: str) -> set[str]:
    toks: set[str] = set()
    for seg in P.segments(path):
        low = seg.lower()
        toks.add(low)
        toks.update(t for t in low.replace("-", "_").split("_") if t)
    return toks


class PathStore:
    """WikiKV path-as-key store over any KVEngine (paper §IV-A/§IV-B)."""

    def __init__(self, engine: KVEngine | None = None,
                 depth_budget: int | None = P.DEFAULT_DEPTH_BUDGET):
        self.engine = engine if engine is not None else MemKV()
        self.depth_budget = depth_budget

    # -- physical key derivation ------------------------------------------
    @staticmethod
    def data_key(path: str) -> bytes:
        return _CF_DATA + P.key_bytes(path)

    @staticmethod
    def path_key(path: str) -> bytes:
        return _CF_PATH + path.encode("utf-8")

    # -- raw record plumbing (used by the consistency writer) --------------
    def put_record(self, path: str, rec: R.Record) -> None:
        path = P.normalize(path, depth_budget=self.depth_budget)
        self.engine.put(self.data_key(path), R.encode(rec))
        self.engine.put(self.path_key(path), P.key_bytes(path))
        # segment-token inverted index: keyword routing (NAV Phase 1)
        # stays O(hits) as the namespace grows (sub-linear scaling, §VI-F)
        pb = path.encode("utf-8")
        for tok in _segment_tokens(path):
            self.engine.put(_CF_TOKEN + tok.encode("utf-8") + b":" + pb, b"1")

    def delete_record(self, path: str) -> None:
        path = P.normalize(path, depth_budget=self.depth_budget)
        self.engine.delete(self.data_key(path))
        self.engine.delete(self.path_key(path))
        pb = path.encode("utf-8")
        for tok in _segment_tokens(path):
            self.engine.delete(_CF_TOKEN + tok.encode("utf-8") + b":" + pb)

    # -- Q1: path lookup ----------------------------------------------------
    def get(self, path: str) -> Optional[R.Record]:
        path = P.normalize(path, depth_budget=self.depth_budget)
        raw = self.engine.get(self.data_key(path))
        return R.decode(raw) if raw is not None else None

    # -- Q2: directory list (≡ one point lookup; children co-located) -------
    def ls(self, path: str) -> Optional[tuple[R.DirRecord, list[str]]]:
        path = P.normalize(path, depth_budget=self.depth_budget)
        rec = self.get(path)
        if rec is None or not isinstance(rec, R.DirRecord):
            return None
        return rec, [P.child(path, s) for s in rec.children()]

    # -- Q3: navigation along a known path (one GET per level) --------------
    def navigate(self, path: str) -> list[R.Record]:
        path = P.normalize(path, depth_budget=self.depth_budget)
        out: list[R.Record] = []
        for anc in list(P.ancestors(path)) + [path]:
            rec = self.get(anc)
            if rec is None:
                break
            out.append(rec)
        return out

    # -- Q4: prefix search over the ordered path namespace ------------------
    def search(self, prefix: str, limit: int | None = None) -> list[str]:
        prefix = prefix if prefix.startswith(P.SEP) else P.SEP + prefix
        out: list[str] = []
        for k, _ in self.engine.scan(self.path_key(prefix)):
            p = k[len(_CF_PATH):].decode("utf-8")
            # segment-aware: "/a" must not match "/ab"
            if not P.is_prefix(prefix.rstrip(P.SEP) or P.ROOT, p) and p != prefix:
                continue
            out.append(p)
            if limit is not None and len(out) >= limit:
                break
        return out

    def search_contains(self, token: str, limit: int | None = None) -> list[str]:
        """Keyword routing over the path namespace (NAV's EXTRACT→SEARCH).

        Served from the segment-token inverted index: one prefix scan over
        ``t:<token>:`` — O(hits), independent of namespace size.  Exact
        segment-token semantics: segments are indexed whole AND split on
        underscores, so "zhou" finds "/rel/zhou_zuoren"; a miss means no
        path carries the token (no O(N) fallback — that is what keeps
        routing sub-linear, §VI-F)."""
        token_l = token.lower()
        out = []
        for k, _ in self.engine.scan(_CF_TOKEN + token_l.encode("utf-8") + b":"):
            p = k.split(b":", 2)[2].decode("utf-8")
            out.append(p)
            if limit is not None and len(out) >= limit:
                break
        return out

    # -- namespace enumeration (offline pipeline / evolution operators) -----
    def all_paths(self) -> list[str]:
        return [k[len(_CF_PATH):].decode("utf-8")
                for k, _ in self.engine.scan(_CF_PATH)]

    def count(self) -> int:
        """Number of live paths (one ordered-namespace scan)."""
        return sum(1 for _ in self.engine.scan(_CF_PATH))

    def op_counts(self) -> dict[str, int]:
        """Engine-level op counters (put/get/scan plus, on a durable
        engine, ``bloom_neg``/``cache_hit``/``cache_miss``) — the same
        shape ``ShardedPathStore.op_counts`` aggregates per shard."""
        return self.engine.op_counts()

    # -- engine maintenance / durable-tier passthroughs ---------------------
    # Duck-typed delegation so the facade works unchanged over MemKV,
    # DictKV, or storage.DurableKV; callers probe the same names on
    # ShardedPathStore, which fans them out per shard.
    @property
    def durable(self) -> bool:
        return hasattr(self.engine, "journal_invalidation")

    def flush(self) -> None:
        self.engine.flush()

    def compact(self) -> None:
        if hasattr(self.engine, "compact"):
            self.engine.compact()

    def close(self) -> None:
        if hasattr(self.engine, "close"):
            self.engine.close()

    def commit_epoch(self, epoch: int) -> None:
        """Group-commit the engine's buffered wave at ``epoch`` (WAL
        COMMIT marker on a durable engine; no-op on volatile ones)."""
        if hasattr(self.engine, "commit_epoch"):
            self.engine.commit_epoch(epoch)

    def seal_commit(self, epoch: int):
        """Synchronous half of a pipelined group commit: seal the
        engine's buffered wave under its lock and return the deferred
        durability closure (WAL write + fsync + spill) for the commit
        sequencer to run off-thread.  None when there is nothing to make
        durable — or when the engine is volatile / pre-pipeline, in
        which case this degrades to a plain synchronous commit."""
        fn = getattr(self.engine, "seal_commit", None)
        if fn is None:
            self.commit_epoch(epoch)
            return None
        return fn(epoch)

    def durable_epoch(self) -> int:
        """Newest epoch advertised as durable.  A synchronous commit
        path never advertises ahead of the WAL, so this is simply the
        last committed epoch; ``ShardedPathStore`` overrides it with the
        commit sequencer's landed-fsync watermark when pipelining."""
        return self.last_epoch()

    def compact_debt(self) -> int | None:
        """Outstanding merge bytes owed by a durable engine (the
        compaction backpressure gauge); None on volatile engines."""
        fn = getattr(self.engine, "compact_debt", None)
        return None if fn is None else fn()

    def last_epoch(self) -> int:
        if hasattr(self.engine, "last_epoch"):
            return self.engine.last_epoch()
        return 0

    def journal_invalidation(self, path: str) -> None:
        if hasattr(self.engine, "journal_invalidation"):
            self.engine.journal_invalidation(path)

    def mark_device_epoch(self, epoch: int) -> None:
        if hasattr(self.engine, "mark_device_epoch"):
            self.engine.mark_device_epoch(epoch)

    def pending_invalidations(self) -> list[str]:
        if hasattr(self.engine, "pending_invalidations"):
            return self.engine.pending_invalidations()
        return []
