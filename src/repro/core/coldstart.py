"""Cold-start: Intent-Anchored Schema Induction (paper §III-C).

IASI runs once at deployment time, off the critical path:

  1. **Ingestion filter Φ** removes seven categories of low-information
     documents *before* sampling, so the positioning descriptor 𝒫 is not
     miscalibrated at the source.
  2. A fixed-size sample 𝒮 ⊂ 𝒟 (independent of |𝒟|) feeds the oracle.
  3. The oracle emits the corpus positioning descriptor
     𝒫 = ⟨focus, audience, ingestion-bias⟩.
  4. The oracle emits the directory scaffold T fixing V_I, V_D, V_E and the
     parent-child structure at those levels, with the §III-B structural
     constraints enforced *by construction* (no generate-then-validate loop).

𝒫 is a first-class schema object: it is materialized to durable storage at
the reserved (unadvertised) path ``/_meta/positioning`` and read directly by
the evolution operators.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass

from . import paths as P
from . import records as R
from .consistency import WikiWriter
from .oracle import Oracle, ScaffoldSpec
from .schema import SchemaParams

POSITIONING_PATH = "/_meta/positioning"

# ---------------------------------------------------------------------------
# Ingestion filter Φ — seven low-information categories (paper §III-C).
# ---------------------------------------------------------------------------
_GREETING_RE = re.compile(
    r"\b(happy\s+(new\s+year|holidays|spring\s+festival)|season'?s\s+greetings|"
    r"merry\s+christmas|best\s+wishes\s+for)\b", re.I)
_ANNOUNCE_RE = re.compile(
    r"\b(announcing|announcement|save\s+the\s+date|event\s+notice|"
    r"will\s+be\s+held|registration\s+opens)\b", re.I)
_AD_RE = re.compile(
    r"\b(limited\s+time\s+offer|discount|coupon|buy\s+now|sponsored)\b", re.I)
_LINKFARM_RE = re.compile(r"(https?://\S+\s*){3,}")

FILTER_CATEGORIES = (
    "seasonal_greeting",      # boilerplate seasonal greetings
    "republication",          # verbatim re-publication of upstream content
    "event_announcement",     # event announcements
    "advertisement",          # promotional content
    "link_farm",              # documents that are mostly links
    "too_short",              # trivially short content
    "template_boilerplate",   # repeated template text across docs
)


@dataclass
class FilterReport:
    kept: list[dict]
    dropped: dict[str, list[str]]  # category -> doc ids

    @property
    def drop_count(self) -> int:
        return sum(len(v) for v in self.dropped.values())


def ingestion_filter(docs: list[dict], min_chars: int = 80) -> FilterReport:
    """Φ: drop the seven low-information categories before sampling."""
    kept: list[dict] = []
    dropped: dict[str, list[str]] = {c: [] for c in FILTER_CATEGORIES}
    seen_hashes: dict[str, str] = {}
    body_counts: dict[str, int] = {}
    for d in docs:
        body_counts[_template_key(d["text"])] = \
            body_counts.get(_template_key(d["text"]), 0) + 1
    for d in docs:
        text, did = d["text"], d.get("id", d.get("title", "?"))
        h = hashlib.sha1(text.strip().encode()).hexdigest()
        cat = None
        if h in seen_hashes:
            cat = "republication"
        elif len(text.strip()) < min_chars:
            cat = "too_short"
        elif _GREETING_RE.search(text):
            cat = "seasonal_greeting"
        elif _ANNOUNCE_RE.search(text):
            cat = "event_announcement"
        elif _AD_RE.search(text):
            cat = "advertisement"
        elif _LINKFARM_RE.search(text):
            cat = "link_farm"
        elif body_counts[_template_key(text)] >= 4:
            cat = "template_boilerplate"
        if cat is None:
            seen_hashes[h] = did
            kept.append(d)
        else:
            dropped[cat].append(did)
    return FilterReport(kept=kept, dropped=dropped)


def _template_key(text: str) -> str:
    """First 60 chars with digits masked — detects repeated templates."""
    return re.sub(r"\d+", "#", text.strip()[:60])


def sample_corpus(docs: list[dict], sample_size: int, seed: int = 0) -> list[dict]:
    """Deterministic fixed-size sample, independent of |𝒟| (paper §III-C).
    Uses a content-hash order so the sample is stable under corpus append."""
    ranked = sorted(
        docs,
        key=lambda d: hashlib.sha1(
            (str(seed) + d.get("id", d.get("title", ""))).encode()).hexdigest())
    return ranked[:sample_size]


@dataclass
class ColdStartResult:
    scaffold: ScaffoldSpec
    positioning: dict[str, str]
    filter_report: FilterReport
    n_dimensions: int
    n_entities: int


def cold_start(writer: WikiWriter, corpus: list[dict], oracle: Oracle,
               params: SchemaParams, sample_size: int = 24,
               seed: int = 0) -> ColdStartResult:
    """Run IASI and materialize S₀ into the store."""
    report = ingestion_filter(corpus)
    sample = sample_corpus(report.kept, sample_size, seed=seed)
    pos = oracle.positioning(sample)
    scaffold = oracle.induce_scaffold(
        sample, pos, k_max=params.k_max, depth_budget=params.depth_budget)

    # materialize: root, dimensions, entity pages (empty leaves at cold start)
    writer.ensure_root(summary=f"Knowledge base — focus: {pos.get('focus','')}")
    n_ent = 0
    for dim, ents in scaffold.dimensions.items():
        dpath = P.child(P.ROOT, dim)
        writer.admit(dpath, R.DirRecord(
            name=dim, summary=f"Dimension: {dim}",
            meta=R.DirMeta(updated_at=writer.clock())))
        for ent in ents[: params.k_max]:
            epath = P.child(dpath, ent)
            writer.admit(epath, R.FileRecord(
                name=ent, text="",
                meta=R.FileMeta(version=0, confidence=0.5,
                                last_verified=writer.clock())))
            n_ent += 1

    # 𝒫 is a durable first-class object, deliberately *unadvertised*
    # (not linked into any directory listing) so it never appears in NAV
    # results but is directly addressable by the evolution operators.
    writer.store.put_record(POSITIONING_PATH, R.FileRecord(
        name="positioning", text=json.dumps(pos, sort_keys=True),
        meta=R.FileMeta(version=0, confidence=1.0)))
    return ColdStartResult(
        scaffold=scaffold, positioning=pos, filter_report=report,
        n_dimensions=len(scaffold.dimensions), n_entities=n_ent)


def load_positioning(store) -> dict[str, str] | None:
    rec = store.get(POSITIONING_PATH)
    if rec is None or not isinstance(rec, R.FileRecord):
        return None
    return json.loads(rec.text)
