"""Path-as-key encoding (paper §IV-A).

A node's path ``π(v)`` is its logical address.  The *physical* KV key is the
64-bit hash digest ``H(π(v))`` so that keys are fixed-width and
separator/charset agnostic (the paper calls out non-ASCII segments).

Normalization rules (paper §IV-A):
  * no trailing slash (except the root ``"/"`` itself),
  * case-sensitive segment matching (we do NOT casefold),
  * the reserved separator ``/`` may not appear inside a segment,
  * depth bounded by the schema constant ``D``.

The same normalization runs on the host (python strings) and — packed into
uint8 token matrices — on device (``core.tensorstore`` / ``kernels.prefix_search``),
so a path is simultaneously a tree address and, via ``H(π)``, a storage key,
with no translation table.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Iterable

SEP = "/"
ROOT = "/"
#: default depth budget D (five node types: Index, Dimension, Entity, Digest, Document)
DEFAULT_DEPTH_BUDGET = 5
#: reserved subtree that hoists shared sources (paper §IV-A)
SOURCES_PREFIX = "/sources"
#: reserved, unadvertised metadata namespace (positioning 𝒫, error book, …)
META_PREFIX = "/_meta"
DIGESTS_PREFIX = "/sources/digests"
ARTICLES_PREFIX = "/sources/articles"

_SEGMENT_BAD = re.compile(r"[\x00/]")


class PathError(ValueError):
    """Raised on malformed or constraint-violating paths."""


def normalize(path: str, *, depth_budget: int | None = DEFAULT_DEPTH_BUDGET) -> str:
    """Normalize a raw path string to canonical form.

    Collapses duplicate separators, strips a trailing slash, validates
    segments and the depth budget.  Idempotent: ``normalize(normalize(p)) ==
    normalize(p)``.
    """
    if not isinstance(path, str) or not path:
        raise PathError(f"empty or non-string path: {path!r}")
    if not path.startswith(SEP):
        raise PathError(f"path must be absolute (start with '/'): {path!r}")
    segs = [s for s in path.split(SEP) if s != ""]
    for s in segs:
        if _SEGMENT_BAD.search(s):
            raise PathError(f"reserved character in segment {s!r} of {path!r}")
        if s in (".", ".."):
            raise PathError(f"relative segment {s!r} not allowed in {path!r}")
    if depth_budget is not None and len(segs) > depth_budget:
        raise PathError(
            f"path depth {len(segs)} exceeds budget {depth_budget}: {path!r}")
    if not segs:
        return ROOT
    return SEP + SEP.join(segs)


def is_normalized(path: str) -> bool:
    try:
        return normalize(path, depth_budget=None) == path
    except PathError:
        return False


def segments(path: str) -> list[str]:
    """Split a normalized path into its segment list; root → []."""
    if path == ROOT:
        return []
    return path.lstrip(SEP).split(SEP)


def depth(path: str) -> int:
    return len(segments(path))


def parent(path: str) -> str:
    """Parent path; the root is its own parent sentinel ``None`` is avoided —
    calling parent('/') is an error (the root has no parent)."""
    segs = segments(path)
    if not segs:
        raise PathError("root path has no parent")
    if len(segs) == 1:
        return ROOT
    return SEP + SEP.join(segs[:-1])


def child(path: str, segment: str) -> str:
    """Join one segment under ``path`` (both sides validated)."""
    if _SEGMENT_BAD.search(segment) or not segment:
        raise PathError(f"bad child segment {segment!r}")
    if path == ROOT:
        return SEP + segment
    return path + SEP + segment


def basename(path: str) -> str:
    segs = segments(path)
    return segs[-1] if segs else ""


def is_prefix(prefix: str, path: str) -> bool:
    """Segment-aware prefix test: ``/a`` is a prefix of ``/a/b`` but not of
    ``/ab``.  The root is a prefix of every path."""
    if prefix == ROOT:
        return True
    return path == prefix or path.startswith(prefix + SEP)


def ancestors(path: str) -> Iterable[str]:
    """Yield every proper ancestor from the root down (root first)."""
    segs = segments(path)
    yield ROOT
    for i in range(1, len(segs)):
        yield SEP + SEP.join(segs[:i])


# ---------------------------------------------------------------------------
# 64-bit FNV-1a hash — the physical key H(π).  Chosen because it is trivially
# expressible both in python (host ingest path) and as a vectorizable integer
# recurrence on device (uint32 pairs; see core/tensorstore.py), with no
# dependency on hashlib state.
# ---------------------------------------------------------------------------
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def path_hash(path: str) -> int:
    """64-bit FNV-1a of the UTF-8 bytes of the *normalized* path."""
    h = FNV_OFFSET
    for b in path.encode("utf-8"):
        h ^= b
        h = (h * FNV_PRIME) & _MASK64
    return h


def key_bytes(path: str) -> bytes:
    """Physical key: 8-byte big-endian digest (sorts like the integer)."""
    return path_hash(path).to_bytes(8, "big")


@dataclass(frozen=True)
class PathKey:
    """A normalized logical path together with its physical digest."""

    path: str
    digest: int

    @classmethod
    def of(cls, raw: str, *, depth_budget: int | None = DEFAULT_DEPTH_BUDGET) -> "PathKey":
        p = normalize(raw, depth_budget=depth_budget)
        return cls(path=p, digest=path_hash(p))


# -- node-type binding (paper Table I) --------------------------------------
NODE_INDEX = "index"
NODE_DIMENSION = "dimension"
NODE_ENTITY = "entity"
NODE_DIGEST = "digest"
NODE_DOCUMENT = "document"


def is_reserved(path: str) -> bool:
    """True for the unadvertised metadata namespace and the hoisted sources
    subtree — excluded from schema shape (Eq. 1) and NAV results."""
    return is_prefix(META_PREFIX, path) or is_prefix(SOURCES_PREFIX, path)


def node_type(path: str) -> str:
    """Infer the schema node type from a normalized path (paper Table I)."""
    segs = segments(path)
    if not segs:
        return NODE_INDEX
    if is_prefix(DIGESTS_PREFIX, path) and depth(path) == 3:
        return NODE_DIGEST
    if is_prefix(ARTICLES_PREFIX, path) and depth(path) == 3:
        return NODE_DOCUMENT
    if len(segs) == 1:
        return NODE_DIMENSION
    if len(segs) == 2:
        return NODE_ENTITY
    # deeper entity subtrees produced by PageSplit stay entities
    return NODE_ENTITY


def digest_path(title: str) -> str:
    return child(DIGESTS_PREFIX, _safe_segment(title))


def article_path(title: str) -> str:
    return child(ARTICLES_PREFIX, _safe_segment(title))


def _safe_segment(title: str) -> str:
    """Make an arbitrary title usable as one path segment."""
    s = title.strip().replace(SEP, "_").replace("\x00", "")
    return s or "untitled"
