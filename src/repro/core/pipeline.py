"""Offline construction-and-evolution pipeline (paper §III-E).

Cadences: cold-start is one-shot; DIMENSIONMERGE + PAGESPLIT run every N
ingested articles (N=30 in the deployment); the Error Book's deterministic
pass runs after every batch, its oracle pass periodically.  Multi-process
parallel construction partitions by author subtree (§IV-C): each author's
corpus compiles into its own store/writer — per-author-parallel,
intra-author-serial — so Theorem 2 holds per subtree with no cross-author
coordination.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from . import paths as P
from . import records as R
from .coldstart import ColdStartResult, cold_start
from .consistency import InvalidationBus, WikiWriter
from .errorbook import ErrorBook, run_errorbook
from .evolution import (AccessLog, CoAccessSketch, apply_access_log,
                        evolution_pass)
from .oracle import Oracle, ScaffoldSpec
from .schema import SchemaParams
from .store import MemKV, PathStore


@dataclass
class PipelineConfig:
    params: SchemaParams = field(default_factory=SchemaParams)
    evolution_every_n: int = 30   # N articles between evolution passes
    llm_errorbook_every: int = 4  # batches between oracle-level EB passes
    sample_size: int = 24
    seed: int = 0
    enable_coldstart: bool = True
    enable_evolution: bool = True
    fixed_dimensions: list[str] | None = None  # Table III "FIXED" variant


@dataclass
class IngestStats:
    ingested: int = 0
    digests: int = 0
    entity_updates: int = 0
    skipped: int = 0
    evolution_ops: int = 0
    errorbook_errors: int = 0


class ConstructionPipeline:
    """One author's construction-and-evolution pipeline over one subtree."""

    def __init__(self, cfg: PipelineConfig, oracle: Oracle,
                 store: PathStore | None = None,
                 bus: InvalidationBus | None = None):
        self.cfg = cfg
        self.oracle = oracle
        self.store = store if store is not None else PathStore(MemKV())
        self.bus = bus if bus is not None else InvalidationBus()
        self.writer = WikiWriter(self.store, bus=self.bus)
        self.scaffold: ScaffoldSpec | None = None
        self.stats = IngestStats()
        self._since_evolution = 0
        self._batch_no = 0

    # ------------------------------------------------------------------
    def bootstrap(self, corpus: list[dict]) -> ColdStartResult | None:
        """Cold-start (IASI) or the FIXED-schema baseline of Table III."""
        if self.cfg.fixed_dimensions is not None:
            self.writer.ensure_root(summary="fixed-schema wiki")
            dims = {}
            for dim in self.cfg.fixed_dimensions:
                self.writer.admit(P.child(P.ROOT, dim), R.DirRecord(
                    name=dim, summary=f"Dimension: {dim}"))
                dims[dim] = []
            self.scaffold = ScaffoldSpec(dimensions=dims, positioning={})
            return None
        if not self.cfg.enable_coldstart:
            # w/o Cold-Start ablation (Table VI): full-document injection
            result = cold_start(self.writer, corpus, self.oracle,
                                self.cfg.params,
                                sample_size=len(corpus), seed=self.cfg.seed)
        else:
            result = cold_start(self.writer, corpus, self.oracle,
                                self.cfg.params,
                                sample_size=self.cfg.sample_size,
                                seed=self.cfg.seed)
        self.scaffold = result.scaffold
        return result

    # ------------------------------------------------------------------
    def ingest(self, docs: list[dict]) -> IngestStats:
        """One ingestion batch: digest + article records into the hoisted
        /sources subtree, entity pages updated with links — all via the
        parent-after-child writer.  The ingestion filter Φ runs here too
        (low-information docs never enter the wiki, matching the
        ingestion-bias recorded in 𝒫)."""
        assert self.scaffold is not None, "bootstrap() first"
        from .coldstart import ingestion_filter
        report = ingestion_filter(docs)
        self.stats.skipped += report.drop_count
        docs = report.kept
        book = ErrorBook.load(self.store)
        banned_links = set(book.bad_link_targets)
        for doc in docs:
            title = doc.get("title") or doc.get("id") or "untitled"
            art_path = P.article_path(title)
            dig_path = P.digest_path(title)
            if self.store.get(art_path) is not None:
                self.stats.skipped += 1
                continue
            # sources first (they are link targets)
            self.writer.admit(art_path, R.FileRecord(
                name=P.basename(art_path), text=doc["text"],
                meta=R.FileMeta(version=0, confidence=1.0,
                                last_verified=self.writer.clock())))
            digest = self.oracle.summarize([doc["text"]], limit=300)
            self.writer.admit(dig_path, R.FileRecord(
                name=P.basename(dig_path), text=digest,
                meta=R.FileMeta(version=0, confidence=0.9,
                                sources=[art_path],
                                last_verified=self.writer.clock())))
            self.stats.digests += 1
            # entity assignment + page update (links, not copies — §IV-A)
            for dim, ent in self.oracle.assign_entities(doc, self.scaffold):
                dpath = P.child(P.ROOT, dim)
                if self.store.get(dpath) is None:
                    if self.cfg.fixed_dimensions is not None:
                        dim = self.cfg.fixed_dimensions[0]
                        dpath = P.child(P.ROOT, dim)
                    else:
                        self.writer.admit(dpath, R.DirRecord(
                            name=dim, summary=f"Dimension: {dim}"))
                epath = P.child(dpath, ent)
                if dig_path in banned_links:
                    continue  # Error Book constraint: known-bad target
                self._update_entity(epath, ent, doc, dig_path, art_path)
                self.stats.entity_updates += 1
            self.stats.ingested += 1
            self._since_evolution += 1
        # Error Book deterministic pass after every batch
        self._batch_no += 1
        with_llm = (self._batch_no % self.cfg.llm_errorbook_every == 0)
        book, report = run_errorbook(self.writer, self.oracle,
                                     with_llm_pass=with_llm)
        self.stats.errorbook_errors += report.total
        # evolution every N articles
        if (self.cfg.enable_evolution
                and self._since_evolution >= self.cfg.evolution_every_n):
            ops = evolution_pass(self.writer, self.oracle, self.cfg.params)
            self.stats.evolution_ops += sum(1 for o in ops if o.committed)
            self._since_evolution = 0
        # LSM hygiene between offline batches: flush + compact so the
        # online read path sees one sorted run (store-level so the durable
        # and sharded facades fan out per engine/shard)
        self.store.flush()
        self.store.compact()
        return self.stats

    def _update_entity(self, epath: str, ent: str, doc: dict,
                       dig_path: str, art_path: str) -> None:
        rec = self.store.get(epath)
        # entity-relevant digest: the sentences of the document that
        # mention this entity (that is what an entity page *is*), plus
        # the structured fact lines — then the wikilink to the source
        ent_words = set(ent.lower().split("_"))
        relevant = [s for s in doc["text"].split(". ")
                    if ent_words & set(s.lower().replace(":", " ").split())]
        summary_line = self.oracle.summarize(
            relevant or [doc["text"]], limit=600)
        fact_lines = "\n".join(doc.get("facts", []))
        addition = (f"{summary_line}\n{fact_lines}\n"
                    f"[[{dig_path}]]").strip()
        if rec is None:
            self.writer.admit(epath, R.FileRecord(
                name=ent, text=addition,
                meta=R.FileMeta(version=0, confidence=0.8,
                                sources=[dig_path, art_path],
                                last_verified=self.writer.clock())))
        elif isinstance(rec, R.FileRecord):
            def _mut(r: R.FileRecord) -> R.FileRecord:
                text = (r.text + "\n\n" + addition).strip()
                srcs = sorted(set(r.meta.sources) | {dig_path, art_path})
                return replace(r, text=text, meta=replace(
                    r.meta, sources=srcs, confidence=min(1.0, r.meta.confidence + 0.05),
                    last_verified=self.writer.clock()))
            self.writer.update_file(epath, _mut)
        else:
            # entity was split into a hub — descend to the matching sub-page
            sub = P.child(epath, ent)
            if self.store.get(sub) is None and P.depth(sub) <= self.cfg.params.depth_budget:
                self.writer.admit(sub, R.FileRecord(
                    name=ent, text=addition,
                    meta=R.FileMeta(version=0, confidence=0.8,
                                    sources=[dig_path, art_path])))

    # ------------------------------------------------------------------
    def absorb_access_log(self, log: AccessLog) -> CoAccessSketch:
        return apply_access_log(self.writer, log)

    def run_evolution(self) -> list:
        return evolution_pass(self.writer, self.oracle, self.cfg.params)


def build_author_wikis(corpora: dict[str, list[dict]], oracle_factory,
                       cfg: PipelineConfig,
                       batch_size: int = 16) -> dict[str, ConstructionPipeline]:
    """Per-author-parallel construction (paper §IV-C): author subtrees are
    disjoint by construction, so building them in any order — or on a pool
    of workers — introduces no write-write conflicts.  Serial here; the
    distributed launcher shards authors over the data axis."""
    out: dict[str, ConstructionPipeline] = {}
    for author, corpus in sorted(corpora.items()):
        pipe = ConstructionPipeline(cfg, oracle_factory())
        pipe.bootstrap(corpus)
        for i in range(0, len(corpus), batch_size):
            pipe.ingest(corpus[i:i + batch_size])
        out[author] = pipe
    return out
