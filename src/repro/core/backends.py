"""Comparison storage backends for the Table II study (paper §VI-B).

Four backends behind one ``Backend`` protocol, each the idiomatic
realization of the wiki on that storage model:

* ``WikiKVBackend``   — the paper's path-as-key layout over the MemKV LSM
                        engine (our method).
* ``FSBackend``       — hierarchical file system: directories are directories,
                        records are files; Q2 enumerates via readdir; Q4 walks.
* ``SQLBackend``      — relational (sqlite ≈ PostgreSQL+ltree): a normalized
                        nodes(path, parent, data) table with indexes; Q2 is a
                        parent-equality SELECT, Q3 indexed equality per level,
                        Q4 a range predicate on the path index.
* ``GraphBackend``    — property-graph (≈ Neo4j): node store + typed adjacency;
                        Q1 resolves by *traversing edges from the root* (the
                        Cypher path-match contract — no direct path index),
                        Q2 expands outgoing edges, Q4 pattern-matches on a
                        node-name scan.

Every backend is loaded from the same list of (path, record) pairs so the
latency comparison isolates the storage model, as in the paper's controlled
in-process setup.
"""
from __future__ import annotations

import os
import shutil
import sqlite3
import tempfile
from typing import Optional, Sequence

from . import paths as P
from . import records as R
from .engine import DeviceEngine, HostEngine, ShardedPathStore
from .store import MemKV, PathStore


class Backend:
    name = "abstract"

    def load(self, items: Sequence[tuple[str, R.Record]]) -> None:
        raise NotImplementedError

    def q1_get(self, path: str) -> Optional[R.Record]:
        raise NotImplementedError

    def q2_ls(self, path: str) -> Optional[list[str]]:
        raise NotImplementedError

    def q3_navigate(self, path: str) -> list[R.Record]:
        raise NotImplementedError

    def q4_search(self, prefix: str) -> list[str]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class WikiKVBackend(Backend):
    """Path-as-key layout, served through the unified ``QueryEngine``
    (core/engine.py).  Variants differ only in the engine behind the same
    Q1–Q4 contract:

    * ``wikikv``         — HostEngine over one MemKV LSM (the paper's layout)
    * ``wikikv_sharded`` — HostEngine over the digest-range ShardedPathStore
    * ``wikikv_device``  — DeviceEngine over the frozen tensor index
                           (Pallas Q1/Q4 on TPU, jnp reference elsewhere)
    * ``wikikv_durable`` — HostEngine over the on-disk WAL + SSTable tier
                           (storage.DurableKV; reads hit real segment files)
    """

    name = "wikikv"
    engine_kind = "host"
    n_shards = 1

    def __init__(self):
        if self.n_shards > 1:
            self.store = ShardedPathStore(n_shards=self.n_shards)
        else:
            self.store = PathStore(MemKV())
        self.engine = None

    def load(self, items):
        for path, rec in items:
            self.store.put_record(path, rec)
        self.store.flush()
        if self.engine_kind == "device":
            self.engine = DeviceEngine.from_store(self.store)
        else:
            self.engine = HostEngine(self.store)

    def q1_get(self, path):
        return self.engine.q1_get([path])[0]

    def q2_ls(self, path):
        out = self.engine.q2_ls([path])[0]
        return None if out is None else out[1]

    def q3_navigate(self, path):
        return self.engine.q3_navigate([path])[0]

    def q4_search(self, prefix):
        return self.engine.q4_search([prefix])[0]

    # batched entry points (the Table II amortization rows)
    def q1_get_batch(self, paths):
        return self.engine.q1_get(paths)

    def q4_search_batch(self, prefixes):
        return self.engine.q4_search(prefixes)


class WikiKVShardedBackend(WikiKVBackend):
    name = "wikikv_sharded"
    n_shards = 4


class WikiKVDeviceBackend(WikiKVBackend):
    name = "wikikv_device"
    engine_kind = "device"


class WikiKVDurableBackend(WikiKVBackend):
    """Path-as-key layout over the durable LSM tier: every record lives
    in WAL + on-disk SSTable segments, and the load ends with a spill +
    full compaction so the measured read path is one real segment file
    (mmap'd sparse-index lookups), not a warm memtable in disguise.
    Runs the serving configuration ``open_durable_store`` wires up —
    default bloom bits and the shared block cache (the cold, cache-less
    read path is measured separately by ``wikikv_durable_cold``).
    Honors ``REPRO_WAL_SYNC`` (CI sets ``none`` for stable timings)."""

    name = "wikikv_durable"

    def __init__(self):
        from ..storage import DurableKV, default_block_cache
        self._dir = tempfile.mkdtemp(prefix="wikikv_durable_")
        self.store = PathStore(DurableKV(self._dir,
                                         block_cache=default_block_cache()))
        self.engine = None

    def load(self, items):
        for path, rec in items:
            self.store.put_record(path, rec)
        self.store.flush()
        self.store.compact()
        self.engine = HostEngine(self.store)

    def close(self):
        self.store.close()
        shutil.rmtree(self._dir, ignore_errors=True)


class FSBackend(Backend):
    """Directories/files on the real filesystem.

    A node at path π is stored as ``<root>/π/.node`` if it is a directory
    record (so it can have children), or ``<root>/π`` as a plain file.
    """

    name = "fs"

    def __init__(self, root: str | None = None):
        self._own = root is None
        self.root = root or tempfile.mkdtemp(prefix="wikikv_fs_")

    def _fs(self, path: str) -> str:
        return os.path.join(self.root, *P.segments(path))

    def load(self, items):
        for path, rec in items:
            fp = self._fs(path)
            if isinstance(rec, R.DirRecord):
                os.makedirs(fp, exist_ok=True)
                with open(os.path.join(fp, ".node"), "wb") as f:
                    f.write(R.encode(rec))
            else:
                os.makedirs(os.path.dirname(fp), exist_ok=True)
                with open(fp, "wb") as f:
                    f.write(R.encode(rec))

    def q1_get(self, path):
        fp = self._fs(path)
        try:
            if os.path.isdir(fp):
                with open(os.path.join(fp, ".node"), "rb") as f:
                    return R.decode(f.read())
            with open(fp, "rb") as f:
                return R.decode(f.read())
        except (FileNotFoundError, NotADirectoryError):
            return None

    def q2_ls(self, path):
        fp = self._fs(path)
        if not os.path.isdir(fp):
            return None
        out = []
        for name in sorted(os.listdir(fp)):
            if name == ".node":
                continue
            out.append(P.child(path, name))
        return out

    def q3_navigate(self, path):
        out = []
        for anc in list(P.ancestors(path)) + [path]:
            rec = self.q1_get(anc)
            if rec is None:
                break
            out.append(rec)
        return out

    def q4_search(self, prefix):
        base = self._fs(prefix)
        hits: list[str] = []
        if os.path.isdir(base):
            for dirpath, dirnames, filenames in os.walk(base):
                rel = os.path.relpath(dirpath, self.root)
                lp = P.ROOT if rel == "." else P.SEP + rel.replace(os.sep, P.SEP)
                hits.append(lp)
                for fn in filenames:
                    if fn != ".node":
                        hits.append(P.child(lp, fn))
        elif os.path.exists(base):
            hits.append(prefix)
        return sorted(hits)

    def close(self):
        if self._own:
            shutil.rmtree(self.root, ignore_errors=True)


class SQLBackend(Backend):
    """Relational layout: one normalized nodes table + parent index.

    Mirrors the paper's PostgreSQL+ltree baseline: Q1/Q3 are indexed path
    equality lookups, Q2 a parent-equality select, Q4 a range predicate
    on the path primary key (``path >= p AND path < p || U+10FFFF``), all
    through the SQL parse/plan path — the constant the paper measures.
    """

    name = "sql"

    def __init__(self):
        self.db = sqlite3.connect(":memory:")
        self.db.execute(
            "CREATE TABLE nodes (path TEXT PRIMARY KEY, parent TEXT, data BLOB)")
        self.db.execute("CREATE INDEX idx_parent ON nodes(parent)")

    def load(self, items):
        rows = []
        for path, rec in items:
            par = P.parent(path) if path != P.ROOT else None
            rows.append((path, par, R.encode(rec)))
        self.db.executemany("INSERT OR REPLACE INTO nodes VALUES (?,?,?)", rows)
        self.db.commit()

    def q1_get(self, path):
        cur = self.db.execute("SELECT data FROM nodes WHERE path = ?", (path,))
        row = cur.fetchone()
        return R.decode(row[0]) if row else None

    def q2_ls(self, path):
        if self.q1_get(path) is None:
            return None
        cur = self.db.execute(
            "SELECT path FROM nodes WHERE parent = ? ORDER BY path", (path,))
        return [r[0] for r in cur.fetchall()]

    def q3_navigate(self, path):
        out = []
        for anc in list(P.ancestors(path)) + [path]:
            rec = self.q1_get(anc)
            if rec is None:
                break
            out.append(rec)
        return out

    def q4_search(self, prefix):
        hi = prefix + "\U0010ffff"
        cur = self.db.execute(
            "SELECT path FROM nodes WHERE path >= ? AND path < ? ORDER BY path",
            (prefix, hi))
        return [r[0] for r in cur.fetchall()
                if P.is_prefix(prefix.rstrip(P.SEP) or P.ROOT, r[0])]

    def close(self):
        self.db.close()


class GraphBackend(Backend):
    """Property-graph layout: nodes by surrogate id, CHILD edges.

    Faithful to the graph-database contract the paper describes: there is
    *no* path index — Q1 must traverse the CHILD edges from the root,
    segment by segment (the Cypher ``MATCH (r)-[:CHILD*]->(n)`` plan), and
    Q4 has no native prefix primitive, so it scans node names.
    """

    name = "graph"

    def __init__(self):
        # node payloads stored SERIALIZED (wire-format parity with the
        # other backends — a property store marshals records too)
        self.nodes: dict[int, bytes] = {}
        self.names: dict[int, str] = {}
        self.edges: dict[int, dict[str, int]] = {}  # id -> {segment: child id}
        self.root_id = 0
        self._next = 1

    def load(self, items):
        ordered = sorted(items, key=lambda it: P.depth(it[0]))
        for path, rec in ordered:
            if path == P.ROOT:
                self.nodes[self.root_id] = R.encode(rec)
                self.names[self.root_id] = ""
                self.edges.setdefault(self.root_id, {})
                continue
            pid = self._resolve(P.parent(path))
            if pid is None:
                continue  # orphan — unreachable in a graph store
            seg = P.basename(path)
            nid = self.edges[pid].get(seg)
            if nid is None:
                nid = self._next
                self._next += 1
                self.edges[pid][seg] = nid
                self.edges.setdefault(nid, {})
            self.nodes[nid] = R.encode(rec)
            self.names[nid] = seg

    def _resolve(self, path: str) -> Optional[int]:
        nid = self.root_id
        for seg in P.segments(path):
            nxt = self.edges.get(nid, {}).get(seg)
            if nxt is None:
                return None
            nid = nxt
        return nid

    def q1_get(self, path):
        nid = self._resolve(path)
        if nid is None or nid not in self.nodes:
            return None
        return R.decode(self.nodes[nid])

    def q2_ls(self, path):
        nid = self._resolve(path)
        if nid is None:
            return None
        return [P.child(path, seg) for seg in sorted(self.edges.get(nid, {}))]

    def q3_navigate(self, path):
        out = []
        nid = self.root_id
        raw = self.nodes.get(nid)
        if raw is None:
            return out
        out.append(R.decode(raw))
        for seg in P.segments(path):
            nid2 = self.edges.get(nid, {}).get(seg)
            if nid2 is None or nid2 not in self.nodes:
                break
            nid = nid2
            out.append(R.decode(self.nodes[nid]))
        return out

    def q4_search(self, prefix):
        # no prefix primitive: BFS the whole graph materializing paths,
        # filter — the pattern-match emulation the paper describes.
        hits = []
        stack = [(self.root_id, P.ROOT)]
        while stack:
            nid, path = stack.pop()
            if P.is_prefix(prefix.rstrip(P.SEP) or P.ROOT, path):
                hits.append(path)
            for seg, cid in self.edges.get(nid, {}).items():
                stack.append((cid, P.child(path, seg)))
        return sorted(hits)


ALL_BACKENDS = {
    "wikikv": WikiKVBackend,
    "wikikv_sharded": WikiKVShardedBackend,
    "wikikv_device": WikiKVDeviceBackend,
    "wikikv_durable": WikiKVDurableBackend,
    "fs": FSBackend,
    "sql": SQLBackend,
    "graph": GraphBackend,
}
