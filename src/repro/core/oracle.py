"""LLM-oracle abstraction.

Every LLM touchpoint in the paper flows through one interface so the whole
pipeline runs (a) deterministically with the seeded ``HeuristicOracle``
(tests/benches — replacing the paper's DeepSeek-V4-Flash, per DESIGN.md §3),
or (b) with a real zoo LM via ``ModelOracle`` (repro/runtime/model_oracle.py).

Touchpoints (paper → method):
  IASI positioning 𝒫               → positioning(sample)
  IASI scaffold induction          → induce_scaffold(sample, positioning, constraints)
  ingestion entity assignment      → assign_entities(doc, scaffold)
  PageSplit Architect adjudication → adjudicate_split(entity_text)
  NAV CLASSIFY                     → classify_query(q)      (hybrid: regex + classifier)
  NAV EXTRACT                      → extract_keywords(q)
  NAV NEEDSDEEPER                  → needs_deeper(q, content)
  summaries / final answer         → summarize(texts), answer(q, evidence)

The HeuristicOracle is intentionally *lexical*: it has no private channel to
ground truth.  Answer correctness in the benchmarks is therefore driven by
whether the retrieval stage surfaced the right evidence — the same causal
structure as the paper's evaluation.
"""
from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field

# NAV route classes (paper §V-B)
ROUTE_ENUMERATE = "ENUMERATE"
ROUTE_LOOKUP = "LOOKUP"
ROUTE_AGGREGATE = "AGGREGATE"

_ENUM_RE = re.compile(
    r"^\s*(which|list|enumerate|what\s+are|show\s+all|how\s+many)\b", re.I)
_AGG_RE = re.compile(r"\b(compare|both|relationship\s+between|and)\b", re.I)

_STOP = frozenset(
    "a an the of in on at to for with and or is are was were did does do what "
    "who when where why how which his her their its about between".split())

_TOKEN_RE = re.compile(r"[a-z0-9_]+")


def tokens(text: str) -> list[str]:
    return _TOKEN_RE.findall(text.lower())


def content_tokens(text: str) -> list[str]:
    return [t for t in tokens(text) if t not in _STOP and len(t) > 1]


@dataclass
class ScaffoldSpec:
    """Directory scaffold T emitted by IASI: dimensions → entity seeds."""

    dimensions: dict[str, list[str]] = field(default_factory=dict)
    positioning: dict[str, str] = field(default_factory=dict)


class Oracle:
    """Abstract LLM oracle; all methods must be deterministic given state."""

    calls: Counter

    def __init__(self):
        self.calls = Counter()

    # --- schema construction ---
    def positioning(self, sample: list[dict]) -> dict[str, str]:
        raise NotImplementedError

    def induce_scaffold(self, sample: list[dict], positioning: dict[str, str],
                        *, k_max: int, depth_budget: int) -> ScaffoldSpec:
        raise NotImplementedError

    def assign_entities(self, doc: dict, scaffold: ScaffoldSpec) -> list[tuple[str, str]]:
        raise NotImplementedError

    def adjudicate_split(self, text: str) -> list[str] | None:
        raise NotImplementedError

    # --- navigation ---
    def classify_query(self, q: str) -> str:
        raise NotImplementedError

    def extract_keywords(self, q: str) -> list[str]:
        raise NotImplementedError

    def needs_deeper(self, q: str, content: str, theta: float = 0.34) -> bool:
        raise NotImplementedError

    # --- generation ---
    def summarize(self, texts: list[str], limit: int = 400) -> str:
        raise NotImplementedError

    def answer(self, q: str, evidence: list[str]) -> str:
        raise NotImplementedError


class HeuristicOracle(Oracle):
    """Deterministic lexical oracle (the container's DeepSeek stand-in)."""

    def __init__(self, seed: int = 0):
        super().__init__()
        self.seed = seed

    # ------------------------------------------------------------------
    def positioning(self, sample):
        self.calls["positioning"] += 1
        topics = Counter()
        for doc in sample:
            topics.update(doc.get("topics", []) or content_tokens(doc["text"])[:4])
        focus = ", ".join(t for t, _ in topics.most_common(3))
        return {
            "focus": focus or "general",
            "audience": "followers of the account",
            "ingestion_bias": "author-curated articles, low-information filtered",
        }

    def induce_scaffold(self, sample, positioning, *, k_max, depth_budget):
        """Intent-anchored: dimensions from the positioning focus topics
        (not just whatever entity surfaces first), entities from per-topic
        salient tokens.  Structural constraints enforced by construction.

        Sample-size sensitivity (the §III-C mechanism the w/o-Cold-Start
        ablation measures): a small curated sample keeps the schema
        discriminating; injecting the *full* corpus inflates the prompt,
        so incidental token overlaps surface as spurious over-specific
        entities and the per-dimension entity lists balloon — modeled
        here by letting the entity pool grow with the sample and by
        admitting raw content-token 'entities' past the curated budget."""
        self.calls["induce_scaffold"] += 1
        dim_docs: dict[str, list[dict]] = {}
        for doc in sample:
            for topic in (doc.get("topics") or ["misc"]):
                dim_docs.setdefault(topic, []).append(doc)
        ranked = sorted(dim_docs.items(), key=lambda kv: (-len(kv[1]), kv[0]))
        oversized = len(sample) > 48
        ents_per_dim = 8 if not oversized else min(k_max, len(sample) // 4)
        dims: dict[str, list[str]] = {}
        for topic, docs in ranked[: max(2, min(k_max, len(ranked)))]:
            ents = Counter()
            for d in docs:
                ents.update(d.get("entities", []) or content_tokens(d["text"])[:3])
                if oversized:
                    # incidental-overlap noise: frequent content tokens
                    # masquerade as entities in an over-fed induction
                    ents.update(t for t in content_tokens(d["text"])[4:8])
            dims[topic] = [e for e, _ in ents.most_common(ents_per_dim)]
        return ScaffoldSpec(dimensions=dims, positioning=dict(positioning))

    def assign_entities(self, doc, scaffold):
        self.calls["assign_entities"] += 1
        out: list[tuple[str, str]] = []
        doc_topics = set(doc.get("topics", []))
        doc_ents = set(doc.get("entities", []))
        for dim, ents in scaffold.dimensions.items():
            if doc_topics and dim not in doc_topics and dim != "misc":
                continue
            for e in ents:
                if not doc_ents or e in doc_ents:
                    out.append((dim, e))
        if not out:
            # fall back to the first dimension + a salient token entity
            dim = next(iter(scaffold.dimensions), "misc")
            ent = (doc.get("entities") or content_tokens(doc["text"])[:1] or ["misc"])[0]
            out.append((dim, ent))
        # dedupe, stable order
        seen, uniq = set(), []
        for pair in out:
            if pair not in seen:
                seen.add(pair)
                uniq.append(pair)
        return uniq

    def adjudicate_split(self, text):
        """Separable-subtree adjudication: a page whose paragraphs cluster
        around ≥2 distinct head tokens admits a split along those heads."""
        self.calls["adjudicate_split"] += 1
        paras = [p for p in text.split("\n\n") if p.strip()]
        if len(paras) < 2:
            return None
        heads = []
        for p in paras:
            ct = content_tokens(p)
            if ct:
                heads.append(ct[0])
        distinct = sorted(set(heads))
        if len(distinct) >= 2:
            return distinct[:4]
        return None

    # ------------------------------------------------------------------
    def classify_query(self, q):
        """Hybrid router: regex layer for enumeration triggers, token
        heuristic (the distilled classifier's stand-in) for the rest."""
        self.calls["classify_query"] += 1
        if _ENUM_RE.search(q):
            return ROUTE_ENUMERATE
        if _AGG_RE.search(q):
            return ROUTE_AGGREGATE
        return ROUTE_LOOKUP

    def extract_keywords(self, q):
        self.calls["extract_keywords"] += 1
        ct = content_tokens(q)
        # rank by rarity proxy: longer tokens first, stable tie-break
        return sorted(set(ct), key=lambda t: (-len(t), t))[:6]

    def needs_deeper(self, q, content, theta=0.34):
        """Semantic-coverage threshold test (paper: lightweight classifier
        or one LLM call).  Coverage = fraction of query content tokens
        present in the candidate content."""
        self.calls["needs_deeper"] += 1
        qt = set(content_tokens(q))
        if not qt:
            return False
        cov = len(qt & set(tokens(content))) / len(qt)
        return cov < theta

    # ------------------------------------------------------------------
    def summarize(self, texts, limit=400):
        self.calls["summarize"] += 1
        joined = " ".join(t.strip() for t in texts if t.strip())
        return joined[:limit]

    def answer(self, q, evidence):
        """Evidence-bounded answering: emit the evidence sentences that
        cover the query tokens.  No access to anything outside `evidence`,
        so retrieval quality is the only driver of correctness."""
        self.calls["answer"] += 1
        qt = set(content_tokens(q))
        scored: list[tuple[float, str]] = []
        for ev in evidence:
            for sent in re.split(r"(?<=[.!?])\s+", ev):
                st = set(content_tokens(sent))
                if not st:
                    continue
                overlap = len(qt & st) / max(len(qt), 1)
                if overlap > 0:
                    scored.append((-overlap, sent.strip()))
        scored.sort(key=lambda x: (x[0], x[1]))
        return " ".join(s for _, s in scored[:6])
