"""Budgeted path navigation NAV(q, B) (paper §V, Algorithm 1).

Two-phase, search-accelerated plan:
  Phase 1 — CLASSIFY(q) routes enumeration queries straight to LS("/");
            everything else runs SEARCH(EXTRACT(q)) over the path namespace
            for k candidate paths (constant KV round trips, independent of
            depth D).
  Phase 2 — targeted GETs on candidates; NEEDSDEEPER triggers at most one
            single-level LS expansion per candidate.

Progressive-answer contract (Property 1): results are emitted in order of
monotonically increasing granularity — r1 index summary, r2 dimension
summary, then entity/source pages — so *any* prefix of the output is a
valid (coarser) answer.  Budget guards run before every potentially
expensive step; on exhaustion the accumulated prefix is returned as-is.

Budgets are pluggable: ``WallClockBudget`` (production semantics, ms) or
``UnitBudget`` (deterministic virtual costs for tests — DESIGN.md §3).

Theorem 3 (step compression) is observable via ``NavTrace.llm_calls``:
layer-by-layer navigation needs D oracle descents; here a single SEARCH
replaces the first D−h levels, leaving h ∈ {0, 1} NEEDSDEEPER calls per
single-target query (≤ k when q aggregates across k dimensions).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from . import paths as P
from . import records as R
from .cache import TieredCache
from .oracle import (ROUTE_AGGREGATE, ROUTE_ENUMERATE, ROUTE_LOOKUP, Oracle)
from .store import PathStore

# result granularity levels, in emission order (Property 1)
KIND_INDEX = "index_summary"
KIND_DIMENSION = "dimension_summary"
KIND_ENTITY = "entity_page"
KIND_LISTING = "listing"
KIND_SOURCE = "source"

# paper §V-A: r1 = index level, r2 = dimension level, r3.. = entity OR
# article level — one shared granularity bucket from r3 onward.
_GRANULARITY = {KIND_INDEX: 0, KIND_DIMENSION: 1, KIND_ENTITY: 2,
                KIND_LISTING: 2, KIND_SOURCE: 2}


@dataclass
class NavResult:
    kind: str
    path: str
    text: str

    @property
    def granularity(self) -> int:
        return _GRANULARITY[self.kind]


@dataclass
class NavTrace:
    """Per-query accounting (Tables III/V/VI metrics)."""

    tool_calls: int = 0     # GET/LS/SEARCH storage operations
    pages_read: int = 0     # entity/source payloads read
    llm_calls: int = 0      # oracle descents on the critical path
    accessed: set[str] = field(default_factory=set)
    budget_exhausted: bool = False
    route: str = ""


class Budget:
    def charge(self, op: str) -> None:
        raise NotImplementedError

    def exhausted(self) -> bool:
        raise NotImplementedError


class WallClockBudget(Budget):
    """B in milliseconds of wall-clock (production semantics)."""

    def __init__(self, ms: float, clock: Callable[[], float] = time.monotonic):
        self.t0 = clock()
        self.ms = ms
        self.clock = clock

    def charge(self, op: str) -> None:
        pass

    def exhausted(self) -> bool:
        return (self.clock() - self.t0) * 1000.0 >= self.ms


class UnitBudget(Budget):
    """Deterministic virtual-cost budget; op costs mirror the paper's
    dominant-step analysis (LLM call ≫ storage round trip)."""

    COSTS = {"get": 1, "ls": 1, "search": 2, "classify": 1, "llm": 25}

    def __init__(self, units: int):
        self.units = units
        self.spent = 0

    def charge(self, op: str) -> None:
        self.spent += self.COSTS.get(op, 1)

    def exhausted(self) -> bool:
        return self.spent >= self.units


class Navigator:
    """NAV(q, B) over a PathStore (optionally through the tiered cache)."""

    def __init__(self, store: PathStore, oracle: Oracle,
                 cache: TieredCache | None = None, k: int = 3,
                 theta: float = 0.34, search_routing: bool = True):
        self.store = store
        self.oracle = oracle
        self.cache = cache
        self.k = k
        self.theta = theta
        self.search_routing = search_routing

    # -- storage primitives through the cache when present -----------------
    def _get(self, path: str, trace: NavTrace, budget: Budget) -> Optional[R.Record]:
        budget.charge("get")
        trace.tool_calls += 1
        trace.accessed.add(path)
        rec = (self.cache.get(path) if self.cache is not None
               else self.store.get(path))
        return rec

    def _ls(self, path: str, trace: NavTrace, budget: Budget):
        budget.charge("ls")
        trace.tool_calls += 1
        trace.accessed.add(path)
        if self.cache is not None:
            return self.cache.ls(path)
        return self.store.ls(path)

    # ----------------------------------------------------------------------
    def nav(self, q: str, budget: Budget) -> tuple[list[NavResult], NavTrace]:
        trace = NavTrace()
        R_out: list[NavResult] = []

        budget.charge("classify")
        cls = self.oracle.classify_query(q)
        trace.route = cls

        # r1: index-level summary — the coarsest valid answer, from L1.
        root_ls = self._ls(P.ROOT, trace, budget)
        if root_ls is not None:
            rec, children = root_ls
            dims = [P.basename(c) for c in children if not P.is_reserved(c)]
            R_out.append(NavResult(
                KIND_INDEX, P.ROOT,
                f"the wiki contains {len(dims)} dimensions: " + ", ".join(dims)))

        # enumeration queries: answered by the single directory listing
        if cls == ROUTE_ENUMERATE:
            return R_out, trace

        # Phase 1: search-accelerated routing
        if self.search_routing:
            budget.charge("search")
            trace.tool_calls += 1
            keywords = self.oracle.extract_keywords(q)
            candidates = self._search_candidates(keywords)
        else:
            # ablation: pure layer-by-layer navigation (w/o Search Routing)
            candidates = self._layer_by_layer(q, trace, budget)

        if budget.exhausted():
            trace.budget_exhausted = True
            return R_out, trace  # coarsest fallback prefix

        # Phase 2: targeted navigation.
        # r2 first: dimension summaries for all candidate dimensions, so the
        # emission order stays monotone in granularity (Property 1).
        chosen = candidates[: self.k if self.search_routing else None]
        emitted_dims: set[str] = set()
        for path in chosen:
            segs = P.segments(path)
            if not segs or P.is_reserved(path):
                continue
            dim = P.SEP + segs[0]
            if dim in emitted_dims:
                continue
            emitted_dims.add(dim)
            drec = self._get(dim, trace, budget)
            if isinstance(drec, R.DirRecord):
                R_out.append(NavResult(
                    KIND_DIMENSION, dim,
                    f"{P.basename(dim)} contains {len(drec.children())} "
                    f"entries: " + ", ".join(drec.children()[:12])))
        # r3 onward: entity/article pages
        for path in chosen:
            rec = self._get(path, trace, budget)
            if rec is None:
                continue  # skip-on-miss
            # the candidate page itself
            text = rec.text if isinstance(rec, R.FileRecord) else rec.summary
            kind = KIND_SOURCE if P.is_prefix(P.SOURCES_PREFIX, path) else KIND_ENTITY
            R_out.append(NavResult(kind, path, text))
            trace.pages_read += 1
            # linked sources: follow entity-page links to the hoisted subtree
            if isinstance(rec, R.FileRecord):
                for src in rec.meta.sources[:2]:
                    if budget.exhausted():
                        break
                    srec = self._get(src, trace, budget)
                    if isinstance(srec, R.FileRecord):
                        R_out.append(NavResult(KIND_SOURCE, src, srec.text))
                        trace.pages_read += 1
            # NEEDSDEEPER: at most one single-level expansion
            budget.charge("llm")
            trace.llm_calls += 1
            if self.oracle.needs_deeper(q, text, self.theta):
                deeper = self._ls(path, trace, budget)
                if deeper is not None:
                    drec, kids = deeper
                    R_out.append(NavResult(
                        KIND_LISTING, path,
                        "contains: " + ", ".join(P.basename(kp) for kp in kids)))
                    for kp in kids[:2]:
                        if budget.exhausted():
                            break
                        krec = self._get(kp, trace, budget)
                        if isinstance(krec, R.FileRecord):
                            R_out.append(NavResult(KIND_ENTITY, kp, krec.text))
                            trace.pages_read += 1
            if budget.exhausted():
                trace.budget_exhausted = True
                break
        return R_out, trace

    # ----------------------------------------------------------------------
    def _search_candidates(self, keywords: list[str]) -> list[str]:
        """SEARCH(EXTRACT(q)): keyword routing over the path namespace.
        Scores paths by keyword hits; prefers deeper (more specific) pages."""
        scores: dict[str, float] = {}
        for kw in keywords:
            for p in self.store.search_contains(kw, limit=64):
                if P.is_prefix(P.META_PREFIX, p):
                    continue
                scores[p] = scores.get(p, 0.0) + 1.0 + 0.1 * P.depth(p)
        ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
        return [p for p, _ in ranked[: self.k * 3]]

    def _layer_by_layer(self, q: str, trace: NavTrace, budget: Budget) -> list[str]:
        """Ablation path: descend one oracle call per level from the root
        (the D-step plan Theorem 3 compresses away)."""
        frontier = [P.ROOT]
        found: list[str] = []
        qk = set(self.oracle.extract_keywords(q))
        while frontier and not budget.exhausted():
            path = frontier.pop(0)
            out = self._ls(path, trace, budget)
            if out is None:
                rec = self._get(path, trace, budget)
                if rec is not None:
                    found.append(path)
                continue
            _, children = out
            # one LLM adjudication per level: pick children lexically
            # overlapping the query
            budget.charge("llm")
            trace.llm_calls += 1
            picked = [c for c in children
                      if not P.is_reserved(c)
                      and (set(P.basename(c).lower().split("_")) & qk
                           or any(k in P.basename(c).lower() for k in qk))]
            if not picked:
                picked = [c for c in children if not P.is_reserved(c)][:2]
            frontier.extend(picked[:3])
            for c in picked:
                if self.store.get(c) is not None and P.depth(c) >= 2:
                    found.append(c)
        return found


def check_progressive(results: list[NavResult]) -> bool:
    """Property 1: granularity is monotonically non-decreasing, so every
    prefix is itself a usable (coarser) answer."""
    levels = [r.granularity for r in results]
    return all(a <= b for a, b in zip(levels, levels[1:]))
