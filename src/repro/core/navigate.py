"""Budgeted path navigation NAV(q, B) (paper §V, Algorithm 1).

Two-phase, search-accelerated plan:
  Phase 1 — CLASSIFY(q) routes enumeration queries straight to LS("/");
            everything else runs SEARCH(EXTRACT(q)) over the path namespace
            for k candidate paths (constant KV round trips, independent of
            depth D).
  Phase 2 — targeted GETs on candidates; NEEDSDEEPER triggers at most one
            single-level LS expansion per candidate.

Progressive-answer contract (Property 1): results are emitted in order of
monotonically increasing granularity — r1 index summary, r2 dimension
summary, then entity/source pages — so *any* prefix of the output is a
valid (coarser) answer.  Budget guards run before every potentially
expensive step; on exhaustion the accumulated prefix is returned as-is.

Budgets are pluggable: ``WallClockBudget`` (production semantics, ms) or
``UnitBudget`` (deterministic virtual costs for tests — DESIGN.md §3).

Theorem 3 (step compression) is observable via ``NavTrace.llm_calls``:
layer-by-layer navigation needs D oracle descents; here a single SEARCH
replaces the first D−h levels, leaving h ∈ {0, 1} NEEDSDEEPER calls per
single-target query (≤ k when q aggregates across k dimensions).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Generator, Optional

from . import paths as P
from . import records as R
from .cache import TieredCache
from .engine import BatchPlanner, HostEngine, QueryEngine, drive
from .oracle import (ROUTE_AGGREGATE, ROUTE_ENUMERATE, ROUTE_LOOKUP, Oracle)
from .store import PathStore

# result granularity levels, in emission order (Property 1)
KIND_INDEX = "index_summary"
KIND_DIMENSION = "dimension_summary"
KIND_ENTITY = "entity_page"
KIND_LISTING = "listing"
KIND_SOURCE = "source"

# paper §V-A: r1 = index level, r2 = dimension level, r3.. = entity OR
# article level — one shared granularity bucket from r3 onward.
_GRANULARITY = {KIND_INDEX: 0, KIND_DIMENSION: 1, KIND_ENTITY: 2,
                KIND_LISTING: 2, KIND_SOURCE: 2}


@dataclass
class NavResult:
    kind: str
    path: str
    text: str

    @property
    def granularity(self) -> int:
        return _GRANULARITY[self.kind]


@dataclass
class NavTrace:
    """Per-query accounting (Tables III/V/VI metrics)."""

    tool_calls: int = 0     # GET/LS/SEARCH storage operations
    pages_read: int = 0     # entity/source payloads read
    llm_calls: int = 0      # oracle descents on the critical path
    accessed: set[str] = field(default_factory=set)
    budget_exhausted: bool = False
    route: str = ""
    rounds: int = 0         # planner rounds this session stayed live
                            # (set by run_sessions; 0 for unbatched nav)


class Budget:
    def charge(self, op: str) -> None:
        raise NotImplementedError

    def exhausted(self) -> bool:
        raise NotImplementedError


class WallClockBudget(Budget):
    """B in milliseconds of wall-clock (production semantics)."""

    def __init__(self, ms: float, clock: Callable[[], float] = time.monotonic):
        self.t0 = clock()
        self.ms = ms
        self.clock = clock

    def charge(self, op: str) -> None:
        pass

    def exhausted(self) -> bool:
        return (self.clock() - self.t0) * 1000.0 >= self.ms


class UnitBudget(Budget):
    """Deterministic virtual-cost budget; op costs mirror the paper's
    dominant-step analysis (LLM call ≫ storage round trip)."""

    COSTS = {"get": 1, "ls": 1, "search": 2, "classify": 1, "llm": 25}

    def __init__(self, units: int):
        self.units = units
        self.spent = 0

    def charge(self, op: str) -> None:
        self.spent += self.COSTS.get(op, 1)

    def exhausted(self) -> bool:
        return self.spent >= self.units


#: generator type of one navigation session: yields whenever it has
#: enqueued planner futures that need a flush; returns (results, trace)
NavSession = Generator[None, None, "tuple[list[NavResult], NavTrace]"]


class Navigator:
    """NAV(q, B), expressed as operation futures against a BatchPlanner.

    Accepts a ``PathStore``/``ShardedPathStore`` (wrapped in a
    ``HostEngine``), a ``QueryEngine`` (host or device), or an existing
    ``BatchPlanner`` (shared with other components, e.g. the serving
    engine).  Each query is a *session generator* that yields at every
    point it needs storage results; the planner batches the pending
    operations of every in-flight session into one engine call per
    operator.  ``nav()`` drives a single session (flush per yield);
    ``nav_many()`` schedules many sessions concurrently — that is where
    the batching wins come from.
    """

    def __init__(self, store, oracle: Oracle,
                 cache: TieredCache | None = None, k: int = 3,
                 theta: float = 0.34, search_routing: bool = True):
        if isinstance(store, BatchPlanner):
            self.planner = store
            self.engine = store.engine
        elif isinstance(store, QueryEngine):
            self.engine = store
            self.planner = BatchPlanner(store)
        else:
            self.engine = HostEngine(store)
            self.planner = BatchPlanner(self.engine)
        # host-side store handle when one exists (back-compat / ablation)
        self.store = getattr(self.engine, "store", None)
        self.oracle = oracle
        self.cache = cache
        self.k = k
        self.theta = theta
        self.search_routing = search_routing

    # -- storage primitives as planner futures -----------------------------
    # each helper charges the budget/trace exactly where the direct-call
    # implementation did, then yields once if (and only if) it actually
    # needs a planner flush — cache hits resolve without yielding.
    def _get_g(self, path: str, trace: NavTrace, budget: Budget):
        budget.charge("get")
        trace.tool_calls += 1
        trace.accessed.add(path)
        if self.cache is not None:
            hit = self.cache.peek(path)
            if hit is not None:
                return hit
        fut = self.planner.get(path)
        yield
        rec = fut.value
        if self.cache is not None:
            self.cache.admit(path, rec)
        return rec

    def _get_many_g(self, paths: list[str], trace: NavTrace, budget: Budget):
        """Batch variant for independent point reads (charges first, one
        yield for the whole set)."""
        for p in paths:
            budget.charge("get")
            trace.tool_calls += 1
            trace.accessed.add(p)
        resolved: dict[str, Optional[R.Record]] = {}
        futs = []
        for p in paths:
            if self.cache is not None:
                hit = self.cache.peek(p)
                if hit is not None:
                    resolved[p] = hit
                    continue
            futs.append((p, self.planner.get(p)))
        if futs:
            yield
        for p, fut in futs:
            rec = fut.value
            if self.cache is not None:
                self.cache.admit(p, rec)
            resolved[p] = rec
        return [resolved[p] for p in paths]

    def _ls_g(self, path: str, trace: NavTrace, budget: Budget):
        budget.charge("ls")
        trace.tool_calls += 1
        trace.accessed.add(path)
        if self.cache is not None:
            # mirror TieredCache.ls: fetch the RECORD (so file records are
            # promoted too — a later _get_g on the same path is a cache
            # hit), derive the child listing locally
            rec = self.cache.peek(path)
            if rec is None:
                fut = self.planner.get(path)
                yield
                rec = fut.value
                self.cache.admit(path, rec)
            if not isinstance(rec, R.DirRecord):
                return None
            return rec, [P.child(path, s) for s in rec.children()]
        fut = self.planner.ls(path)
        yield
        return fut.value

    # ----------------------------------------------------------------------
    def nav(self, q: str, budget: Budget) -> tuple[list[NavResult], NavTrace]:
        """Single-query entry point: drives one session, flushing the
        planner at every yield (batch size ≥ 1)."""
        return drive(self.session(q, budget), self.planner)

    def nav_many(self, queries: list[str], budgets: list[Budget]
                 ) -> list[tuple[list[NavResult], NavTrace]]:
        """Run many sessions concurrently: every round advances each live
        session to its next storage dependency, then ONE planner flush
        executes the union of their pending ops as per-operator batches."""
        gens = [self.session(q, b) for q, b in zip(queries, budgets)]
        return run_sessions(self.planner, gens)

    def session(self, q: str, budget: Budget) -> NavSession:
        trace = NavTrace()
        R_out: list[NavResult] = []

        budget.charge("classify")
        cls = self.oracle.classify_query(q)
        trace.route = cls

        # r1: index-level summary — the coarsest valid answer, from L1.
        root_ls = yield from self._ls_g(P.ROOT, trace, budget)
        if root_ls is not None:
            rec, children = root_ls
            dims = [P.basename(c) for c in children if not P.is_reserved(c)]
            R_out.append(NavResult(
                KIND_INDEX, P.ROOT,
                f"the wiki contains {len(dims)} dimensions: " + ", ".join(dims)))

        # enumeration queries: answered by the single directory listing
        if cls == ROUTE_ENUMERATE:
            return R_out, trace

        # Phase 1: search-accelerated routing
        if self.search_routing:
            budget.charge("search")
            trace.tool_calls += 1
            keywords = self.oracle.extract_keywords(q)
            candidates = yield from self._search_candidates_g(keywords)
        else:
            # ablation: pure layer-by-layer navigation (w/o Search Routing)
            candidates = yield from self._layer_by_layer_g(q, trace, budget)

        if budget.exhausted():
            trace.budget_exhausted = True
            return R_out, trace  # coarsest fallback prefix

        # Phase 2: targeted navigation.
        # r2 first: dimension summaries for all candidate dimensions, so the
        # emission order stays monotone in granularity (Property 1).  The
        # dimension reads are independent → one batched round.
        chosen = candidates[: self.k if self.search_routing else None]
        dims_wanted: list[str] = []
        emitted_dims: set[str] = set()
        for path in chosen:
            segs = P.segments(path)
            if not segs or P.is_reserved(path):
                continue
            dim = P.SEP + segs[0]
            if dim in emitted_dims:
                continue
            emitted_dims.add(dim)
            dims_wanted.append(dim)
        dim_recs = yield from self._get_many_g(dims_wanted, trace, budget)
        for dim, drec in zip(dims_wanted, dim_recs):
            if isinstance(drec, R.DirRecord):
                R_out.append(NavResult(
                    KIND_DIMENSION, dim,
                    f"{P.basename(dim)} contains {len(drec.children())} "
                    f"entries: " + ", ".join(drec.children()[:12])))
        # r3 onward: entity/article pages
        for path in chosen:
            rec = yield from self._get_g(path, trace, budget)
            if rec is None:
                continue  # skip-on-miss
            # the candidate page itself
            text = rec.text if isinstance(rec, R.FileRecord) else rec.summary
            kind = KIND_SOURCE if P.is_prefix(P.SOURCES_PREFIX, path) else KIND_ENTITY
            R_out.append(NavResult(kind, path, text))
            trace.pages_read += 1
            # linked sources: follow entity-page links to the hoisted subtree
            if isinstance(rec, R.FileRecord):
                for src in rec.meta.sources[:2]:
                    if budget.exhausted():
                        break
                    srec = yield from self._get_g(src, trace, budget)
                    if isinstance(srec, R.FileRecord):
                        R_out.append(NavResult(KIND_SOURCE, src, srec.text))
                        trace.pages_read += 1
            # NEEDSDEEPER: at most one single-level expansion
            budget.charge("llm")
            trace.llm_calls += 1
            if self.oracle.needs_deeper(q, text, self.theta):
                deeper = yield from self._ls_g(path, trace, budget)
                if deeper is not None:
                    drec, kids = deeper
                    R_out.append(NavResult(
                        KIND_LISTING, path,
                        "contains: " + ", ".join(P.basename(kp) for kp in kids)))
                    for kp in kids[:2]:
                        if budget.exhausted():
                            break
                        krec = yield from self._get_g(kp, trace, budget)
                        if isinstance(krec, R.FileRecord):
                            R_out.append(NavResult(KIND_ENTITY, kp, krec.text))
                            trace.pages_read += 1
            if budget.exhausted():
                trace.budget_exhausted = True
                break
        return R_out, trace

    # ----------------------------------------------------------------------
    def _search_candidates_g(self, keywords: list[str]):
        """SEARCH(EXTRACT(q)): keyword routing over the path namespace —
        all keywords resolve in one batched containment round.  Scores
        paths by keyword hits; prefers deeper (more specific) pages."""
        futs = [(kw, self.planner.contains(kw, limit=64)) for kw in keywords]
        if futs:
            yield
        scores: dict[str, float] = {}
        for kw, fut in futs:
            for p in fut.value:
                if P.is_prefix(P.META_PREFIX, p):
                    continue
                scores[p] = scores.get(p, 0.0) + 1.0 + 0.1 * P.depth(p)
        ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
        return [p for p, _ in ranked[: self.k * 3]]

    def _layer_by_layer_g(self, q: str, trace: NavTrace, budget: Budget):
        """Ablation path: descend one oracle call per level from the root
        (the D-step plan Theorem 3 compresses away)."""
        frontier = [P.ROOT]
        found: list[str] = []
        qk = set(self.oracle.extract_keywords(q))
        while frontier and not budget.exhausted():
            path = frontier.pop(0)
            out = yield from self._ls_g(path, trace, budget)
            if out is None:
                rec = yield from self._get_g(path, trace, budget)
                if rec is not None:
                    found.append(path)
                continue
            _, children = out
            # one LLM adjudication per level: pick children lexically
            # overlapping the query
            budget.charge("llm")
            trace.llm_calls += 1
            picked = [c for c in children
                      if not P.is_reserved(c)
                      and (set(P.basename(c).lower().split("_")) & qk
                           or any(k in P.basename(c).lower() for k in qk))]
            if not picked:
                picked = [c for c in children if not P.is_reserved(c)][:2]
            frontier.extend(picked[:3])
            # probe reads (uncharged in the direct-call implementation):
            # batch them in one round
            futs = [(c, self.planner.get(c)) for c in picked]
            if futs:
                yield
            for c, fut in futs:
                if fut.value is not None and P.depth(c) >= 2:
                    found.append(c)
        return found


def run_sessions(planner: BatchPlanner, gens: list[NavSession]
                 ) -> list[tuple[list[NavResult], NavTrace]]:
    """Concurrent session scheduler: round-based continuous batching of
    storage operations.  Each round advances every live session once,
    then a single ``planner.flush()`` executes all pending operations as
    per-operator batches."""
    out: list = [None] * len(gens)
    rounds = [0] * len(gens)
    active = list(enumerate(gens))
    while active:
        still = []
        for i, g in active:
            rounds[i] += 1
            try:
                next(g)
                still.append((i, g))
            except StopIteration as e:
                out[i] = e.value
        planner.flush()
        active = still
    # one wave == one run_sessions call: writes admitted during the wave
    # (writer sessions sharing this planner) commit to the read view here,
    # so the NEXT wave pins the fresh epoch — staleness Δ = 1 wave
    planner.engine.refresh()
    for i, res in enumerate(out):
        if res is not None:
            res[1].rounds = rounds[i]
    return out


def check_progressive(results: list[NavResult]) -> bool:
    """Property 1: granularity is monotonically non-decreasing, so every
    prefix is itself a usable (coarser) answer."""
    levels = [r.granularity for r in results]
    return all(a <= b for a, b in zip(levels, levels[1:]))
