"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int = 200, total: int = 10000,
                    min_ratio: float = 0.1):
    """Linear warmup → cosine decay to min_ratio.  Returns a scale in
    (0, 1] multiplying the base lr."""
    s = jnp.asarray(step, jnp.float32)
    # (s+1)/warmup: the first step trains at lr/warmup instead of zero
    warm = jnp.minimum((s + 1.0) / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
