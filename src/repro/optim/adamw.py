"""AdamW with optionally quantized first/second moments.

Trillion-parameter configs (kimi-k2) cannot afford f32 moments: at 1T
params, f32 (m, v) alone is 8 TB.  ``state_dtype``:

  "float32"  — reference Adam (small/medium configs)
  "bfloat16" — 2× smaller; update math still in f32
  "int8"     — block-quantized moments (256-entry blocks, absmax scale,
               the 8-bit-Adam recipe) — 8× smaller than f32; the
               dequant→update→requant round-trip is fused by XLA.

State is a pytree mirroring params; every leaf keeps the param's sharding,
so FSDP/TP sharding of the moments comes for free from the param specs.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

#: leaves smaller than this keep f32 moments (quantization overhead
#: dominates below it)
_QUANT_MIN = 1 << 16


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"   # float32 | bfloat16 | int8
    #: stacked scanned-body leaves bigger than this (elements) update via
    #: lax.map over the leading period axis, bounding f32 temp memory
    scan_update_min: int = 1 << 28


def _q_init(x):
    """Per-row (last-axis) absmax int8: ``q`` keeps the param's SHAPE and
    therefore its SHARDING — quantized moments never force a relayout
    (flat-block layouts regather the whole tensor at every step; measured
    2.5 TB/device temp on kimi before this layout)."""
    return {
        "q": jnp.zeros(x.shape, jnp.int8),
        "scale": jnp.zeros(x.shape[:-1], jnp.float32),
    }


def _q_quant(val: jax.Array, like_shape, *, root: bool = False) -> dict:
    """``root=True`` stores the moment in the sqrt domain: the second
    moment spans many decades within a row, and the update consumes
    ``sqrt(v)`` — quantizing the root bounds the error on the quantity
    actually used instead of letting absmax error blow up small entries
    through the sqrt (measured 6× lower trajectory error)."""
    vf = val.astype(jnp.float32)
    if root:
        vf = jnp.sqrt(vf)
    scale = jnp.max(jnp.abs(vf), axis=-1) / 127.0
    q = jnp.round(vf / jnp.maximum(scale, 1e-12)[..., None]).astype(jnp.int8)
    return {"q": q, "scale": scale}


def _q_dequant(st: dict, shape, *, root: bool = False) -> jax.Array:
    x = st["q"].astype(jnp.float32) * st["scale"][..., None]
    return x * x if root else x


def _leaf_quantized(p) -> bool:
    n = 1
    for d in p.shape:
        n *= d
    return n >= _QUANT_MIN and len(p.shape) >= 2


def adamw_init(params, cfg: AdamWConfig):
    if cfg.state_dtype == "int8":
        def init_leaf(p):
            if _leaf_quantized(p):
                return _q_init(p)
            return jnp.zeros(p.shape, jnp.float32)
        m = jax.tree.map(init_leaf, params)
        v = jax.tree.map(init_leaf, params)
    else:
        dt = jnp.dtype(cfg.state_dtype)
        m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params)
        v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state).  Update math in f32 regardless of
    storage dtype."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m_st, v_st):
        gf = g.astype(jnp.float32)
        quant = isinstance(m_st, dict)
        if quant:
            m_prev = _q_dequant(m_st, p.shape)
            v_prev = _q_dequant(v_st, p.shape, root=True)
        else:
            m_prev = m_st.astype(jnp.float32)
            v_prev = v_st.astype(jnp.float32)
        m_new = cfg.b1 * m_prev + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v_prev + (1 - cfg.b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        if quant:
            return (pf.astype(p.dtype), _q_quant(m_new, p.shape),
                    _q_quant(v_new, p.shape, root=True))
        dt = (jnp.float32 if cfg.state_dtype == "int8"
              else jnp.dtype(cfg.state_dtype))
        return pf.astype(p.dtype), m_new.astype(dt), v_new.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    # flatten_up_to stops at param-leaf positions, so quantized moment
    # subtrees ({"q","scale"} dicts) come through intact
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])

    def upd_leaf(p, g, m, v):
        # chunk the update over the leading (scan-period) axis for huge
        # stacked leaves: bounds the f32 dequant/update temp to one slice
        if (p.ndim >= 3 and p.size >= cfg.scan_update_min
                and p.shape[0] > 1):
            def body(args):
                return upd(*args)
            return jax.lax.map(body, (p, g, m, v))
        return upd(p, g, m, v)

    out = [upd_leaf(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
