"""Error-feedback int8 gradient compression for the DP reduce.

At multi-pod scale the data-parallel gradient reduce-scatter crosses the
(slow) pod interconnect; int8 block-quantized gradients cut those bytes
4× vs f32 / 2× vs bf16.  Error feedback (residual carried to the next
step) keeps the compression unbiased in the long run — SGD-with-EF
convergence applies.

Usage in the train loop:
    cgrads, new_resid = compress_grads(grads, resid)
    # all-reduce cgrads (int8 payload + f32 scales: scales are 1/256 of
    # the payload, reduced in f32)
    grads = decompress_grads(cgrads)

The compression is applied *after* the per-device grad computation and
*before* the cross-pod reduce; within-pod reduces stay full precision
(configured in runtime/train_loop.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_BLOCK = 256


def _quant_leaf(g: jax.Array, r: jax.Array | None):
    gf = g.astype(jnp.float32)
    if r is not None:
        gf = gf + r
    n = gf.size
    pad = (-n) % _BLOCK
    flat = gf.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None]).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n].reshape(g.shape)
    resid = gf - deq
    return {"q": q, "scale": scale, "shape": tuple(g.shape)}, resid


def compress_grads(grads, residuals=None):
    """Returns (compressed pytree, new residuals pytree)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = (treedef.flatten_up_to(residuals)
                  if residuals is not None else [None] * len(leaves))
    outs = [_quant_leaf(g, r) for g, r in zip(leaves, res_leaves)]
    comp = treedef.unflatten([o[0] for o in outs])
    resid = treedef.unflatten([o[1] for o in outs])
    return comp, resid


def decompress_grads(comp):
    def deq(st):
        flat = (st["q"].astype(jnp.float32) * st["scale"][:, None]).reshape(-1)
        n = 1
        for d in st["shape"]:
            n *= d
        return flat[:n].reshape(st["shape"])
    return jax.tree.map(deq, comp,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x)
