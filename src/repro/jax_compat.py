"""Version gates for jax APIs that moved between the pinned 0.4.37 and
current releases.  Every version-sensitive jax surface the repo touches is
adapted HERE so call sites stay clean and the next pin bump is one audit
of this file.

Gated surfaces (new spelling → 0.4.37 fallback):
  * ``jax.sharding.AxisType`` + ``make_mesh(axis_types=…)`` → plain
    ``jax.make_mesh`` (Auto is the implicit default mode).
  * ``jax.shard_map(check_vma=…)`` → ``jax.experimental.shard_map``
    (kwarg named ``check_rep``).
  * ``Compiled.cost_analysis()`` returns a dict → returns a one-element
    list of dicts.
  * ``jax.tree.flatten_with_path`` → ``jax.tree_util`` spelling (use
    ``jax.tree_util.tree_flatten_with_path`` directly; it exists in every
    supported version).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.38
    from jax.sharding import AxisType
except ImportError:  # 0.4.37
    AxisType = None


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with explicit Auto axis types when supported."""
    kwargs = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes),
                                 **kwargs)
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes, **kwargs)


try:  # jax >= 0.4.38: top-level export, kwarg is check_vma
    _shard_map_new = jax.shard_map
except AttributeError:
    _shard_map_new = None
    from jax.experimental.shard_map import shard_map as _shard_map_old


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    if _shard_map_new is not None:
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every version
    (0.4.37 wraps the per-program dict in a list)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca) if ca else {}


__all__ = ["AxisType", "make_mesh", "shard_map", "cost_analysis_dict"]
