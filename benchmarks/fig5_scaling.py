"""Fig. 5: end-to-end scalability over nested corpus regimes.

Three nested corpus sizes; per regime: structural footprint (directories
vs pages — the 'directories flat, pages linear' separation) and the
first-token/navigation latency profile (Avg/P50/P95/P99) — checking the
sub-linear latency scaling claim of §VI-F.
"""
from __future__ import annotations

import time

import numpy as np

from common import build_wiki, emit, pct

from repro.core.cache import TieredCache
from repro.core.navigate import Navigator, WallClockBudget
from repro.core.oracle import HeuristicOracle
from repro.core.schema import structure_counts


def run(seed: int = 0):
    regimes = {"small": 60, "medium": 120, "full": 240}
    rows = []
    out = {}
    for name, n_docs in regimes.items():
        pipe, docs, questions = build_wiki(
            n_docs=n_docs, n_questions=60, seed=seed)
        cache = TieredCache(pipe.store, bus=pipe.bus)
        cache.prewarm()
        nav = Navigator(pipe.store, HeuristicOracle(), cache=cache)
        lats = []
        for i in range(300):
            q = questions[i % len(questions)]
            t0 = time.perf_counter()
            nav.nav(q.text, WallClockBudget(50.0))
            lats.append((time.perf_counter() - t0) * 1000)
        counts = structure_counts(pipe.store)
        res = {
            "directories": counts["directories"],
            "pages": counts["pages"],
            "documents": counts["documents"],
            "lat_avg": float(np.mean(lats)),
            "lat_p50": pct(lats, 50),
            "lat_p95": pct(lats, 95),
            "lat_p99": pct(lats, 99),
        }
        out[name] = res
        for k, v in res.items():
            rows.append((f"fig5_{name}_{k}", round(v, 3), ""))
    emit(rows, header="Fig 5: scalability across corpus regimes")
    return out


if __name__ == "__main__":
    run()
