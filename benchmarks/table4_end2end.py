"""Table IV: end-to-end answer correctness on (synthetic) AUTHTRACE by
fan-in bucket — LLM-Wiki(WikiKV) vs No-RAG / Dense-RAG / GraphRAG-lite /
RAPTOR-lite.

All baselines share the same generation oracle and answer protocol; only
the retrieval stage differs (exactly the paper's control).  Retrieval
budgets are matched: every method surfaces ≤ K passages.

  No-RAG      — the oracle answers with no evidence.
  Dense-RAG   — flat chunk index, lexical-overlap retrieval (the
                deterministic stand-in for an embedding ANN; same
                structural properties: flat, chunk-level, top-k).
  GraphRAG-lite — entity co-occurrence graph; retrieve the community
                (entity neighborhood) summaries touching query entities.
  RAPTOR-lite — recursive 4-way summary tree over chunks; root-to-leaf
                beam descent by lexical overlap, emitting summaries+leaf.
  LLM-Wiki    — NAV(q,B) over WikiKV (the full system of §V).
"""
from __future__ import annotations

from collections import defaultdict

from common import build_wiki, emit

from repro.core.navigate import Navigator, UnitBudget
from repro.core.oracle import HeuristicOracle, content_tokens
from repro.data.corpus import bucket, score_answer

K = 6          # passages surfaced per query (matched across methods)
BUDGET = 400   # NAV budget units


def _chunks(docs, size=220):
    out = []
    for d in docs:
        t = d["text"]
        for i in range(0, len(t), size):
            out.append(t[i:i + size])
    return out


def retrieve_none(q, docs, state):
    return []


def _lex_top(q, passages, k):
    qt = set(content_tokens(q))
    scored = sorted(
        passages,
        key=lambda p: -len(qt & set(content_tokens(p))) / (len(qt) or 1))
    return scored[:k]


def retrieve_dense(q, docs, state):
    if "chunks" not in state:
        state["chunks"] = _chunks(docs)
    return _lex_top(q, state["chunks"], K)


def retrieve_graph(q, docs, state):
    if "communities" not in state:
        ent_docs = defaultdict(list)
        for d in docs:
            for e in d.get("entities", []):
                ent_docs[e].append(d["text"][:300])
        oracle = HeuristicOracle()
        state["communities"] = {
            e: oracle.summarize(txts, limit=500)
            for e, txts in ent_docs.items()}
    qt = set(content_tokens(q))
    hits = [summ for e, summ in state["communities"].items()
            if set(e.split("_")) & qt or e in q.lower().replace(" ", "_")]
    return (hits + _lex_top(q, list(state["communities"].values()), K))[:K]


def retrieve_raptor(q, docs, state):
    if "tree" not in state:
        oracle = HeuristicOracle()
        level = _chunks(docs)
        tree = [level]
        while len(level) > 4:
            nxt = [oracle.summarize(level[i:i + 4], limit=300)
                   for i in range(0, len(level), 4)]
            tree.append(nxt)
            level = nxt
        state["tree"] = tree
    # beam descent from the root levels, collecting summaries + leaves
    out = []
    for lvl in reversed(state["tree"]):
        out.extend(_lex_top(q, lvl, 2))
        if len(out) >= K:
            break
    return out[:K]


def make_retrieve_wiki(pipe):
    nav = Navigator(pipe.store, HeuristicOracle())

    def retrieve(q, docs, state):
        results, trace = nav.nav(q, UnitBudget(BUDGET))
        state.setdefault("traces", []).append(trace)
        return [r.text for r in results if r.text][:K + 2]
    return retrieve


def run(seed: int = 0, n_docs: int = 160, n_questions: int = 100):
    pipe, docs, questions = build_wiki(n_docs=n_docs,
                                       n_questions=n_questions, seed=seed)
    oracle = HeuristicOracle()
    methods = {
        "no_rag": retrieve_none,
        "dense_rag": retrieve_dense,
        "graphrag": retrieve_graph,
        "raptor": retrieve_raptor,
        "llm_wiki": make_retrieve_wiki(pipe),
    }
    rows = []
    per_method = {}
    for name, retr in methods.items():
        state: dict = {}
        acc = defaultdict(list)
        for q in questions:
            evidence = retr(q.text, docs, state)
            answer = oracle.answer(q.text, evidence)
            acc[bucket(q)].append(score_answer(answer, q))
            acc["overall"].append(score_answer(answer, q))
        res = {b: 100.0 * sum(v) / len(v) for b, v in acc.items()}
        per_method[name] = res
        for b in ("single", "low_multi", "high_multi", "overall"):
            rows.append((f"table4_{name}_{b}", round(res.get(b, 0.0), 1),
                         "AC_percent"))
    emit(rows, header="Table IV: end-to-end AC by fan-in bucket")
    return per_method


if __name__ == "__main__":
    run()
