"""Shared benchmark harness utilities."""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.oracle import HeuristicOracle  # noqa: E402
from repro.core.pipeline import ConstructionPipeline, PipelineConfig  # noqa: E402
from repro.data.corpus import AuthTraceConfig, generate_authtrace  # noqa: E402
from repro.obs.metrics import Histogram  # noqa: E402

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts"


def pct(samples, q: float) -> float:
    """Percentile ``q`` of ``samples`` through the SHARED log-bucket
    histogram (``repro.obs.metrics.Histogram``) — every table reports the
    same percentile logic ``ServingEngine.stats_snapshot()`` uses, so a
    benchmark p99 and a serving p99 over identical samples are identical
    by construction (ISSUE 8)."""
    return Histogram(samples).percentile(q)


def latency_summary(samples) -> dict:
    """Fixed-schema p50/p90/p99/max summary of ``samples`` (same rows as
    the snapshot's ``latency_ms`` entries)."""
    return Histogram(samples).summary()


def timeit_median(fn, n_iters: int = 200, warmup: int = 50) -> float:
    """Median (histogram p50) wall-clock per call, in ms (paper protocol:
    median over repeated runs after warmup)."""
    for _ in range(warmup):
        fn()
    h = Histogram()
    for _ in range(n_iters):
        t0 = time.perf_counter()
        fn()
        h.record((time.perf_counter() - t0) * 1000.0)
    return h.percentile(50)


def build_wiki(n_docs=120, n_questions=60, seed=0, cfg: PipelineConfig | None = None,
               oracle=None):
    docs, questions = generate_authtrace(
        AuthTraceConfig(n_docs=n_docs, n_questions=n_questions, seed=seed))
    pipe = ConstructionPipeline(cfg or PipelineConfig(),
                                oracle or HeuristicOracle())
    pipe.bootstrap(docs)
    for i in range(0, len(docs), 16):
        pipe.ingest(docs[i:i + 16])
    return pipe, docs, questions


def emit(rows: list[tuple], header: str | None = None):
    """Print ``name,us_per_call,derived`` CSV rows (harness contract)."""
    if header:
        print(f"# {header}")
    for row in rows:
        print(",".join(str(x) for x in row))
