"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus # section headers).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table4     # one table
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import table2_backends  # noqa: E402
import table3_schema  # noqa: E402
import table4_end2end  # noqa: E402
import table5_online  # noqa: E402
import table6_ablation  # noqa: E402
import fig5_scaling  # noqa: E402
import errorbook_bench  # noqa: E402
import roofline_report  # noqa: E402

ALL = {
    "table2": lambda: table2_backends.run(),
    "table3": lambda: table3_schema.run(),
    "table4": lambda: table4_end2end.run(),
    "table5": lambda: table5_online.run(),
    "table6": lambda: table6_ablation.run(),
    "fig5": lambda: fig5_scaling.run(),
    "errorbook": lambda: errorbook_bench.run(),
    "roofline": lambda: roofline_report.run(),
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    for name in which:
        if name not in ALL:
            print(f"unknown benchmark {name!r}; have {sorted(ALL)}")
            continue
        print(f"\n##### {name} #####")
        ALL[name]()


if __name__ == "__main__":
    main()
