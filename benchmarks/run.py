"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus # section headers).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table4     # one table
    PYTHONPATH=src python -m benchmarks.run --smoke    # cheap CI subset
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import table2_backends  # noqa: E402
import table3_schema  # noqa: E402
import table4_end2end  # noqa: E402
import table5_online  # noqa: E402
import table6_ablation  # noqa: E402
import fig5_scaling  # noqa: E402
import errorbook_bench  # noqa: E402
import roofline_report  # noqa: E402

ALL = {
    "table2": lambda: table2_backends.run(),
    "table3": lambda: table3_schema.run(),
    "table4": lambda: table4_end2end.run(),
    "table5": lambda: table5_online.run(),
    "table6": lambda: table6_ablation.run(),
    "fig5": lambda: fig5_scaling.run(),
    "errorbook": lambda: errorbook_bench.run(),
    "roofline": lambda: roofline_report.run(),
}


#: reduced-size runs for CI (scripts/smoke.sh): exercises the engine
#: layer end-to-end — backend sweep incl. host-sharded + device engines,
#: and the batched online path — in well under a minute
SMOKE = {
    "table2": lambda: table2_backends.run(n_iters=50, warmup=10),
    "table5": lambda: table5_online.run(n_queries=128),
}


def main() -> None:
    args = sys.argv[1:]
    if args and args[0] == "--smoke":
        for name, fn in SMOKE.items():
            print(f"\n##### {name} (smoke) #####")
            fn()
        return
    which = args or list(ALL)
    for name in which:
        if name not in ALL:
            print(f"unknown benchmark {name!r}; have {sorted(ALL)}")
            continue
        print(f"\n##### {name} #####")
        ALL[name]()


if __name__ == "__main__":
    main()
