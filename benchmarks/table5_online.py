"""Table V: online latency + tool-call distribution on a live query mix.

The production study's system-side metrics, reproduced on the serving
stack: 1,000 queries sampled from the question log (with paraphrase
noise), full online path router → navigation → (oracle) generation.

Navigation runs through the batched QueryEngine (core/engine.py): the
query mix is served in WAVES of concurrent sessions whose Q1–Q4
operations are continuously batched by the BatchPlanner — one engine
call per operator per round.  Reports Avg/P50/P95/P99 of wiki tool
calls, amortized wiki tool latency, and end-to-end latency, a 3-level
quality proxy (3 = pack-exact, 2 = partial shard coverage, 1 = no shard
surfaced), plus the engine amortization evidence: round trips, logical
ops, and the largest Q1 batch a single engine call executed (the ISSUE 1
acceptance floor is ≥ 64).  A second pass reports the DeviceEngine
(Pallas Q1/Q4 path) on the same mix.
"""
from __future__ import annotations

import random
import time

import numpy as np

from common import build_wiki, emit, pct

from repro.core import records as R
from repro.core import tensorstore as TS
from repro.core.cache import TieredCache
from repro.core.consistency import WikiWriter
from repro.core.engine import (BatchPlanner, DeviceEngine, HostEngine,
                               ShardedPathStore)
from repro.core.navigate import Navigator, UnitBudget
from repro.core.oracle import HeuristicOracle
from repro.core.store import MemKV, PathStore
from repro.data.corpus import score_answer

WAVE = 256  # concurrent navigation sessions per planner wave


def _pct(xs, p):
    # the shared log-bucket histogram (repro.obs.metrics) — same
    # percentile logic as ServingEngine.stats_snapshot()
    return pct(list(xs), p)


def _sharded_copy(store) -> ShardedPathStore:
    """Re-shard the pipeline store by digest range (4 shards)."""
    sh = ShardedPathStore(n_shards=4)
    for p in store.all_paths():
        rec = store.get(p)
        if rec is not None:
            sh.put_record(p, rec)
    sh.flush()
    return sh


def _run_engine(tag: str, engine, store, bus, questions, rng,
                n_queries: int) -> list[tuple]:
    cache = TieredCache(store, bus=bus)
    cache.prewarm()
    nav = Navigator(engine, HeuristicOracle(), cache=cache)
    oracle = HeuristicOracle()
    texts, qobjs = [], []
    for i in range(n_queries):
        q = questions[rng.randrange(len(questions))]
        texts.append(q.text if i % 3 else ("tell me, " + q.text.lower()))
        qobjs.append(q)

    tool_calls, tool_lat, e2e_lat, quality = [], [], [], []
    for w0 in range(0, n_queries, WAVE):
        wave = texts[w0:w0 + WAVE]
        t0 = time.perf_counter()
        outs = nav.nav_many(wave, [UnitBudget(400) for _ in wave])
        t1 = time.perf_counter()
        wave_ms = (t1 - t0) * 1000
        # a session completes after trace.rounds planner rounds; its wall
        # latency under continuous batching is that fraction of the wave,
        # so the percentile rows reflect real per-query variation (deep
        # NEEDSDEEPER chains stay live for more rounds)
        max_rounds = max((t.rounds for _, t in outs), default=1) or 1
        for (results, trace), text, qobj in zip(outs, wave,
                                                qobjs[w0:w0 + WAVE]):
            per_q_nav = wave_ms * trace.rounds / max_rounds
            ta = time.perf_counter()
            # answer from the same (possibly paraphrased) text that drove
            # navigation — the protocol's paraphrase noise stays in scoring
            answer = oracle.answer(text, [r.text for r in results])
            tb = time.perf_counter()
            tool_calls.append(trace.tool_calls)
            tool_lat.append(per_q_nav)
            e2e_lat.append(per_q_nav + (tb - ta) * 1000)
            if score_answer(answer, qobj) == 1.0:
                quality.append(3)
            elif any(s.lower() in answer.lower() for s in qobj.answer_shards):
                quality.append(2)
            else:
                quality.append(1)

    rows = []
    for name, xs, unit in (("tool_calls", tool_calls, "count"),
                           ("tool_latency", tool_lat, "ms"),
                           ("e2e_latency", e2e_lat, "ms")):
        rows.append((f"table5_{tag}_{name}_avg",
                     round(float(np.mean(xs)), 3), unit))
        for p in (50, 95, 99):
            rows.append((f"table5_{tag}_{name}_p{p}",
                         round(_pct(xs, p), 3), unit))
    rows.append((f"table5_{tag}_quality_mean",
                 round(float(np.mean(quality)), 3), "rating_1_3"))
    rows.append((f"table5_{tag}_cache_hit_rate",
                 round(cache.stats.hit_rate(), 3), "fraction"))
    # engine amortization: the batched-Q1 acceptance evidence.
    # "served" = logical lookups resolved by one engine call (concurrent
    # sessions' identical ops share a batch slot); "keys" = unique keys
    # the call actually executed.
    st = engine.stats
    rows.append((f"table5_{tag}_engine_round_trips", st.total_calls(),
                 f"count;ops={st.total_ops()}"))
    rows.append((f"table5_{tag}_engine_q1_max_lookups_per_call",
                 st.max_served.get("q1_get", 0),
                 f"lookups;unique_keys_max={st.max_batch.get('q1_get', 0)}"))
    q1_calls = st.calls.get("q1_get", 1)
    rows.append((f"table5_{tag}_engine_q1_avg_lookups_per_call",
                 round(st.served.get("q1_get", 0) / max(q1_calls, 1), 2),
                 f"lookups;unique_keys_avg="
                 f"{round(st.ops.get('q1_get', 0) / max(q1_calls, 1), 2)}"))
    return rows


def _run_mixed(tag: str, engine, questions, rng, n_queries: int) -> list[tuple]:
    """ISSUE 2 mixed read/write workload: every wave carries WAVE
    concurrent navigation sessions PLUS one batch of admissions/unlinks
    riding the same planner flush.  Reports write amortization (admits
    served per w_admit engine call), epoch-lag percentiles (waves between
    a write's admission and its read visibility — the Δ = 1 wave bound)
    and previous-wave write visibility (must be 1.0)."""
    nav = Navigator(engine, HeuristicOracle())
    wave_n = min(WAVE, max(64, n_queries // 4))
    n_waves = max(2, n_queries // wave_n)
    writes_per_wave = max(2, wave_n // 4)
    epoch_lags, wave_ms = [], []
    visible = checked = 0
    prev_paths: list[str] = []
    w_seq = 0
    for w in range(n_waves):
        texts = [questions[rng.randrange(len(questions))].text
                 for _ in range(wave_n)]
        # this wave's write batch: admissions + an unlink of an old row
        batch = []
        for _ in range(writes_per_wave):
            path = f"/online/w{w_seq % 8}/rec_{w_seq}"
            batch.append((path, R.FileRecord(
                name=f"rec_{w_seq}", text=f"online record {w_seq}")))
            w_seq += 1
        for p, rec in batch:
            nav.planner.admit(p, rec)
        if prev_paths:
            nav.planner.unlink(prev_paths[0])
        pinned = engine.epoch
        t0 = time.perf_counter()
        nav.nav_many(texts, [UnitBudget(400) for _ in texts])
        wave_ms.append((time.perf_counter() - t0) * 1000)
        # run_sessions refreshed at wave end: lag = epochs the wave's
        # pinned snapshot ended up behind the committed tip
        epoch_lags.append(engine.epoch - pinned)
        # writes of wave w-1 must be visible to wave w reads (Δ = 1)
        if prev_paths:
            got = engine.q1_get(prev_paths[1:])
            checked += len(prev_paths) - 1
            visible += sum(1 for r in got if r is not None)
        prev_paths = [p for p, _ in batch]
    st_ = engine.stats
    admit_calls = max(st_.calls.get("w_admit", 0), 1)
    rows = [
        (f"table5_mixed_{tag}_waves", n_waves,
         f"count;wave={wave_n};writes_per_wave={writes_per_wave}"),
        (f"table5_mixed_{tag}_wave_latency_avg",
         round(float(np.mean(wave_ms)), 3), "ms"),
        (f"table5_mixed_{tag}_write_amortization",
         round(st_.served.get("w_admit", 0) / admit_calls, 2),
         "admits_per_engine_call"),
        (f"table5_mixed_{tag}_epoch_lag_p50",
         round(_pct(epoch_lags, 50), 3), "waves"),
        (f"table5_mixed_{tag}_epoch_lag_p95",
         round(_pct(epoch_lags, 95), 3), "waves"),
        (f"table5_mixed_{tag}_epoch_lag_max",
         int(max(epoch_lags)), "waves"),
        (f"table5_mixed_{tag}_prev_wave_visibility",
         round(visible / max(checked, 1), 3), "fraction"),
    ]
    if "refresh" in st_.ops:
        rows.append((f"table5_mixed_{tag}_refresh_rows",
                     st_.ops["refresh"],
                     f"rows;refreshes={st_.calls['refresh']}"))
    for kind in ("patch", "rebuild"):
        k = f"refresh_{kind}"
        if k in st_.calls:
            rows.append((f"table5_mixed_{tag}_{k}", st_.calls[k], "count"))
    return rows


# ---------------------------------------------------------------------------
# ISSUE 6: per-epoch refresh latency — in-place patch vs full rebuild
# ---------------------------------------------------------------------------
def _build_table(n_rows: int, dims: int | None = None):
    """Synthetic (paths, records) table of ~n_rows rows: root + ``dims``
    dimension dirs + files spread across them.  Directory fan-out is held
    ~constant (64) across table sizes — the wiki grows by adding
    dimensions, not by growing one directory without bound — so the
    refresh-scaling benchmark isolates the patch mechanism's cost from
    the cost of re-listing ever-larger touched directories."""
    if dims is None:
        dims = max(8, n_rows // 64)
    files: dict[int, list[str]] = {d: [] for d in range(dims)}
    paths = ["/"]
    recs: list = [R.DirRecord(name="root",
                              sub_dirs=[f"dim{d}" for d in range(dims)])]
    for i in range(max(0, n_rows - 1 - dims)):
        d = i % dims
        files[d].append(f"f{i}")
        paths.append(f"/dim{d}/f{i}")
        recs.append(R.FileRecord(name=f"f{i}", text=f"row {i}"))
    for d in range(dims):
        paths.append(f"/dim{d}")
        recs.append(R.DirRecord(name=f"dim{d}", files=list(files[d])))
    return paths, recs, files


def _refresh_epochs(wiki, recs, files, n_delta: int, epochs: int, mode: str):
    """Apply ``epochs`` deltas of |Δ| = n_delta file admissions (plus the
    touched parent-dir upserts) in the given mode; per-epoch wall ms."""
    times, kinds = [], []
    dims = len(files)
    seq = sum(len(v) for v in files.values()) + 10**6  # fresh names
    for e in range(epochs):
        per_dim: dict[int, list[str]] = {}
        ups = []
        for _ in range(n_delta):
            # groups of 8 files share a directory: the delta touches a
            # bounded set of parents (write locality), so the measured
            # curve is the patch mechanism, not parent re-listing
            d = (seq // 8) % dims
            name = f"g{seq}"
            per_dim.setdefault(d, []).append(name)
            ups.append((f"/dim{d}/{name}",
                        R.FileRecord(name=name, text=f"new {seq}")))
            seq += 1
        for d, names in per_dim.items():
            files[d].extend(names)
            ups.append((f"/dim{d}",
                        R.DirRecord(name=f"dim{d}", files=list(files[d]))))
        delta = TS.TensorDelta(epoch=e + 1, upserts=ups)
        t0 = time.perf_counter()
        wiki, recs, info = TS.apply_delta_ex(wiki, recs, delta, mode=mode)
        times.append((time.perf_counter() - t0) * 1000)
        kinds.append(info.kind)
    return times, kinds


def _run_refresh_scaling(n_delta: int = 64, epochs: int = 3) -> list[tuple]:
    """The perf_opt acceptance curve: p50 per-epoch refresh at fixed
    |Δ| = n_delta across store sizes.  The in-place patch path must stay
    flat (within 2× from 1k to 16k rows) while the full rebuild scales
    with the table — measured on the same delta sequence, both modes."""
    rows = []
    patch_p50: dict[int, float] = {}
    for n in (1024, 4096, 16384):
        paths, recs, files = _build_table(n)
        wiki_p, recs_p = TS._materialize(list(paths), list(recs))
        t_patch, kinds = _refresh_epochs(
            wiki_p, recs_p, {d: list(v) for d, v in files.items()},
            n_delta, epochs, "patch")
        assert all(k == "patch" for k in kinds), kinds
        wiki_r, recs_r = TS._materialize(list(paths), list(recs))
        t_rebuild, _ = _refresh_epochs(
            wiki_r, recs_r, {d: list(v) for d, v in files.items()},
            n_delta, epochs, "rebuild")
        p_p50, r_p50 = _pct(t_patch, 50), _pct(t_rebuild, 50)
        patch_p50[n] = p_p50
        rows.append((f"table5_refresh_patch_p50_n{n}", round(p_p50, 3),
                     f"ms;delta={n_delta};epochs={epochs}"))
        rows.append((f"table5_refresh_rebuild_p50_n{n}", round(r_p50, 3),
                     f"ms;delta={n_delta};epochs={epochs}"))
        rows.append((f"table5_refresh_patch_speedup_n{n}",
                     round(r_p50 / max(p_p50, 1e-9), 2), "x_vs_rebuild"))
    flat = patch_p50[16384] / max(patch_p50[1024], 1e-9)
    rows.append(("table5_refresh_patch_flatness_16k_vs_1k",
                 round(flat, 2), "x;acceptance<=2"))
    return rows


# ---------------------------------------------------------------------------
# ISSUE 10: serial vs parallel shard fan-out (report-only this PR)
# ---------------------------------------------------------------------------
class _SleepyKV(MemKV):
    """MemKV with a fixed per-get latency injection.  ``time.sleep``
    releases the GIL, which is exactly the point: a real shard tier
    waits on IO (mmap faults, page cache, eventually sockets), and the
    fan-out win is overlap of that WAIT — pure-Python compute cannot
    overlap under the GIL, so the no-injection rows are reported
    alongside as the honest in-process reference."""

    def __init__(self, delay_s: float, **kw):
        super().__init__(**kw)
        self._delay = delay_s

    def get(self, key):
        if self._delay:
            time.sleep(self._delay)
        return super().get(key)


def _run_fanout(n_shards: int = 8, wave: int = 256,
                delay_us: float = 50.0, reps: int = 5) -> list[tuple]:
    """Batched Q1 p50 per wave, serial loops vs the shard-executor pool
    (8 shards, wave of 256): the same store content, the same per-get
    latency injection, only ``shard_workers`` differs.  Acceptance
    target (report-only this PR): parallel >= 2x serial on the
    latency-injected rows."""
    rng = random.Random(7)
    rows: list[tuple] = []
    speedups = {}
    for label, delay in (("", delay_us * 1e-6), ("_noinject", 0.0)):
        stores = {}
        for workers in (0, n_shards):
            store = ShardedPathStore(
                engines=[_SleepyKV(delay) for _ in range(n_shards)],
                shard_workers=workers)
            w = WikiWriter(store, clock=lambda: 0.0)
            w.ensure_root("root")
            for d in range(8):
                w.admit(f"/d{d}", R.DirRecord(name=f"d{d}"))
                for e in range(64):
                    w.admit(f"/d{d}/e{e}",
                            R.FileRecord(name=f"e{e}", text=f"{d}:{e}"))
            stores[workers] = store
        live = stores[0].all_paths()
        batch = [live[rng.randrange(len(live))] for _ in range(wave)]
        p50 = {}
        for workers, store in stores.items():
            he = HostEngine(store)
            he.q1_get(batch[:16])                     # warm the pool
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                he.q1_get(batch)
                times.append((time.perf_counter() - t0) * 1000)
            p50[workers] = _pct(times, 50)
        speedups[label] = p50[0] / max(p50[n_shards], 1e-9)
        tag = f"ms;shards={n_shards};wave={wave}" + \
            (f";delay={delay_us}us_per_get" if delay else ";no_injection")
        rows.append((f"table5_fanout_serial_q1_p50{label}",
                     round(p50[0], 3), tag))
        rows.append((f"table5_fanout_parallel_q1_p50{label}",
                     round(p50[n_shards], 3), tag))
    rows.append(("table5_fanout_parallel_speedup",
                 round(speedups[""], 2),
                 "x;accept>=2;report_only_soak;latency_injected"))
    rows.append(("table5_fanout_parallel_speedup_noinject",
                 round(speedups["_noinject"], 2),
                 "x;gil_bound_reference"))
    return rows


def _run_cadence(cadence: int = 4, n_waves: int = 16) -> list[tuple]:
    """Refresh batching: with refresh_cadence=k, per-write visibility lag
    is bounded by k waves and refresh commits drop to n_waves/k."""
    store = PathStore(MemKV())
    w = WikiWriter(store, clock=lambda: 0.0)
    w.ensure_root("root")
    for d in range(4):
        w.admit(f"/d{d}", R.DirRecord(name=f"d{d}"))
    dev = DeviceEngine.from_store(store, refresh_cadence=cadence)
    pl = BatchPlanner(dev)
    pending: list[tuple[str, int]] = []
    lags: list[int] = []
    for wv in range(n_waves):
        path = f"/d{wv % 4}/w{wv}"
        pl.admit(path, R.FileRecord(name=f"w{wv}", text="x"))
        pl.flush()
        dev.refresh()
        pending.append((path, wv))
        still = []
        for p, w0 in pending:
            if dev.q1_get([p])[0] is not None:
                lags.append(wv - w0 + 1)
            else:
                still.append((p, w0))
        pending = still
    return [
        ("table5_cadence_refresh_cadence", cadence, "waves"),
        ("table5_cadence_visibility_lag_p50",
         round(_pct(lags, 50), 2), "waves"),
        ("table5_cadence_visibility_lag_max", int(max(lags)),
         f"waves;acceptance<={cadence}"),
        ("table5_cadence_refresh_commits",
         dev.stats.calls.get("refresh", 0), f"count;waves={n_waves}"),
    ]


def run(seed: int = 0, n_queries: int = 1000):
    pipe, docs, questions = build_wiki(n_docs=160, n_questions=100,
                                       seed=seed)
    rows = []
    # host engine over the digest-range sharded store (4 shards)
    sharded = _sharded_copy(pipe.store)
    rows += _run_engine("host", HostEngine(sharded), sharded, None,
                        questions, random.Random(seed), n_queries)
    # device engine frozen from the same store (Pallas Q1/Q4 on TPU)
    dev = DeviceEngine.from_store(pipe.store)
    rows += _run_engine("device", dev, pipe.store, pipe.bus,
                        questions, random.Random(seed), n_queries)
    # mixed read/write workload: online admissions at wave cadence
    # (fresh engines so read-only and mixed stats don't blend)
    rows += _run_mixed("host", HostEngine(sharded), questions,
                       random.Random(seed + 1), n_queries)
    rows += _run_mixed("device", DeviceEngine.from_store(pipe.store),
                       questions, random.Random(seed + 1), n_queries)
    # ISSUE 6: refresh-latency scaling (patch vs rebuild at fixed |Δ|)
    # and refresh-cadence staleness
    rows += _run_refresh_scaling()
    rows += _run_fanout()
    rows += _run_cadence()
    emit(rows, header="Table V: online latency + quality on "
                      f"{n_queries} queries (waves of {WAVE})")
    return rows


if __name__ == "__main__":
    run()
