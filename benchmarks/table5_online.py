"""Table V: online latency + tool-call distribution on a live query mix.

The production study's system-side metrics, reproduced on the serving
stack: 1,000 queries sampled from the question log (with paraphrase
noise), full online path router → navigation → (oracle) generation.
Reports Avg/P50/P95/P99 of wiki tool calls, wiki tool latency, and
end-to-end latency, plus a 3-level quality proxy (3 = pack-exact,
2 = partial shard coverage, 1 = no shard surfaced) standing in for the
human rubric.
"""
from __future__ import annotations

import random
import time

import numpy as np

from common import build_wiki, emit

from repro.core.cache import TieredCache
from repro.core.navigate import Navigator, WallClockBudget
from repro.core.oracle import HeuristicOracle
from repro.data.corpus import score_answer


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p))


def run(seed: int = 0, n_queries: int = 1000):
    pipe, docs, questions = build_wiki(n_docs=160, n_questions=100,
                                       seed=seed)
    cache = TieredCache(pipe.store, bus=pipe.bus)
    cache.prewarm()
    nav = Navigator(pipe.store, HeuristicOracle(), cache=cache)
    oracle = HeuristicOracle()
    rng = random.Random(seed)
    tool_calls, tool_lat, e2e_lat, quality = [], [], [], []
    for i in range(n_queries):
        q = questions[rng.randrange(len(questions))]
        text = q.text if i % 3 else ("tell me, " + q.text.lower())
        t0 = time.perf_counter()
        results, trace = nav.nav(text, WallClockBudget(50.0))
        t1 = time.perf_counter()
        answer = oracle.answer(text, [r.text for r in results])
        t2 = time.perf_counter()
        tool_calls.append(trace.tool_calls)
        tool_lat.append((t1 - t0) * 1000)
        e2e_lat.append((t2 - t0) * 1000)
        if score_answer(answer, q) == 1.0:
            quality.append(3)
        elif any(s.lower() in answer.lower() for s in q.answer_shards):
            quality.append(2)
        else:
            quality.append(1)
    rows = []
    for name, xs, unit in (("tool_calls", tool_calls, "count"),
                           ("tool_latency", tool_lat, "ms"),
                           ("e2e_latency", e2e_lat, "ms")):
        rows.append((f"table5_{name}_avg", round(float(np.mean(xs)), 3), unit))
        for p in (50, 95, 99):
            rows.append((f"table5_{name}_p{p}", round(_pct(xs, p), 3), unit))
    rows.append(("table5_quality_mean", round(float(np.mean(quality)), 3),
                 "rating_1_3"))
    rows.append(("table5_cache_hit_rate", round(cache.stats.hit_rate(), 3),
                 "fraction"))
    emit(rows, header="Table V: online latency + quality on 1000 queries")
    return rows


if __name__ == "__main__":
    run()
