"""Error Book effectiveness (paper §III-D): the two-layer repair loop +
persisted constraint rules reduce both new and pre-existing errors across
ingestion batches.

Protocol: ingest a corpus whose docs deliberately carry error patterns
(dangling links injected post-hoc, contradictory facts, uncited facts) in
three batches; after each batch record the detector's error count with the
Error Book enabled (constraints persist, repairs run) vs a control where
the book state is wiped between batches.  Claim reproduced iff the
enabled run's error counts decline across batches and end below control.
"""
from __future__ import annotations

from dataclasses import replace

from common import emit

from repro.core import paths as P
from repro.core import records as R
from repro.core.consistency import WikiWriter
from repro.core.errorbook import ERRORBOOK_PATH, ErrorBook, detect_errors, run_errorbook
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import ConstructionPipeline, PipelineConfig
from repro.data.corpus import AuthTraceConfig, generate_authtrace


def _inject_errors(pipe, batch_no: int) -> None:
    """Post-ingestion corruption: the upstream 'LLM writer' misbehaving."""
    w = pipe.writer
    store = pipe.store
    ents = [p for p in store.all_paths()
            if P.node_type(p) == P.NODE_ENTITY and not P.is_reserved(p)][:6]
    for i, ep in enumerate(ents):
        rec = store.get(ep)
        if not isinstance(rec, R.FileRecord):
            continue
        bad_link = f"[[/sources/digests/missing_{batch_no}_{i}]]"
        extra = f"\nfact: shared_{i}={1900 + batch_no}" if i < 3 else ""
        store.put_record(ep, replace(
            rec,
            text=rec.text + f"\n{bad_link}{extra}",
            meta=replace(rec.meta,
                         sources=rec.meta.sources + [f"http://bad{i}"])))


def run(seed: int = 5, n_docs: int = 90):
    docs, _ = generate_authtrace(AuthTraceConfig(n_docs=n_docs, seed=seed))
    rows = []
    for mode in ("with_book", "no_repair"):
        pipe = ConstructionPipeline(PipelineConfig(), HeuristicOracle())
        pipe.bootstrap(docs)
        counts, rules = [], []
        for b in range(3):
            lo, hi = b * n_docs // 3, (b + 1) * n_docs // 3
            pipe.ingest(docs[lo:hi])
            _inject_errors(pipe, b)
            if mode == "with_book":
                book, _ = run_errorbook(pipe.writer, pipe.oracle,
                                        with_llm_pass=True)
                rules.append(len(book.rules))
            residual = detect_errors(pipe.store, ErrorBook()).total
            counts.append(residual)
        for b, c in enumerate(counts):
            rows.append((f"errorbook_{mode}_batch{b}", c, "residual_errors"))
        if rules:
            rows.append(("errorbook_rules_accumulated", rules[-1],
                         "constraint_rules"))
    emit(rows, header="Error Book: residual errors per batch "
                      "(repair loop on vs detection only)")
    return rows


if __name__ == "__main__":
    run()
