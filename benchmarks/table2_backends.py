"""Table II: median per-operator latency (Q1–Q4) × storage backend.

Reproduces the paper's protocol: a MEDIUM wiki (~2,000 KV pairs), 100
random target paths/prefixes per operator, 1,000 queries per backend
after a 200-query warmup, medians reported.  Backends: the WikiKV
path-as-key layout on the MemKV LSM engine (our method, now served
through the unified QueryEngine), its digest-range sharded variant
(``wikikv_sharded``), the device engine over the frozen tensor index
(``wikikv_device`` — Pallas Q1/Q4 on TPU, jnp reference elsewhere), the
durable WAL+SSTable tier (``wikikv_durable`` — reads served from real
mmap'd segment files; honors ``REPRO_WAL_SYNC``), FS, SQL (sqlite ≈
PostgreSQL+ltree) and a property-graph store (≈ Neo4j) — all in-process,
so the comparison isolates the storage model exactly as §VI-B argues.

The amortization section reports the engines' *batched* Q1/Q4 (one
engine call for 256 lookups / a whole prefix batch) — the serving-tier
execution shape (core/engine.py).

The ``wikikv_durable_cold`` section (ISSUE 7) measures the leveled
durable tier with the memtable dropped — every lookup hits segment
files — comparing Q1 hit/miss p50 with per-segment bloom filters and
the shared block cache ON (defaults) vs OFF (``bloom_bits=0``,
``block_cache_bytes=0``, the PR-3 read path) over a tree holding at
least 3 levels and 8 segments.
"""
from __future__ import annotations

import random
import shutil
import tempfile

from common import build_wiki, emit, timeit_median

from repro import obs
from repro.core import paths as P
from repro.core import records as R
from repro.core.backends import ALL_BACKENDS


def collect_items(pipe):
    items = []
    for path in pipe.store.all_paths():
        if P.is_prefix(P.META_PREFIX, path):
            continue
        rec = pipe.store.get(path)
        if rec is not None:
            items.append((path, rec))
    return items


MIN_LEVELS = 3        # acceptance shape for the cold-store comparison
MIN_SEGMENTS = 8
# small enough that the byte-capacity triggers build a ≥3-level tree of
# multi-partition levels out of a MEDIUM wiki; large enough that the
# shape (segment count) stays in the baseline's regime
SEGMENT_TARGET = 16384


def _build_cold_store(items, root: str, bloom_bits: int,
                      block_cache_bytes: int):
    """Ingest ``items`` into a single-shard leveled store, spilling every
    few records, and top up with filler spills until the tree holds at
    least MIN_LEVELS levels / MIN_SEGMENTS segments; then force the
    memtable out so every read is served from segment files."""
    from repro.storage import open_durable_store
    store = open_durable_store(root, n_shards=1, memtable_limit=32,
                               sync="none", level_ratio=4,
                               bloom_bits=bloom_bits,
                               block_cache_bytes=block_cache_bytes,
                               segment_target_bytes=SEGMENT_TARGET)
    for i, (p, rec) in enumerate(items):
        store.put_record(p, rec)
        if i % 8 == 7:
            store.flush()
    eng = store.engine
    filler = 0
    # the size-ratio cascade leaves (spills mod ratio) residuals per
    # level, so one more spill per iteration always reaches the target
    # shape within one full cycle (< ratio^MIN_LEVELS extra spills)
    while (len(eng.level_counts()) < MIN_LEVELS
           or sum(eng.level_counts().values()) < MIN_SEGMENTS):
        for _ in range(8):
            store.put_record(f"/fill/f{filler}",
                             R.FileRecord(name=f"f{filler}", text="pad"))
            filler += 1
        store.flush()
        if filler > 4096:
            raise RuntimeError(f"cold store never reached shape: "
                               f"{eng.level_counts()}")
    eng.spill()                      # drop the memtable: truly cold reads
    assert not eng._mem
    return store


def durable_cold_rows(items, rng, n_iters: int, warmup: int):
    """Q1 hit/miss p50 over the cold leveled store, three variants:
    the full read path (blooms + block cache + partitioned levels), the
    PR-3/PR-5 flat path (``_nofilter``: no filters, no cache, probe
    every segment of every level newest-first), and ``_part_nofilter``
    (no filters/cache but partitioned binary search, report-only this
    PR) — so the ISSUE 7 bloom/cache speedup and the ISSUE 9
    partitioning speedup are isolated from each other on identical
    segment files.

    Measured at the engine key level (the ``d:<digest>`` point lookup a
    Q1 bottoms out in) so the comparison isolates the storage tier —
    path normalization and digest hashing cost the same in both
    variants and would only dilute the ratio."""
    from repro.core.store import PathStore as PS
    paths = [p for p, _ in items]
    hits = [PS.data_key(rng.choice(paths)) for _ in range(100)]
    misses = [PS.data_key(f"/zz/absent_{i * 131}") for i in range(100)]
    rows, p50 = [], {}
    shape = None
    for label, bloom_bits, cache_bytes, flat in (
            ("", None, None, False),
            ("_nofilter", 0, 0, True),
            ("_part_nofilter", 0, 0, False)):
        root = tempfile.mkdtemp(prefix="wikikv_cold_")
        try:
            store = _build_cold_store(items, root, bloom_bits, cache_bytes)
            eng = store.engine
            eng.set_flat_reads(flat)
            levels = eng.level_counts()
            shape = shape or (len(levels), sum(levels.values()))

            # the op under test is ~10us, so iterations are nearly free:
            # floor the count and take the best of 3 medians to shrug off
            # CPU-frequency dips that would swamp a single smoke median
            n = max(n_iters, 300)

            def best_median(fn):
                return min(timeit_median(fn, n, max(warmup, 50))
                           for _ in range(3))

            it = iter(range(10**9))
            q1h = best_median(lambda: eng.get(hits[next(it) % 100]))
            it = iter(range(10**9))
            q1m = best_median(lambda: eng.get(misses[next(it) % 100]))
            p50[f"hit{label}"], p50[f"miss{label}"] = q1h, q1m
            counts = eng.op_counts()
            derived = (f"us;levels={len(levels)};"
                       f"segments={sum(levels.values())};"
                       f"bloom_neg={counts.get('bloom_neg', 0)};"
                       f"cache_hit={counts.get('cache_hit', 0)};"
                       f"seg_probe={counts.get('seg_probe', 0)}")
            rows.append((f"table2_wikikv_durable_cold{label}_q1_hit",
                         round(q1h * 1000, 2), derived))
            rows.append((f"table2_wikikv_durable_cold{label}_q1_miss",
                         round(q1m * 1000, 2), derived))
            store.close()
        finally:
            shutil.rmtree(root, ignore_errors=True)
    rows.append(("table2_wikikv_durable_cold_miss_speedup",
                 round(p50["miss_nofilter"] / p50["miss"], 2),
                 f"x;accept>=5;levels={shape[0]};segments={shape[1]}"))
    rows.append(("table2_wikikv_durable_cold_hit_speedup",
                 round(p50["hit_nofilter"] / p50["hit"], 2), "x"))
    # ISSUE 9 acceptance: partitioned binary search vs flat probe-all on
    # the SAME filterless files — the pure partitioning win (>= 1.5x)
    rows.append(("table2_wikikv_durable_cold_part_speedup",
                 round(p50["miss_nofilter"] / p50["miss_part_nofilter"], 2),
                 "x;accept>=1.5;report_only_soak"))
    return rows


def trace_overhead_rows(items, targets, n_iters: int, warmup: int):
    """Traced-vs-untraced Q1 p50 on the wikikv engine backend — the
    ISSUE 8 report-only soak metric: the span cost a user pays for
    turning ``REPRO_TRACE=1`` on (ratio ~1.x; the =0 path must be free
    and is what the gated rows run under)."""
    was = obs.enabled()
    be = ALL_BACKENDS["wikikv"]()
    try:
        be.load(items)
        it = iter(range(10**9))
        q1 = lambda: be.q1_get(targets[next(it) % 100])  # noqa: E731
        n = max(n_iters, 300)
        obs.configure(enabled=False)
        off = min(timeit_median(q1, n, max(warmup, 50)) for _ in range(3))
        obs.configure(enabled=True)
        on = min(timeit_median(q1, n, max(warmup, 50)) for _ in range(3))
    finally:
        be.close()
        obs.configure(enabled=was)
    return [("table2_trace_overhead_q1", round(on / off, 3),
             f"x;off={round(off * 1000, 2)}us;on={round(on * 1000, 2)}us")]


def pipeline_commit_rows(n_waves: int = 24, wave_writes: int = 32,
                         n_shards: int = 8) -> list[tuple]:
    """ISSUE 10 (report-only this PR): how much of the per-wave WAL
    fsync cost the pipelined + fan-out commit path hides.

    Three runs of the identical write schedule over an 8-shard durable
    store: synchronous serial commits with ``sync="fsync"`` (the PR-9
    path), the same with ``sync="none"`` (isolates the schedule's
    compute), and pipelined + parallel commits with ``sync="fsync"``.
    The serial fsync bill is ``t_serial - t_compute``; whatever of it no
    longer shows up on the pipelined wall clock was hidden — by the
    concurrent per-shard fsyncs and by overlapping wave e's fsync with
    wave e+1's compute (acceptance target >= 0.5)."""
    import time as _time

    from repro.storage import open_durable_store

    def one(sync, workers, pipeline):
        root = tempfile.mkdtemp(prefix="wikikv_pipe_")
        try:
            store = open_durable_store(
                root, n_shards=n_shards, sync=sync, memtable_limit=4096,
                shard_workers=workers, commit_pipeline=pipeline)
            t0 = _time.perf_counter()
            seq = 0
            for e in range(1, n_waves + 1):
                for _ in range(wave_writes):
                    store.put_record(
                        f"/w/{seq % 16}/r{seq}",
                        R.FileRecord(name=f"r{seq}", text=f"rec {seq}"))
                    seq += 1
                store.commit_epoch(e)
            store.flush()                # drain: durability is included
            t = _time.perf_counter() - t0
            store.close()
            return t
        finally:
            shutil.rmtree(root, ignore_errors=True)

    t_serial = min(one("fsync", 0, False) for _ in range(3))
    t_compute = min(one("none", 0, False) for _ in range(3))
    t_pipe = min(one("fsync", n_shards, True) for _ in range(3))
    fsync_bill = max(t_serial - t_compute, 1e-9)
    visible = max(t_pipe - t_compute, 0.0)
    hidden = max(0.0, min(1.0, 1.0 - visible / fsync_bill))
    tag = (f"waves={n_waves};shards={n_shards};"
           f"serial={round(t_serial * 1000, 1)}ms;"
           f"compute={round(t_compute * 1000, 1)}ms;"
           f"pipelined={round(t_pipe * 1000, 1)}ms")
    return [
        ("table2_commit_serial_fsync_wave_ms",
         round(t_serial * 1000 / n_waves, 3), f"ms_per_wave;{tag}"),
        ("table2_commit_pipelined_wave_ms",
         round(t_pipe * 1000 / n_waves, 3), "ms_per_wave"),
        ("table2_commit_pipeline_hidden_fsync_fraction",
         round(hidden, 3), "fraction;accept>=0.5;report_only_soak"),
    ]


def run(n_iters: int = 1000, warmup: int = 200, seed: int = 0):
    pipe, docs, _ = build_wiki(n_docs=160, n_questions=80, seed=seed)
    items = collect_items(pipe)
    rng = random.Random(seed)
    paths = [p for p, _ in items]
    entity_paths = [p for p in paths if P.depth(p) >= 2] or paths
    dir_paths = [p for p, r in items if hasattr(r, "sub_dirs")] or ["/"]
    targets = [rng.choice(entity_paths) for _ in range(100)]
    dirs = [rng.choice(dir_paths) for _ in range(100)]
    prefixes = [rng.choice(["/" + P.segments(p)[0] for p in entity_paths])
                for _ in range(100)]

    rows = []
    for name, cls in sorted(ALL_BACKENDS.items()):
        be = cls()
        try:
            be.load(items)
            it = iter(range(10**9))
            q1 = timeit_median(
                lambda: be.q1_get(targets[next(it) % 100]),
                n_iters, warmup)
            it = iter(range(10**9))
            q2 = timeit_median(
                lambda: be.q2_ls(dirs[next(it) % 100]), n_iters, warmup)
            it = iter(range(10**9))
            q3 = timeit_median(
                lambda: be.q3_navigate(targets[next(it) % 100]),
                n_iters // 4, warmup // 4)
            it = iter(range(10**9))
            q4 = timeit_median(
                lambda: be.q4_search(prefixes[next(it) % 100]),
                n_iters // 4, warmup // 4)
            rows.append((f"table2_{name}_q1", round(q1 * 1000, 2), "us"))
            rows.append((f"table2_{name}_q2", round(q2 * 1000, 2), "us"))
            rows.append((f"table2_{name}_q3", round(q3 * 1000, 2), "us"))
            rows.append((f"table2_{name}_q4", round(q4 * 1000, 2), "us"))
        finally:
            be.close()

    # batched engine amortization: ONE engine call per 256-query Q1 batch
    # and per multi-prefix Q4 batch — host-sharded vs device, the two
    # QueryEngine implementations behind the serving tier
    batch_paths = [rng.choice(paths) for _ in range(256)]
    batch_prefixes = sorted({"/" + P.segments(p)[0] for p in entity_paths})
    for name in ("wikikv_sharded", "wikikv_device"):
        be = ALL_BACKENDS[name]()
        be.load(items)
        t = timeit_median(lambda: be.q1_get_batch(batch_paths), 100, 20)
        rows.append((f"table2_{name}_q1_batch256", round(t * 1000, 2),
                     f"us_per_batch;{round(t * 1000 / 256, 3)}us_per_query"))
        t4 = timeit_median(lambda: be.q4_search_batch(batch_prefixes), 50, 10)
        rows.append((f"table2_{name}_q4_batch{len(batch_prefixes)}",
                     round(t4 * 1000, 2),
                     f"us_per_batch;{round(t4 * 1000 / max(len(batch_prefixes), 1), 3)}us_per_prefix"))
        rows.append((f"table2_{name}_engine_calls",
                     be.engine.stats.total_calls(),
                     f"count;ops={be.engine.stats.total_ops()}"))
        be.close()
    rows.extend(durable_cold_rows(items, rng, n_iters, warmup))
    rows.extend(trace_overhead_rows(items, targets, n_iters, warmup))
    rows.extend(pipeline_commit_rows())
    rows.append(("table2_wiki_kv_pairs", len(items), "count"))
    emit(rows, header="Table II: per-operator median latency by backend")
    return rows


if __name__ == "__main__":
    run()
