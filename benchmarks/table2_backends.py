"""Table II: median per-operator latency (Q1–Q4) × storage backend.

Reproduces the paper's protocol: a MEDIUM wiki (~2,000 KV pairs), 100
random target paths/prefixes per operator, 1,000 queries per backend
after a 200-query warmup, medians reported.  Backends: the WikiKV
path-as-key layout on the MemKV LSM engine (our method, now served
through the unified QueryEngine), its digest-range sharded variant
(``wikikv_sharded``), the device engine over the frozen tensor index
(``wikikv_device`` — Pallas Q1/Q4 on TPU, jnp reference elsewhere), the
durable WAL+SSTable tier (``wikikv_durable`` — reads served from real
mmap'd segment files; honors ``REPRO_WAL_SYNC``), FS, SQL (sqlite ≈
PostgreSQL+ltree) and a property-graph store (≈ Neo4j) — all in-process,
so the comparison isolates the storage model exactly as §VI-B argues.

The amortization section reports the engines' *batched* Q1/Q4 (one
engine call for 256 lookups / a whole prefix batch) — the serving-tier
execution shape (core/engine.py).
"""
from __future__ import annotations

import random

from common import build_wiki, emit, timeit_median

from repro.core import paths as P
from repro.core.backends import ALL_BACKENDS


def collect_items(pipe):
    items = []
    for path in pipe.store.all_paths():
        if P.is_prefix(P.META_PREFIX, path):
            continue
        rec = pipe.store.get(path)
        if rec is not None:
            items.append((path, rec))
    return items


def run(n_iters: int = 1000, warmup: int = 200, seed: int = 0):
    pipe, docs, _ = build_wiki(n_docs=160, n_questions=80, seed=seed)
    items = collect_items(pipe)
    rng = random.Random(seed)
    paths = [p for p, _ in items]
    entity_paths = [p for p in paths if P.depth(p) >= 2] or paths
    dir_paths = [p for p, r in items if hasattr(r, "sub_dirs")] or ["/"]
    targets = [rng.choice(entity_paths) for _ in range(100)]
    dirs = [rng.choice(dir_paths) for _ in range(100)]
    prefixes = [rng.choice(["/" + P.segments(p)[0] for p in entity_paths])
                for _ in range(100)]

    rows = []
    for name, cls in sorted(ALL_BACKENDS.items()):
        be = cls()
        try:
            be.load(items)
            it = iter(range(10**9))
            q1 = timeit_median(
                lambda: be.q1_get(targets[next(it) % 100]),
                n_iters, warmup)
            it = iter(range(10**9))
            q2 = timeit_median(
                lambda: be.q2_ls(dirs[next(it) % 100]), n_iters, warmup)
            it = iter(range(10**9))
            q3 = timeit_median(
                lambda: be.q3_navigate(targets[next(it) % 100]),
                n_iters // 4, warmup // 4)
            it = iter(range(10**9))
            q4 = timeit_median(
                lambda: be.q4_search(prefixes[next(it) % 100]),
                n_iters // 4, warmup // 4)
            rows.append((f"table2_{name}_q1", round(q1 * 1000, 2), "us"))
            rows.append((f"table2_{name}_q2", round(q2 * 1000, 2), "us"))
            rows.append((f"table2_{name}_q3", round(q3 * 1000, 2), "us"))
            rows.append((f"table2_{name}_q4", round(q4 * 1000, 2), "us"))
        finally:
            be.close()

    # batched engine amortization: ONE engine call per 256-query Q1 batch
    # and per multi-prefix Q4 batch — host-sharded vs device, the two
    # QueryEngine implementations behind the serving tier
    batch_paths = [rng.choice(paths) for _ in range(256)]
    batch_prefixes = sorted({"/" + P.segments(p)[0] for p in entity_paths})
    for name in ("wikikv_sharded", "wikikv_device"):
        be = ALL_BACKENDS[name]()
        be.load(items)
        t = timeit_median(lambda: be.q1_get_batch(batch_paths), 100, 20)
        rows.append((f"table2_{name}_q1_batch256", round(t * 1000, 2),
                     f"us_per_batch;{round(t * 1000 / 256, 3)}us_per_query"))
        t4 = timeit_median(lambda: be.q4_search_batch(batch_prefixes), 50, 10)
        rows.append((f"table2_{name}_q4_batch{len(batch_prefixes)}",
                     round(t4 * 1000, 2),
                     f"us_per_batch;{round(t4 * 1000 / max(len(batch_prefixes), 1), 3)}us_per_prefix"))
        rows.append((f"table2_{name}_engine_calls",
                     be.engine.stats.total_calls(),
                     f"count;ops={be.engine.stats.total_ops()}"))
        be.close()
    rows.append(("table2_wiki_kv_pairs", len(items), "count"))
    emit(rows, header="Table II: per-operator median latency by backend")
    return rows


if __name__ == "__main__":
    run()
