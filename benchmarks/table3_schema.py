"""Table III: cold-start + evolution ablation.

WIKIKV (full) vs FIXED (manual dimensions replace IASI) vs STATIC
(cold-start kept, evolution operators disabled).  All three share the
storage + query layers, so AC/latency deltas isolate schema design —
the paper's §VI-C control.  Access statistics are fed back between
query rounds so the evolution operators have signal to act on.
"""
from __future__ import annotations

from collections import defaultdict

from common import build_wiki, emit

from repro.core.evolution import AccessLog
from repro.core.navigate import Navigator, UnitBudget
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import PipelineConfig
from repro.core.schema import SchemaParams, structure_counts
from repro.data.corpus import score_answer

BUDGET = 400


def evaluate(pipe, questions, feed_access: bool = True):
    nav = Navigator(pipe.store, HeuristicOracle())
    oracle = HeuristicOracle()
    accs, tools, pages, llms = [], [], [], []
    log = AccessLog()
    for q in questions:
        results, trace = nav.nav(q.text, UnitBudget(BUDGET))
        answer = oracle.answer(q.text, [r.text for r in results])
        accs.append(score_answer(answer, q))
        tools.append(trace.tool_calls)
        pages.append(trace.pages_read)
        llms.append(trace.llm_calls)
        log.record(trace.accessed)
    if feed_access:
        pipe.absorb_access_log(log)
    n = len(questions)
    return {
        "AC": 100.0 * sum(accs) / n,
        "tool_calls": sum(tools) / n,
        "pages_read": sum(pages) / n,
        "llm_calls": sum(llms) / n,
    }


def run(seed: int = 0, n_docs: int = 160, n_questions: int = 80):
    variants = {
        "full": PipelineConfig(),
        "fixed": PipelineConfig(fixed_dimensions=[
            "general", "misc_a", "misc_b", "misc_c", "misc_d", "misc_e"]),
        "static": PipelineConfig(enable_evolution=False),
    }
    rows = []
    out = {}
    for name, cfg in variants.items():
        # evolution needs quality-weighted params with real signal
        cfg.params = SchemaParams(alpha=0.02, beta=1.0, gamma=12.0,
                                  theta_merge=0.03, l_max=1200)
        pipe, docs, questions = build_wiki(
            n_docs=n_docs, n_questions=n_questions, seed=seed, cfg=cfg)
        # round 1 populates access stats; evolution runs on ingest cadence
        evaluate(pipe, questions)
        if cfg.enable_evolution and cfg.fixed_dimensions is None:
            pipe.run_evolution()
        res = evaluate(pipe, questions)
        counts = structure_counts(pipe.store)
        res["page_count"] = counts["pages"] + counts["directories"]
        out[name] = res
        for k, v in res.items():
            rows.append((f"table3_{name}_{k}", round(v, 2), ""))
    emit(rows, header="Table III: cold-start/evolution ablation")
    return out


if __name__ == "__main__":
    run()
