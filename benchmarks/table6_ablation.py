"""Table VI: ablation on the densest single-author corpus — Full vs
w/o Cold-Start (full-document injection into schema induction) vs
w/o Search Routing (pure layer-by-layer navigation)."""
from __future__ import annotations

from common import build_wiki, emit

from repro.core.navigate import Navigator, UnitBudget
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import PipelineConfig
from repro.data.corpus import score_answer

BUDGET = 500


def evaluate(pipe, questions, search_routing=True):
    nav = Navigator(pipe.store, HeuristicOracle(),
                    search_routing=search_routing)
    oracle = HeuristicOracle()
    accs, tools, pages, llms = [], [], [], []
    for q in questions:
        results, trace = nav.nav(q.text, UnitBudget(BUDGET))
        answer = oracle.answer(q.text, [r.text for r in results])
        accs.append(score_answer(answer, q))
        tools.append(trace.tool_calls)
        pages.append(trace.pages_read)
        llms.append(trace.llm_calls)
    n = len(questions)
    return {"AC": 100.0 * sum(accs) / n,
            "tool_calls": sum(tools) / n,
            "pages_read": sum(pages) / n,
            "llm_calls": sum(llms) / n}


def run(seed: int = 3, n_docs: int = 140, n_questions: int = 80):
    rows = []
    out = {}
    # Full
    pipe, docs, questions = build_wiki(n_docs=n_docs,
                                       n_questions=n_questions, seed=seed)
    out["full"] = evaluate(pipe, questions, search_routing=True)
    # w/o Cold-Start: full-document injection (enable_coldstart=False
    # passes the whole corpus into schema induction)
    pipe2, _, _ = build_wiki(n_docs=n_docs, n_questions=n_questions,
                             seed=seed,
                             cfg=PipelineConfig(enable_coldstart=False))
    out["wo_coldstart"] = evaluate(pipe2, questions, search_routing=True)
    # w/o Search Routing: same wiki as Full, layer-by-layer plan
    out["wo_search_routing"] = evaluate(pipe, questions,
                                        search_routing=False)
    for name, res in out.items():
        for k, v in res.items():
            rows.append((f"table6_{name}_{k}", round(v, 2), ""))
    emit(rows, header="Table VI: Lu Xun corpus ablation")
    return out


if __name__ == "__main__":
    run()
