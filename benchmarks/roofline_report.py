"""§Roofline report: the three-term model per (arch × shape × mesh),
read from the dry-run artifacts (no recompilation).

    compute   = HLO_FLOPs(per device)     / peak_FLOP/s
    memory    = HLO_bytes(per device)     / HBM_bw
    collective= collective_bytes(per dev) / link_bw

Flags the dominant term, the MODEL_FLOPS/HLO_FLOPS 'useful compute'
ratio, and per-device memory vs the 16 GiB v5e HBM budget.

A second section times the *storage* kernels live (ISSUE 6): the real
Pallas paths — ``path_lookup`` with its VMEM pinned probe, and
``prefix_search`` — under ``REPRO_FORCE_PALLAS=1`` (interpret mode on
this CPU container; compiled on TPU) against the jitted XLA references
(``REPRO_DISABLE_PALLAS=1``).  The interpreter-vs-compiled delta rows
land in the bench-gate JSON artifact so kernel-path drift is tracked
per PR even before TPU time.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from common import ARTIFACTS, emit

HBM_BUDGET = 16 * 2**30


def load_cells(mesh: str | None = None):
    cells = []
    for fn in sorted((ARTIFACTS / "dryrun").glob("*.json")):
        rec = json.loads(fn.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def _timed_ms(fn, iters: int = 5) -> float:
    fn()  # warmup (trace/compile)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1000)
    return float(np.median(ts))


def _mode_env(mode: str):
    """Pin the kernels.ops dispatch: "pallas" forces the Pallas kernels
    (interpret mode off-TPU), "ref" forces the jitted XLA references."""
    prev = {k: os.environ.get(k)
            for k in ("REPRO_FORCE_PALLAS", "REPRO_DISABLE_PALLAS")}
    os.environ.pop("REPRO_FORCE_PALLAS", None)
    os.environ.pop("REPRO_DISABLE_PALLAS", None)
    os.environ["REPRO_FORCE_PALLAS" if mode == "pallas"
               else "REPRO_DISABLE_PALLAS"] = "1"
    return prev


def _restore_env(prev: dict) -> None:
    for k, v in prev.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v


def storage_kernel_rows(n_keys: int = 2048, n_q: int = 512,
                        n_pin: int = 8, iters: int = 5) -> list[tuple]:
    """Time the storage kernels on both dispatch paths and report the
    interpreter-vs-compiled delta (see module docstring)."""
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.path_lookup import pad_pinned

    rng = np.random.default_rng(0)
    keys = np.sort(rng.choice(2**62, size=n_keys, replace=False)
                   .astype(np.uint64))
    khi = jnp.asarray((keys >> np.uint64(32)).astype(np.uint32))
    klo = jnp.asarray((keys & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    # query mix: half hits (some pinned), half misses
    hit = keys[rng.integers(0, n_keys, size=n_q // 2)]
    miss = rng.choice(2**62, size=n_q - n_q // 2).astype(np.uint64) | 1
    q = np.concatenate([hit, miss])
    qhi = jnp.asarray((q >> np.uint64(32)).astype(np.uint32))
    qlo = jnp.asarray((q & np.uint64(0xFFFFFFFF)).astype(np.uint32))
    pin_idx = np.sort(rng.choice(n_keys, size=n_pin, replace=False))
    pinned = tuple(jnp.asarray(a) for a in pad_pinned(
        (keys[pin_idx] >> np.uint64(32)).astype(np.uint32),
        (keys[pin_idx] & np.uint64(0xFFFFFFFF)).astype(np.uint32),
        pin_idx.astype(np.int32)))
    L = 96
    toks = np.zeros((n_keys, L), dtype=np.uint8)
    for i in range(n_keys):
        p = f"/dim{i % 16}/doc{i}".encode()
        toks[i, :len(p)] = np.frombuffer(p, dtype=np.uint8)
    toks_j = jnp.asarray(toks)
    prefs = np.full((8, L), 255, dtype=np.uint8)
    lens = np.full((8,), 1, dtype=np.int32)
    for i in range(8):
        p = f"/dim{i}".encode()
        prefs[i, :len(p)] = np.frombuffer(p, dtype=np.uint8)
        lens[i] = len(p)
    prefs_j, lens_j = jnp.asarray(prefs), jnp.asarray(lens)

    def lookup():
        np.asarray(ops.path_lookup(khi, klo, qhi, qlo, pinned=pinned))

    def prefix():
        np.asarray(ops.prefix_search(toks_j, prefs_j, lens_j))

    rows, ms = [], {}
    for mode in ("pallas", "ref"):
        prev = _mode_env(mode)
        try:
            ms[("lookup", mode)] = _timed_ms(lookup, iters)
            ms[("prefix", mode)] = _timed_ms(prefix, iters)
        finally:
            _restore_env(prev)
    on_tpu = False
    try:
        import jax
        on_tpu = jax.devices()[0].platform == "tpu"
    except Exception:
        pass
    kind = "compiled" if on_tpu else "interpret"
    for op in ("lookup", "prefix"):
        p_ms, r_ms = ms[(op, "pallas")], ms[(op, "ref")]
        rows.append((f"roofline_storage_{op}_pallas_{kind}",
                     round(p_ms, 3),
                     f"ms;keys={n_keys};q={n_q};pinned={n_pin}"))
        rows.append((f"roofline_storage_{op}_ref_compiled",
                     round(r_ms, 3), "ms;jitted_xla_reference"))
        rows.append((f"roofline_storage_{op}_{kind}_vs_compiled",
                     round(p_ms / max(r_ms, 1e-9), 2),
                     "x;pallas_over_ref"))
    return rows


def run(mesh: str = "16x16"):
    rows = []
    for rec in load_cells(mesh):
        tag = f"{rec['arch']}__{rec['shape']}"
        if rec["status"] == "skip":
            rows.append((f"roofline_{tag}", "skip",
                         rec.get("skip_reason", "")[:40]))
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline_{tag}", "error", rec.get("error", "")[:60]))
            continue
        r = rec["roofline"]
        mem = rec["memory"]["total_per_device"]
        rows.append((
            f"roofline_{tag}",
            round(max(r["t_compute_s"], r["t_memory_s"],
                      r["t_collective_s"]), 4),
            f"dom={r['dominant']};tc={r['t_compute_s']:.3e};"
            f"tm={r['t_memory_s']:.3e};tx={r['t_collective_s']:.3e};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"mem={mem/2**30:.1f}GiB;"
            f"fits16G={'Y' if mem <= HBM_BUDGET else 'N'}"))
    emit(rows, header=f"Roofline terms per cell ({mesh})")
    kernel_rows = storage_kernel_rows()
    emit(kernel_rows, header="Storage kernels: Pallas path vs XLA reference")
    return rows + kernel_rows


if __name__ == "__main__":
    run()
