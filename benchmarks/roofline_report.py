"""§Roofline report: the three-term model per (arch × shape × mesh),
read from the dry-run artifacts (no recompilation).

    compute   = HLO_FLOPs(per device)     / peak_FLOP/s
    memory    = HLO_bytes(per device)     / HBM_bw
    collective= collective_bytes(per dev) / link_bw

Flags the dominant term, the MODEL_FLOPS/HLO_FLOPS 'useful compute'
ratio, and per-device memory vs the 16 GiB v5e HBM budget.
"""
from __future__ import annotations

import json
from pathlib import Path

from common import ARTIFACTS, emit

HBM_BUDGET = 16 * 2**30


def load_cells(mesh: str | None = None):
    cells = []
    for fn in sorted((ARTIFACTS / "dryrun").glob("*.json")):
        rec = json.loads(fn.read_text())
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def run(mesh: str = "16x16"):
    rows = []
    for rec in load_cells(mesh):
        tag = f"{rec['arch']}__{rec['shape']}"
        if rec["status"] == "skip":
            rows.append((f"roofline_{tag}", "skip",
                         rec.get("skip_reason", "")[:40]))
            continue
        if rec["status"] != "ok":
            rows.append((f"roofline_{tag}", "error", rec.get("error", "")[:60]))
            continue
        r = rec["roofline"]
        mem = rec["memory"]["total_per_device"]
        rows.append((
            f"roofline_{tag}",
            round(max(r["t_compute_s"], r["t_memory_s"],
                      r["t_collective_s"]), 4),
            f"dom={r['dominant']};tc={r['t_compute_s']:.3e};"
            f"tm={r['t_memory_s']:.3e};tx={r['t_collective_s']:.3e};"
            f"useful={r['useful_flops_ratio']:.2f};"
            f"mem={mem/2**30:.1f}GiB;"
            f"fits16G={'Y' if mem <= HBM_BUDGET else 'N'}"))
    emit(rows, header=f"Roofline terms per cell ({mesh})")
    return rows


if __name__ == "__main__":
    run()
