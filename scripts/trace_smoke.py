"""Traced serving smoke for CI (ISSUE 8).

Runs a tiny ``ServingEngine`` — DeviceEngine over a durable WAL+SSTable
store — with tracing ON, drives a couple of navigation requests plus an
online write batch through the continuous-batching loop, then

* exports the span ring as Chrome trace-event / Perfetto JSON to
  ``artifacts/TRACE_smoke.json`` (open it in ``chrome://tracing``),
* validates it with the shared checker (monotonic, well-nested spans;
  coverage of the full chain planner wave → engine op → device refresh →
  WAL commit), and
* prints the ``stats_snapshot()`` summary table.

Exit 0 iff the trace is valid and covers the chain.  Run from the repo
root: ``python scripts/trace_smoke.py``.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("REPRO_WAL_SYNC", "none")
os.environ["REPRO_TRACE"] = "1"

OUT = REPO / "artifacts" / "TRACE_smoke.json"
SCRATCH = REPO / "artifacts" / f"durable_scratch_trace_{os.getpid()}"

#: the acceptance chain: one serving wave must leave spans at every tier
REQUIRED_SPANS = ("serving.wave", "planner.flush", "device.refresh",
                  "wal.commit")


def build_serving():
    from repro import obs
    from repro.configs import get_config
    from repro.core import records as R
    from repro.core.engine import DeviceEngine
    from repro.core.oracle import HeuristicOracle
    from repro.data.tokenizer import HashTokenizer
    from repro.models import model as M
    from repro.runtime.serving import ServingEngine
    from repro.storage import open_durable_store

    obs.configure(enabled=True)
    obs.set_context(run="trace_smoke")
    store = open_durable_store(str(SCRATCH / "store"), sync="none")
    store.put_record("/", R.DirRecord(name=""))
    store.put_record("/wiki", R.DirRecord(name="wiki"))
    for i in range(8):
        store.put_record(f"/wiki/page{i}",
                         R.FileRecord(name=f"page{i}",
                                      text=f"entry {i} about topic {i % 3}"))
    store.flush()
    dev = DeviceEngine.from_store(store)
    cfg = get_config("wikikv-router").reduced(d_model=32, vocab=512,
                                              n_layers=2)
    tok = HashTokenizer(vocab_size=cfg.vocab).fit(["topic entry page"])
    params = M.init_params(cfg, seed=0)
    eng = ServingEngine(cfg, params, tok, dev, HeuristicOracle(),
                        batch_size=2, max_len=64, write_batch=4)
    return eng, store


def main() -> int:
    from repro import obs
    from repro.core import records as R
    from repro.runtime.serving import Request

    shutil.rmtree(SCRATCH, ignore_errors=True)
    OUT.parent.mkdir(parents=True, exist_ok=True)
    try:
        eng, store = build_serving()
        # online writes ride the waves → dirty device refresh + WAL commit
        for i in range(6):
            eng.submit_admit(f"/wiki/live{i}",
                             R.FileRecord(name=f"live{i}",
                                          text=f"online write {i}"))
        reqs = [Request(rid=f"r{i}", query=f"topic {i}", max_new_tokens=2)
                for i in range(2)]
        done = eng.run(reqs)
        assert len(done) == 2 and all(r.done for r in done), \
            "serving run did not retire every request"

        snap = eng.stats_snapshot()
        n = obs.export_trace(str(OUT))
        print(f"trace smoke: exported {n} events to {OUT}")
        events = obs.load_events(str(OUT))
        problems = obs.validate_events(events, require=REQUIRED_SPANS)
        # at least one engine read op between wave and refresh
        if not any(str(ev.get("name", "")).startswith(("device.q", "host.q"))
                   for ev in events):
            problems.append("no engine op span (device.q*/host.q*) in trace")
        for p in problems:
            print(f"trace smoke: INVALID: {p}", file=sys.stderr)
        print(obs.format_snapshot(snap))
        print(f"trace smoke: snapshot keys: {sorted(snap)}")
        json.dumps(snap)  # must stay JSON-able (the stats contract)
        store.close()
        if problems:
            return 1
        print("trace smoke: trace valid, span chain covered: "
              + ", ".join(REQUIRED_SPANS))
        return 0
    finally:
        shutil.rmtree(SCRATCH, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
