#!/usr/bin/env bash
# CI smoke: tier-1 tests + a cheap benchmark pass over the engine layer.
# Mirrors the ROADMAP tier-1 verify command; pyproject.toml makes the
# bare pytest invocation work without PYTHONPATH.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (engine layer) =="
PYTHONPATH=src python -m benchmarks.run --smoke
