#!/usr/bin/env bash
# CI smoke: tier-1 tests + a cheap benchmark pass over the engine layer,
# then the bench regression gate.  Both steps use the ROADMAP tier-1
# PYTHONPATH convention (prepend src, preserve any pre-set PYTHONPATH) so
# local and CI invocations are byte-identical.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (engine layer) =="
mkdir -p artifacts
python -m benchmarks.run --smoke | tee artifacts/BENCH_smoke.txt

echo "== bench gate (Q1 host-engine p50 regression) =="
python scripts/bench_gate.py artifacts/BENCH_smoke.txt \
  --json-out artifacts/BENCH_smoke.json \
  --baseline benchmarks/baseline_smoke.json
