#!/usr/bin/env bash
# CI smoke: tier-1 tests + a cheap benchmark pass over the engine layer,
# then the bench regression gate.  Both steps use the ROADMAP tier-1
# PYTHONPATH convention (prepend src, preserve any pre-set PYTHONPATH) so
# local and CI invocations are byte-identical.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# durable-tier fsync-off knob: container/CI timings are dominated by fsync
# jitter otherwise; production default (unset) is fsync-per-wave
export REPRO_WAL_SYNC="${REPRO_WAL_SYNC:-none}"

# sweep durable-tier scratch on every exit path: the recovery-smoke
# store dirs plus any stray *.wal/*.seg a crashed run left under
# artifacts/.  Deliberately scoped to artifacts/ — a developer's own
# durable store elsewhere in the tree must not have its WAL/segments
# deleted out from under its manifest.
cleanup() {
  rm -rf artifacts/durable_scratch_*
  find artifacts \( -name '*.wal' -o -name '*.seg' \) -type f -delete \
    2>/dev/null || true
}
trap cleanup EXIT

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (engine layer) =="
mkdir -p artifacts
python -m benchmarks.run --smoke | tee artifacts/BENCH_smoke.txt

echo "== bench gate (Q1 host-engine p50 regression) =="
python scripts/bench_gate.py artifacts/BENCH_smoke.txt \
  --json-out artifacts/BENCH_smoke.json \
  --baseline benchmarks/baseline_smoke.json

echo "== durable-tier recovery smoke (build → crash → reopen) =="
python scripts/recovery_smoke.py

echo "== traced serving smoke (REPRO_TRACE=1 → Perfetto export) =="
# one serving wave with tracing on: exports artifacts/TRACE_smoke.json
# and validates it (monotonic, well-nested, full span chain); then the
# standalone checker exercises the CLI path CI consumers use
python scripts/trace_smoke.py
python scripts/check_trace.py artifacts/TRACE_smoke.json \
  --require serving.wave --require planner.flush \
  --require device.refresh --require wal.commit

echo "== docs consistency (links + REPRO_* knob table) =="
python scripts/check_docs.py
