"""Benchmark regression gate for CI.

Parses the ``name,value,unit`` CSV emitted by ``benchmarks/run.py --smoke``,
writes the parsed rows as a JSON artifact, and fails (exit 1) if any gated
metric regressed more than ``--factor`` (default 2.0, overridable via the
``BENCH_GATE_FACTOR`` env var) against the checked-in baseline.

The gated metrics are the Q1 host-engine medians (``timeit_median`` reports
the median, i.e. p50, per call).  On the first run — no baseline file yet —
the gate writes the baseline from the current run and passes; the written
file is meant to be checked in.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

# Q1 host-engine p50 rows (plain + digest-range-sharded host backends),
# the durable tier (WAL + SSTable segments, REPRO_WAL_SYNC=none in CI),
# the cold leveled-store rows (ISSUE 7), and the key-range-partitioned
# cold rows (ISSUE 9; one-PR soak done) — the gate runs with
# REPRO_TRACE unset, so these also pin "telemetry is free when off"
# (ISSUE 8).
GATED_METRICS = (
    "table2_wikikv_q1",
    "table2_wikikv_sharded_q1",
    "table2_wikikv_durable_q1",
    "table2_wikikv_durable_q4",
    "table2_wikikv_durable_cold_q1_hit",
    "table2_wikikv_durable_cold_q1_miss",
    "table2_wikikv_durable_cold_nofilter_q1_hit",
    "table2_wikikv_durable_cold_nofilter_q1_miss",
    "table2_wikikv_durable_cold_miss_speedup",
    "table2_wikikv_durable_cold_hit_speedup",
    "table2_wikikv_durable_cold_part_nofilter_q1_hit",
    "table2_wikikv_durable_cold_part_nofilter_q1_miss",
)

# Absolute gates (ISSUE 9/10 soak graduated): ratio-vs-baseline is the
# wrong shape for these — a speedup getting BETTER would trip a ratio
# gate, and the trace-overhead ratio is already normalized.  Floors
# fail when current < floor; ceilings fail when current > ceiling.
ABSOLUTE_FLOOR_METRICS = {
    # partitioned binary search vs flat probe-all on filterless files
    "table2_wikikv_durable_cold_part_speedup": 1.5,
}
ABSOLUTE_CEILING_METRICS = {
    # traced/untraced Q1 p50 ratio — the REPRO_TRACE=1 span cost
    "table2_trace_overhead_q1": 2.0,
}

# Rows recorded in the JSON artifact and printed, but not gated; newly
# added benchmarks soak here for one PR before joining GATED_METRICS.
# The ISSUE 10 rows: parallel-fanout speedup over the serial shard
# loops (latency-injected; acceptance >= 2x) and the fraction of the
# per-wave WAL fsync bill the pipelined commit hides (>= 0.5).
REPORT_ONLY_METRICS = (
    "table5_fanout_parallel_speedup",
    "table5_fanout_parallel_speedup_noinject",
    "table2_commit_pipeline_hidden_fsync_fraction",
    "table2_commit_serial_fsync_wave_ms",
    "table2_commit_pipelined_wave_ms",
)

# Informational budget from the ISSUE 3 acceptance: durable Q1 p50 should
# stay within this factor of the in-memory wikikv backend with sync off.
DURABLE_VS_MEM_BUDGET = 5.0


def parse_rows(text: str) -> dict[str, float]:
    """Extract ``name -> value`` from the benchmark harness CSV output."""
    rows: dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith(("#", "=")):
            continue
        parts = line.split(",")
        if len(parts) < 2:
            continue
        try:
            rows[parts[0]] = float(parts[1])
        except ValueError:
            continue
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_output", help="captured benchmarks/run.py output")
    ap.add_argument("--json-out", default=None, help="write parsed rows as JSON")
    ap.add_argument("--baseline", default="benchmarks/baseline_smoke.json")
    ap.add_argument(
        "--factor",
        type=float,
        default=float(os.environ.get("BENCH_GATE_FACTOR", "2.0")),
        help="max allowed current/baseline ratio (default 2.0)",
    )
    args = ap.parse_args()

    rows = parse_rows(Path(args.bench_output).read_text())
    if not rows:
        print(f"bench gate: no parseable rows in {args.bench_output}", file=sys.stderr)
        return 1
    if args.json_out:
        Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json_out).write_text(json.dumps(rows, indent=2, sort_keys=True))
        print(f"bench gate: wrote {len(rows)} rows to {args.json_out}")

    for metric in REPORT_ONLY_METRICS:
        if metric in rows:
            value = rows[metric]
            print(f"bench gate: {metric}: current={value:.2f} (report-only, not gated this PR)")
    durable = rows.get("table2_wikikv_durable_q1")
    mem = rows.get("table2_wikikv_q1")
    if durable and mem and mem > 0:
        ratio = durable / mem
        budget = DURABLE_VS_MEM_BUDGET
        verdict = "OK" if ratio <= budget else "OVER BUDGET (informational)"
        print(f"bench gate: durable/mem q1 ratio={ratio:.2f}x (budget {budget:.1f}x) {verdict}")

    gated = {m: rows[m] for m in GATED_METRICS if m in rows}
    if not gated:
        print("bench gate: no gated metrics in this run; nothing to compare")
        return 0

    baseline_path = Path(args.baseline)
    if not baseline_path.exists():
        baseline_path.parent.mkdir(parents=True, exist_ok=True)
        baseline_path.write_text(json.dumps(gated, indent=2, sort_keys=True))
        print(f"bench gate: baseline missing — wrote {baseline_path} (check it in)")
        return 0

    baseline = json.loads(baseline_path.read_text())
    # backfill: a gated metric with no baseline entry yet (freshly
    # promoted) records its current value and passes — the updated
    # baseline file is meant to be checked in with the promoting PR
    backfilled = {m: v for m, v in gated.items() if m not in baseline}
    if backfilled:
        baseline.update(backfilled)
        baseline_path.write_text(json.dumps(baseline, indent=2,
                                            sort_keys=True))
        for m, v in sorted(backfilled.items()):
            print(f"bench gate: {m}: baseline backfilled at {v:.2f} "
                  "(newly gated — check in the updated baseline)")
    failures = []
    for metric, current in sorted(gated.items()):
        base = baseline.get(metric)
        if base is None or base <= 0:
            print(f"bench gate: {metric} has no baseline; skipping")
            continue
        ratio = current / base
        status = "OK" if ratio <= args.factor else "REGRESSED"
        print(
            f"bench gate: {metric}: current={current:.2f} baseline={base:.2f} "
            f"ratio={ratio:.2f}x (limit {args.factor:.2f}x) {status}"
        )
        if ratio > args.factor:
            failures.append(metric)
    for metric, floor in sorted(ABSOLUTE_FLOOR_METRICS.items()):
        if metric not in rows:
            continue
        current = rows[metric]
        status = "OK" if current >= floor else "REGRESSED"
        print(f"bench gate: {metric}: current={current:.2f} (floor {floor:.2f}) {status}")
        if current < floor:
            failures.append(metric)
    for metric, ceiling in sorted(ABSOLUTE_CEILING_METRICS.items()):
        if metric not in rows:
            continue
        current = rows[metric]
        status = "OK" if current <= ceiling else "REGRESSED"
        print(f"bench gate: {metric}: current={current:.2f} (ceiling {ceiling:.2f}) {status}")
        if current > ceiling:
            failures.append(metric)
    if failures:
        print(f"bench gate: FAILED — regressed metrics: {failures}", file=sys.stderr)
        return 1
    print("bench gate: all gated metrics within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
