"""Docs consistency gate for CI (ISSUE 7 satellite).

Two checks, both cheap enough for every push:

* every intra-repo markdown link (``[text](relative/path)``) in the
  repo's tracked ``*.md`` files resolves to an existing file — anchors
  and external ``http(s)``/``mailto`` links are skipped;
* every ``REPRO_*`` environment knob referenced anywhere under ``src/``
  appears as a table row in the docs/STORAGE.md knob table, so a new
  knob cannot ship undocumented.

Run from the repo root: ``python scripts/check_docs.py``.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
STORAGE_MD = REPO / "docs" / "STORAGE.md"

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must resolve too.  Nested ")" in targets are not used here.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_ENV = re.compile(r"\bREPRO_[A-Z][A-Z0-9_]*\b")

SKIP_DIRS = {".git", "artifacts", "__pycache__", ".pytest_cache",
             ".hypothesis", "node_modules"}
# harvested external reference material (quoted verbatim from other
# repos/papers) — their links point outside this repository by design
SKIP_FILES = {"SNIPPETS.md", "PAPERS.md", "PAPER.md"}


def iter_markdown() -> list[Path]:
    out = []
    for p in REPO.rglob("*.md"):
        rel = p.relative_to(REPO)
        if SKIP_DIRS.intersection(rel.parts) or rel.name in SKIP_FILES:
            continue
        out.append(p)
    return sorted(out)


def check_links() -> list[str]:
    errors = []
    for md in iter_markdown():
        for target in _LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            base = REPO if rel.startswith("/") else md.parent
            if not (base / rel.lstrip("/")).exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def check_env_knobs() -> list[str]:
    used: set[str] = set()
    for py in sorted((REPO / "src").rglob("*.py")):
        used.update(_ENV.findall(py.read_text(encoding="utf-8")))
    if not STORAGE_MD.exists():
        return [f"{STORAGE_MD.relative_to(REPO)} missing (knob table home)"]
    # only markdown table rows count as documentation — a knob merely
    # mentioned in prose is not "in the knob table"
    table_rows = [ln for ln in STORAGE_MD.read_text(encoding="utf-8")
                  .splitlines() if ln.lstrip().startswith("|")]
    documented = set()
    for ln in table_rows:
        documented.update(_ENV.findall(ln))
    errors = [f"src/ references {var} but docs/STORAGE.md's knob table "
              "has no row for it" for var in sorted(used - documented)]
    return errors


def main() -> int:
    errors = check_links() + check_env_knobs()
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if errors:
        print(f"check_docs: FAILED ({len(errors)} problems)",
              file=sys.stderr)
        return 1
    n_md = len(iter_markdown())
    print(f"check_docs: OK — links resolve across {n_md} markdown files; "
          "every REPRO_* knob documented in docs/STORAGE.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
