"""Durable-tier recovery smoke for CI (ISSUE 3 satellite; multi-level
since ISSUE 7).

Two phases in two processes:

* child  (``--build DIR``): opens a sharded durable store with a tiny
  memtable and an aggressive ``level_ratio=2``, admits enough committed
  waves that spills cascade through leveled compaction (the child asserts
  the tree really is multi-level before exiting), prints the committed
  state as JSON, then writes ONE more wave without committing it and
  exits via ``os._exit`` — no ``close()``, no atexit, no buffered-tail
  flush.  The SIGKILL-free analogue of a crash.
* parent (default): runs the child, reopens the directory, and asserts
  the record count and epoch match what the child committed — and that
  the child's uncommitted wave is gone (Δ = 1 wave across restart), over
  a store whose reads traverse multiple compaction levels.

Run from the repo root: ``python scripts/recovery_smoke.py``.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

SCRATCH = REPO / "artifacts" / f"durable_scratch_{os.getpid()}"
UNCOMMITTED_PATH = "/d0/uncommitted_marker"


def build(root: str) -> None:
    from repro.core import records as R
    from repro.core.engine import BatchPlanner, HostEngine
    from repro.storage import open_durable_store

    # tiny memtable + ratio 2: wave commits spill constantly and the
    # spills cascade through leveled compaction while the store serves;
    # the small segment target makes the merges PARTITION their output
    # (multiple range-disjoint segments per level >= 1, ISSUE 9)
    store = open_durable_store(root, n_shards=2, memtable_limit=16,
                               level_ratio=2, segment_target_bytes=512)
    host = HostEngine(store)
    pl = BatchPlanner(host)
    pl.admit("/d0", R.DirRecord(name="d0"))
    for wave in range(10):
        for i in range(6):
            pl.admit(f"/d{i % 3}/w{wave}_{i}",
                     R.FileRecord(name=f"w{wave}_{i}", text=f"{wave}:{i}"))
        pl.flush()
        host.refresh()                       # wave boundary = WAL commit
    levels = [sh.engine.level_counts() for sh in store.shards]
    assert any(max(lc, default=0) >= 1 for lc in levels), \
        f"build never produced a multi-level store: {levels}"
    assert any(any(lvl >= 1 and n >= 2 for lvl, n in lc.items())
               for lc in levels), \
        f"no level >= 1 ever partitioned into multiple segments: {levels}"
    committed = {"epoch": host.epoch, "paths": store.count(),
                 "levels": levels}
    print(json.dumps(committed), flush=True)
    # one more wave, executed but never committed — must not survive
    pl.admit(UNCOMMITTED_PATH, R.FileRecord(name="m", text="lost"))
    pl.flush()
    assert store.get(UNCOMMITTED_PATH) is not None
    os._exit(0)                              # crash: no close, no commit


def main() -> int:
    if len(sys.argv) == 3 and sys.argv[1] == "--build":
        build(sys.argv[2])
        return 0

    root = str(SCRATCH / "store")
    shutil.rmtree(SCRATCH, ignore_errors=True)
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src{os.pathsep}{env['PYTHONPATH']}" \
        if env.get("PYTHONPATH") else "src"
    env.setdefault("REPRO_WAL_SYNC", "none")
    proc = subprocess.run(
        [sys.executable, __file__, "--build", root],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)
    if proc.returncode != 0:
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
        print("recovery smoke: child build FAILED", file=sys.stderr)
        return 1
    committed = json.loads(proc.stdout.strip().splitlines()[-1])

    os.environ.setdefault("REPRO_WAL_SYNC", "none")
    from repro.core.engine import HostEngine
    from repro.storage import open_durable_store

    store = open_durable_store(root)
    host = HostEngine(store)
    ok = True
    reopened_levels = [sh.engine.level_counts() for sh in store.shards]
    if not any(max(lc, default=0) >= 1 for lc in reopened_levels):
        print(f"recovery smoke: reopened store is not multi-level: "
              f"{reopened_levels}", file=sys.stderr)
        ok = False
    # ISSUE 9: the reopened levels >= 1 must be key-range PARTITIONED —
    # pairwise-disjoint ranges the read path can binary-search — and at
    # least one of them multi-segment (a real partitioned merge output)
    multi_part = False
    for sh in store.shards:
        for view in sh.engine._levels:
            if view.level >= 1 and not view.partitioned:
                print(f"recovery smoke: level {view.level} reopened "
                      "unpartitioned (probe-all fallback)", file=sys.stderr)
                ok = False
            if view.level >= 1 and len(view.entries) >= 2:
                multi_part = True
    if not multi_part:
        print(f"recovery smoke: no partitioned multi-segment level "
              f"survived reopen: {reopened_levels}", file=sys.stderr)
        ok = False
    if host.epoch != committed["epoch"]:
        print(f"recovery smoke: epoch {host.epoch} != committed "
              f"{committed['epoch']}", file=sys.stderr)
        ok = False
    if store.count() != committed["paths"]:
        print(f"recovery smoke: count {store.count()} != committed "
              f"{committed['paths']}", file=sys.stderr)
        ok = False
    if store.get(UNCOMMITTED_PATH) is not None:
        print("recovery smoke: uncommitted wave survived the crash",
              file=sys.stderr)
        ok = False
    store.close()
    shutil.rmtree(SCRATCH, ignore_errors=True)
    if ok:
        print(f"recovery smoke: OK — reopened {committed['paths']} records "
              f"at epoch {committed['epoch']} across levels "
              f"{reopened_levels}; uncommitted wave dropped")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
