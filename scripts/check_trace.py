"""Validate a Chrome trace-event / Perfetto JSON export.

Thin CLI over ``repro.obs.validate_events``: checks every event is a
complete ("X") span with non-negative numeric timestamps and that spans
are well-nested per thread; ``--require NAME`` (repeatable) additionally
asserts named spans are present.  Exit 0 iff valid.

Usage: ``python scripts/check_trace.py artifacts/TRACE_smoke.json \
           --require serving.wave --require wal.commit``
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs import load_events, validate_events  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace JSON file to validate")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME",
                    help="span name that must appear (repeatable)")
    args = ap.parse_args()
    try:
        events = load_events(args.trace)
    except (OSError, ValueError) as e:
        print(f"check_trace: {e}", file=sys.stderr)
        return 1
    problems = validate_events(events, require=tuple(args.require))
    for p in problems:
        print(f"check_trace: {p}", file=sys.stderr)
    if problems:
        print(f"check_trace: {args.trace}: INVALID "
              f"({len(problems)} problem(s) in {len(events)} events)",
              file=sys.stderr)
        return 1
    print(f"check_trace: {args.trace}: OK ({len(events)} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
