"""Serving example: the WeChat-assistant pattern — per-author wikis +
continuous-batching LM serving over WikiKV.

    PYTHONPATH=src python examples/serve_assistant.py

Builds TWO author wikis (disjoint subtrees — the §IV-C parallel
construction model), freezes one into the device-resident tensor index,
then serves a mixed query batch through the engine (NAV retrieval → LM
decode), printing per-request traces and the batched device-lookup demo.
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core import tensorstore as TS
from repro.core.cache import TieredCache
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import build_author_wikis, PipelineConfig
from repro.data.corpus import AuthTraceConfig, generate_authtrace, score_answer
from repro.data.tokenizer import HashTokenizer
from repro.models import model as M
from repro.runtime.serving import Request, ServingEngine


def main():
    print("=== per-author parallel construction (disjoint subtrees) ===")
    corpora, questions = {}, {}
    for author in ("lu_xun", "qian_zhongshu"):
        docs, qs = generate_authtrace(
            AuthTraceConfig(n_docs=60, n_questions=16, seed=hash(author) % 97,
                            author=author))
        corpora[author] = docs
        questions[author] = qs
    wikis = build_author_wikis(corpora, HeuristicOracle, PipelineConfig())
    for author, pipe in wikis.items():
        print(f"  {author}: {pipe.store.count()} KV pairs")

    print("\n=== tensorized index (TPU-native batched GET) ===")
    pipe = wikis["lu_xun"]
    wiki = TS.freeze(pipe.store)
    t0 = time.perf_counter()
    rows = TS.batched_get(wiki, wiki.paths)   # the whole namespace at once
    dt = (time.perf_counter() - t0) * 1e6
    print(f"  {wiki.n} lookups in one launch: {dt:.0f} us "
          f"({dt/wiki.n:.2f} us/query), all hits: {all(r >= 0 for r in rows)}")

    print("\n=== continuous-batching serving ===")
    cfg = get_config("wikikv-router").reduced(d_model=64, vocab=2048)
    texts = [pipe.store.get(p).text for p in pipe.store.all_paths()
             if hasattr(pipe.store.get(p), "text")]
    tok = HashTokenizer(vocab_size=cfg.vocab).fit(texts[:80])
    params = M.init_params(cfg, seed=0)
    cache = TieredCache(pipe.store, bus=pipe.bus)
    cache.prewarm()
    engine = ServingEngine(cfg, params, tok, pipe.store, HeuristicOracle(),
                           cache=cache, batch_size=2, max_len=192)
    reqs = [Request(rid=q.qid, query=q.text, max_new_tokens=6)
            for q in questions["lu_xun"][:4]]
    done = engine.run(reqs)
    qmap = {q.qid: q for q in questions["lu_xun"]}
    correct = 0
    for r in done:
        ok = score_answer(r.answer, qmap[r.rid])
        correct += ok
        print(f"  [{r.rid}] fan_in={qmap[r.rid].fan_in} "
              f"tools={r.trace.tool_calls} pages={r.trace.pages_read} "
              f"AC={'✓' if ok else '✗'}")
    print(f"answered {correct}/{len(done)} exactly; "
          f"cache hit-rate {cache.stats.hit_rate():.2f}")


if __name__ == "__main__":
    main()
