"""End-to-end training driver: train the wikikv-router LM on wiki text.

    PYTHONPATH=src python examples/train_router.py [--steps 300]

Trains the paper's routing/navigation LM (§V-B's distilled classifier
backbone) for a few hundred steps on the synthetic author corpus through
the full production stack: data pipeline → jit'd train step (AdamW +
cosine schedule) → atomic checkpoints → crash-safe resume.  Loss is
reported; a mid-run "crash" + restore demonstrates fault tolerance.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.launch.train import build_pipeline
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--dir", default="checkpoints/router")
    args = ap.parse_args()

    cfg = get_config("wikikv-router")
    pipeline, tok = build_pipeline(cfg.vocab, seq_len=128, global_batch=8)
    loop = TrainLoop(cfg, AdamWConfig(lr=3e-4),
                     TrainLoopConfig(total_steps=args.steps,
                                     checkpoint_every=100,
                                     checkpoint_dir=args.dir, log_every=25),
                     pipeline)
    # phase 1
    loop.run(n_steps=args.steps // 2)
    loop.save()
    loop.ckpt.wait()        # commit before the "crash" (async save)
    mid_loss = loop.metrics.losses[-1]
    print(f"--- simulated preemption at step {loop.step_no} "
          f"(loss {mid_loss:.3f}) — restarting from checkpoint ---")
    # phase 2: a fresh loop restores params/opt/data position and finishes
    pipeline2, _ = build_pipeline(cfg.vocab, seq_len=128, global_batch=8)
    loop2 = TrainLoop(cfg, AdamWConfig(lr=3e-4),
                      TrainLoopConfig(total_steps=args.steps,
                                      checkpoint_every=100,
                                      checkpoint_dir=args.dir, log_every=25),
                      pipeline2)
    metrics = loop2.run()
    assert loop2.step_no == args.steps
    assert len(metrics.losses) == args.steps - args.steps // 2, \
        "phase 2 must RESUME, not restart"
    print(f"resumed at {args.steps // 2}, finished at {loop2.step_no}; "
          f"resumed-loss {metrics.losses[0]:.3f} → final "
          f"{metrics.losses[-1]:.3f}")
    assert metrics.losses[-1] < mid_loss + 0.5, "loss should keep improving"


if __name__ == "__main__":
    main()
