"""Quickstart: corpus → cold-start → ingest → evolve → navigate.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --durable /tmp/wiki_store

With ``--durable DIR`` the wiki is built on the on-disk WAL + SSTable
tier (``repro.storage``); the demo then closes the store, reopens the
directory in-place, and navigates again with zero re-ingestion —
byte-identical results straight from disk.

Builds a WikiKV instance from a synthetic author corpus, runs budgeted
navigation queries at several budgets (showing the anytime/progressive
contract), feeds access statistics back, runs one evolution pass, and
prints the schema-cost trajectory.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro import obs
from repro.core.cache import TieredCache
from repro.core.evolution import AccessLog
from repro.core.navigate import Navigator, UnitBudget, check_progressive
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import ConstructionPipeline, PipelineConfig
from repro.core.schema import SchemaParams, schema_cost, structure_counts
from repro.data.corpus import AuthTraceConfig, generate_authtrace


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--durable", metavar="DIR", default=None,
                    help="build on the durable WAL+SSTable tier rooted at "
                         "DIR (must be a fresh/empty directory — the demo "
                         "ingests from scratch), then demonstrate "
                         "close → reopen → navigate: reopening replays the "
                         "WAL tail into the memtable and serves committed "
                         "records straight from the leveled segments, no "
                         "re-ingestion")
    args = ap.parse_args()
    # telemetry on for the whole demo: every navigation batch below
    # records spans + latency histograms, summarized at exit (§6)
    obs.configure(enabled=True)
    print("=== 1. generate corpus (AUTHTRACE protocol) ===")
    docs, questions = generate_authtrace(
        AuthTraceConfig(n_docs=100, n_questions=40, seed=42))
    print(f"{len(docs)} docs, {len(questions)} questions "
          f"(fan-in 1/2/3+ buckets)")

    print("\n=== 2. cold-start (IASI) + ingest ===")
    cfg = PipelineConfig(params=SchemaParams(alpha=0.02, beta=1.0,
                                             gamma=12.0, theta_merge=0.03))
    store = None
    if args.durable:
        from repro.storage import open_durable_store
        store = open_durable_store(args.durable)
        if store.count():
            # a recovered store would mix the previous run's (possibly
            # evolved) records with this run's fresh ingest
            sys.exit(f"--durable: {args.durable} already holds "
                     f"{store.count()} records; pass a fresh directory "
                     "(or delete it) — this demo builds from scratch")
        print(f"durable tier: WAL + segments under {args.durable}")
    pipe = ConstructionPipeline(cfg, HeuristicOracle(), store=store)
    res = pipe.bootstrap(docs)
    print(f"filter Φ dropped {res.filter_report.drop_count} low-info docs; "
          f"scaffold: {res.n_dimensions} dimensions, {res.n_entities} entities")
    print(f"positioning 𝒫: {res.positioning}")
    for i in range(0, len(docs), 20):
        pipe.ingest(docs[i:i + 20])
    print(f"structure: {structure_counts(pipe.store)}")

    print("\n=== 3. budgeted navigation (anytime semantics) ===")
    cache = TieredCache(pipe.store, bus=pipe.bus)
    print(f"L1 prewarmed with {cache.prewarm()} pages")
    nav = Navigator(pipe.store, HeuristicOracle(), cache=cache)
    q = questions[0]
    print(f"Q: {q.text}  (fan-in {q.fan_in})")
    for budget in (6, 40, 400):
        results, trace = nav.nav(q.text, UnitBudget(budget))
        kinds = [r.kind for r in results]
        print(f"  B={budget:4d}: {len(results)} results {kinds} "
              f"progressive={check_progressive(results)} "
              f"tools={trace.tool_calls} llm={trace.llm_calls}")

    print("\n=== 4. access stats → evolution (Theorem 1) ===")
    log = AccessLog()
    for q in questions:
        _, trace = nav.nav(q.text, UnitBudget(300))
        log.record(trace.accessed)
    pipe.absorb_access_log(log)
    before = schema_cost(pipe.store, cfg.params)
    ops = pipe.run_evolution()
    after = schema_cost(pipe.store, cfg.params)
    for op in ops:
        mark = "✓" if op.committed else "✗"
        print(f"  {mark} {op.op:6s} {op.target}  ΔC={op.measured_delta:+.4f}")
    print(f"cost C(S;W): {before.total:.3f} → {after.total:.3f} "
          f"(monotone: {after.total <= before.total + 1e-9})")

    print(f"\ncache hit-rate: {cache.stats.hit_rate():.2f}")

    if args.durable:
        print("\n=== 5. durable tier: close → reopen → navigate ===")
        from repro.storage import open_durable_store
        n_before = pipe.store.count()
        baseline, _ = nav.nav(q.text, UnitBudget(400))
        base_sig = [(r.kind, r.path) for r in baseline]
        pipe.store.flush()
        pipe.store.close()
        reopened = open_durable_store(args.durable)
        print(f"reopened {reopened.count()} records from disk "
              f"(built {n_before}; zero re-ingestion)")
        nav2 = Navigator(reopened, HeuristicOracle())
        results2, _ = nav2.nav(q.text, UnitBudget(400))
        match = [(r.kind, r.path) for r in results2] == base_sig
        print(f"re-navigated Q: {len(results2)} results, "
              f"identical to pre-restart: {match}")
        reopened.close()

    sec = 6 if args.durable else 5
    print(f"\n=== {sec}. telemetry: stats_snapshot() ===")
    print(obs.format_snapshot(obs.build_snapshot(nav.engine, nav.planner)))


if __name__ == "__main__":
    main()
