"""Three-tier cache (paper §V-C): prewarm, promotion, invalidation,
bounded footprint."""
from repro.core import records as R
from repro.core.cache import LruTtl, TieredCache
from repro.core.consistency import InvalidationBus, WikiWriter
from repro.core.store import DictKV, PathStore


def _wiki():
    store = PathStore(DictKV())
    bus = InvalidationBus()
    w = WikiWriter(store, bus=bus)
    w.ensure_root()
    for d in ("rel", "style"):
        w.admit(f"/{d}", R.DirRecord(name=d))
    for i in range(30):
        w.admit(f"/rel/e{i}", R.FileRecord(name=f"e{i}", text=f"page {i}"))
    bus.drain()
    return store, bus, w


def test_lru_ttl():
    clock = {"t": 0.0}
    c = LruTtl(capacity=3, ttl=10.0, clock=lambda: clock["t"])
    for i in range(5):
        c.put(f"k{i}", b"v")
    assert len(c) == 3 and c.evictions == 2
    assert c.get("k0") is None            # evicted
    assert c.get("k4") == b"v"
    clock["t"] = 11.0
    assert c.get("k4") is None            # expired


def test_prewarm_l1_holds_root_and_dims():
    store, bus, _ = _wiki()
    cache = TieredCache(store, bus=bus)
    n = cache.prewarm()
    assert n >= 3                          # root + 2 dimensions
    cache.get("/")
    cache.get("/rel")
    assert cache.stats.l1_hits == 2 and cache.stats.misses == 0


def test_promotion_and_hit_path():
    store, bus, _ = _wiki()
    cache = TieredCache(store, bus=bus)
    cache.prewarm()
    assert cache.get("/rel/e5") is not None   # L3 hit, promoted to L2
    assert cache.stats.l3_hits == 1
    cache.get("/rel/e5")
    assert cache.stats.l2_hits == 1


def test_invalidation_refreshes_entries():
    store, bus, w = _wiki()
    cache = TieredCache(store, bus=bus)
    cache.prewarm()
    _, kids = cache.ls("/rel")
    assert "/rel/new" not in kids
    w.admit("/rel/new", R.FileRecord(name="new", text="fresh"))
    bus.drain()                            # Δ elapses
    _, kids = cache.ls("/rel")             # L1 entry was refreshed
    assert "/rel/new" in kids
    rec = cache.get("/rel/new")
    assert rec.text == "fresh"


def test_stale_entry_updated_on_page_rewrite():
    store, bus, w = _wiki()
    cache = TieredCache(store, bus=bus)
    cache.get("/rel/e1")                   # promoted to L2
    w.update_file("/rel/e1",
                  lambda r: R.FileRecord(name=r.name, text="rewritten",
                                         meta=r.meta))
    bus.drain()
    assert cache.get("/rel/e1").text == "rewritten"


def test_bounded_footprint():
    """§V-C: resident set bounded by capacity caps, not corpus size."""
    store, bus, w = _wiki()
    cache = TieredCache(store, bus=bus, l1_capacity=8, l2_capacity=16)
    cache.prewarm()
    for i in range(30):
        cache.get(f"/rel/e{i}")
    fp = cache.memory_footprint()
    assert fp["l1_entries"] <= 8
    assert fp["l2_entries"] <= 16
