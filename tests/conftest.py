"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 device
(the dry-run is the only consumer of the 512-device override)."""
import os
import sys
from pathlib import Path

# allow `pytest tests/` without PYTHONPATH=src
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# the pinned container ships without hypothesis; fall back to the vendored
# deterministic shim (a real install always wins — it is found first)
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.append(str(Path(__file__).resolve().parent / "_vendor"))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402

from repro.core.oracle import HeuristicOracle  # noqa: E402
from repro.core.pipeline import ConstructionPipeline, PipelineConfig  # noqa: E402
from repro.data.corpus import AuthTraceConfig, generate_authtrace  # noqa: E402


@pytest.fixture(scope="session")
def corpus_and_questions():
    return generate_authtrace(AuthTraceConfig(n_docs=64, n_questions=24,
                                              seed=7))


@pytest.fixture(scope="session")
def built_wiki(corpus_and_questions):
    docs, questions = corpus_and_questions
    pipe = ConstructionPipeline(PipelineConfig(), HeuristicOracle())
    pipe.bootstrap(docs)
    for i in range(0, len(docs), 16):
        pipe.ingest(docs[i:i + 16])
    return pipe, questions
