"""Telemetry layer (ISSUE 8): mergeable histograms, span ring + Perfetto
export, disabled-mode no-op, snapshot schema, durable-counter reset."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro import obs
from repro.core import records as R
from repro.core.engine import (D_BLOOM_NEG, D_CACHE_HIT, BatchPlanner,
                               DeviceEngine, HostEngine)
from repro.core.store import MemKV, PathStore
from repro.obs.metrics import NULL_METRIC, Histogram, bucket_of
from repro.obs.trace import NULL_SPAN


@pytest.fixture
def traced():
    """Fresh ENABLED global registry; restores the env default after."""
    reg = obs.configure(enabled=True, ring_size=4096)
    yield reg
    obs.configure()


@pytest.fixture
def untraced():
    """Fresh DISABLED global registry; restores the env default after."""
    reg = obs.configure(enabled=False)
    yield reg
    obs.configure()


# latency-like values spanning 1µs .. 10s in ms units, plus exact zeros
_samples = st.lists(
    st.integers(min_value=0, max_value=10**7).map(lambda n: n / 1000.0),
    min_size=0, max_size=60)


# ---------------------------------------------------------------------------
# histogram: merge ≡ pooled, percentile accuracy
# ---------------------------------------------------------------------------
@settings(max_examples=40)
@given(_samples, _samples)
def test_histogram_merge_equals_pooled(a, b):
    """The load-bearing property: fixed global bucket boundaries make
    merge(h(A), h(B)) identical to h(A + B) — counts, extremes, and every
    percentile, bucket-for-bucket."""
    merged = Histogram(a).merge(Histogram(b))
    pooled = Histogram(a + b)
    assert merged.counts == pooled.counts
    assert merged.n == pooled.n and merged.zeros == pooled.zeros
    if a or b:
        assert merged.vmin == pooled.vmin and merged.vmax == pooled.vmax
    assert merged.total == pytest.approx(pooled.total)
    for q in (0, 10, 50, 90, 99, 99.9, 100):
        assert merged.percentile(q) == pooled.percentile(q)


@settings(max_examples=40)
@given(st.lists(st.integers(min_value=1, max_value=10**7)
                .map(lambda n: n / 1000.0), min_size=1, max_size=60))
def test_histogram_percentile_within_bucket_error(xs):
    """Reported percentiles stay within the ~2.2% half-bucket relative
    error of the exact nearest-rank sample percentile."""
    import math
    h = Histogram(xs)
    ordered = sorted(xs)
    for q in (50, 90, 99):
        exact = ordered[max(1, math.ceil(q / 100.0 * len(xs))) - 1]
        got = h.percentile(q)
        assert got == pytest.approx(exact, rel=0.023)
    assert h.percentile(0) == ordered[0]      # exact at the extremes
    assert h.percentile(100) == ordered[-1]


def test_histogram_zero_and_empty():
    assert Histogram().percentile(50) == 0.0
    assert Histogram().summary()["count"] == 0
    h = Histogram([0.0, 0.0, 0.0, 5.0])
    assert h.zeros == 3
    assert h.percentile(50) == 0.0            # rank 2 of 4 is a zero
    assert h.percentile(100) == 5.0


def test_bucket_width_is_sub16():
    # adjacent bucket boundaries differ by 2^(1/16) ≈ 4.4%
    assert bucket_of(1.0) == 0
    assert bucket_of(2.0 ** (1 / 16) * 1.001) == 1


# ---------------------------------------------------------------------------
# disabled mode: no-op singletons, zero recorded state
# ---------------------------------------------------------------------------
def test_disabled_mode_is_noop(untraced):
    reg = untraced
    assert not obs.enabled()
    # singletons, not fresh allocations
    assert obs.span("x", tag=1) is NULL_SPAN
    assert obs.histogram("h") is NULL_METRIC
    assert obs.counter("c") is NULL_METRIC
    assert obs.gauge("g") is NULL_METRIC
    with obs.span("outer") as sp:
        sp.set(kind="y")
        obs.histogram("h").record(1.0)
        obs.counter("c").inc()
        obs.gauge("g").set(3.0)
        obs.set_context(wave=7)
    assert reg.ring == type(reg.ring)() and len(reg.ring) == 0
    assert reg.histograms == {} and reg.counters == {} and reg.gauges == {}
    assert reg.ctx == {}                      # set_context gated too


def test_default_registry_matches_env(monkeypatch):
    monkeypatch.delenv(obs.TRACE_ENV, raising=False)
    try:
        assert obs.configure().enabled is False
        monkeypatch.setenv(obs.TRACE_ENV, "1")
        assert obs.configure().enabled is True
    finally:
        monkeypatch.delenv(obs.TRACE_ENV, raising=False)
        obs.configure()


# ---------------------------------------------------------------------------
# spans: ring events, histograms, nesting, correlation
# ---------------------------------------------------------------------------
def test_span_records_event_and_histogram(traced):
    reg = traced
    obs.set_context(session="s1")
    with obs.span("outer", a=1):
        with obs.span("inner") as sp:
            sp.set(kind="leaf")
    assert [e["name"] for e in reg.ring] == ["inner", "outer"]
    inner, outer = reg.ring
    assert inner["args"] == {"session": "s1", "kind": "leaf"}
    assert outer["args"] == {"session": "s1", "a": 1}
    assert inner["ts"] >= outer["ts"]
    assert inner["dur"] <= outer["dur"] + 1e-6
    assert reg.histograms["outer"].n == 1
    assert reg.histograms["inner"].n == 1
    assert obs.validate_events(list(reg.ring)) == []


def test_span_ring_is_bounded():
    reg = obs.configure(enabled=True, ring_size=16)
    try:
        for i in range(100):
            with obs.span(f"s{i}"):
                pass
        assert len(reg.ring) == 16
        assert reg.ring[-1]["name"] == "s99"
    finally:
        obs.configure()


def test_validate_events_flags_overlap_and_requires():
    bad = [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 100.0, "tid": 1},
        {"name": "b", "ph": "X", "ts": 50.0, "dur": 100.0, "tid": 1},
    ]
    problems = obs.validate_events(bad, require=("missing",))
    assert any("overlaps" in p for p in problems)
    assert any("missing" in p for p in problems)
    ok = [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 100.0, "tid": 1},
        {"name": "b", "ph": "X", "ts": 10.0, "dur": 20.0, "tid": 1},
        {"name": "c", "ph": "X", "ts": 40.0, "dur": 20.0, "tid": 1},
    ]
    assert obs.validate_events(ok, require=("a", "b", "c")) == []


def test_trace_export_roundtrip(traced, tmp_path):
    with obs.span("one"):
        with obs.span("two"):
            pass
    out = tmp_path / "trace.json"
    n = obs.export_trace(str(out))
    assert n == 2
    doc = json.loads(out.read_text())
    assert doc["displayTimeUnit"] == "ms"
    events = obs.load_events(str(out))
    assert obs.validate_events(events, require=("one", "two")) == []


def test_span_nesting_and_correlation_across_refresh_wave(traced, tmp_path):
    """A real write wave over the durable device tier leaves a
    well-nested trace — planner flush → device refresh → WAL commit —
    whose storage-tier spans carry the wave id that caused them."""
    from repro.storage import open_durable_store
    store = open_durable_store(str(tmp_path / "wiki"), sync="none")
    store.put_record("/", R.DirRecord(name=""))
    store.flush()
    dev = DeviceEngine.from_store(store)
    pl = BatchPlanner(dev)
    pl.admit("/d0", R.DirRecord(name="d0"))
    pl.admit("/d0/e0", R.FileRecord(name="e0", text="v0"))
    f = pl.get("/d0/e0")
    pl.flush()
    dev.refresh()
    assert f.done
    events = list(traced.ring)
    assert obs.validate_events(
        events, require=("planner.flush", "device.q1_get",
                         "device.refresh", "wal.commit")) == []
    by_name = {}
    for ev in events:
        by_name.setdefault(ev["name"], []).append(ev)
    # correlation: the flush stamped wave=1 before any nested span closed
    assert by_name["device.q1_get"][0]["args"]["wave"] == 1
    assert by_name["wal.commit"][-1]["args"]["wave"] == 1
    # the refresh span knows what the device applied
    refresh = by_name["device.refresh"][-1]
    assert refresh["args"]["kind"] in ("patch", "rebuild")
    assert refresh["args"]["epoch"] == dev.epoch
    # epoch context updated for *subsequent* spans
    assert traced.ctx["epoch"] == dev.epoch
    # and the per-kind refresh duration landed in a histogram
    kinds = [k for k in ("patch", "rebuild")
             if f"device.refresh.{k}" in traced.histograms]
    assert kinds
    store.close()


# ---------------------------------------------------------------------------
# snapshot: schema stability + percentile parity
# ---------------------------------------------------------------------------
def _mini_serving(store):
    from repro.configs import get_config
    from repro.core.oracle import HeuristicOracle
    from repro.data.tokenizer import HashTokenizer
    from repro.models import model as M
    from repro.runtime.serving import ServingEngine
    cfg = get_config("wikikv-router").reduced(d_model=32, vocab=512,
                                              n_layers=2)
    tok = HashTokenizer(vocab_size=cfg.vocab).fit(["x"])
    return ServingEngine(cfg, M.init_params(cfg, seed=0), tok, store,
                         HeuristicOracle(), batch_size=2, max_len=64)


def test_stats_snapshot_schema_stable_on_and_off():
    """The top-level key set is a contract: identical with tracing on
    and off, and JSON-able in both modes."""
    store = PathStore(MemKV())
    store.put_record("/", R.DirRecord(name=""))
    eng = _mini_serving(store)
    obs.configure(enabled=True)
    try:
        obs.histogram("serving.request_nav_ms").record(1.25)
        on = eng.stats_snapshot()
        obs.configure(enabled=False)
        off = eng.stats_snapshot()
    finally:
        obs.configure()
    expected = {"trace_enabled", "epoch", "waves", "ops", "dedup_ratio",
                "refresh", "durable", "pending", "latency_ms", "counters",
                "gauges", "pending_writes", "lanes_active"}
    assert set(on) == expected
    assert set(off) == expected
    assert on["trace_enabled"] and not off["trace_enabled"]
    assert on["latency_ms"]["serving.request_nav_ms"]["count"] == 1
    assert off["latency_ms"] == {}            # shape kept, content empty
    json.dumps(on), json.dumps(off)


def test_snapshot_percentiles_match_benchmark_logic(traced):
    """Acceptance: snapshot p50/p99 equal the benchmark tables' shared
    histogram percentile on identical samples (one implementation)."""
    samples = [0.05 * (i % 97) + 0.01 for i in range(500)]
    h = obs.histogram("op_ms")
    for v in samples:
        h.record(v)
    row = traced.metrics_snapshot()["latency_ms"]["op_ms"]
    ref = Histogram(samples)                   # == benchmarks.common.pct
    assert row["p50"] == round(ref.percentile(50), 6)
    assert row["p90"] == round(ref.percentile(90), 6)
    assert row["p99"] == round(ref.percentile(99), 6)
    assert row["max"] == round(max(samples), 6)


# ---------------------------------------------------------------------------
# satellite 1: durable high-water marks reset on store (re)attach
# ---------------------------------------------------------------------------
class _FakeDurable:
    """op_counts-only stand-in for a durable store."""

    def __init__(self, counts):
        self.counts = counts

    def op_counts(self):
        return dict(self.counts)


def test_durable_seen_resets_on_store_swap():
    """Regression: after a store swap (reopen), the fresh store's
    counters restart at 0 — stale high-water marks from the previous
    store must not silently drop its telemetry."""
    eng = HostEngine(PathStore(MemKV()))
    eng.store = _FakeDurable({"bloom_neg": 5, "cache_hit": 3,
                              "cache_miss": 1})
    eng.sync_durable_stats()
    assert eng.stats.ops[D_BLOOM_NEG] == 5
    eng.sync_durable_stats()                   # delta'd: no double count
    assert eng.stats.ops[D_BLOOM_NEG] == 5
    # swap in a reopened store: counters restarted below the old marks
    eng.store = _FakeDurable({"bloom_neg": 2, "cache_hit": 1,
                              "cache_miss": 0})
    eng.sync_durable_stats()
    assert eng.stats.ops[D_BLOOM_NEG] == 7     # 5 + 2, nothing dropped
    assert eng.stats.ops[D_CACHE_HIT] == 4
