"""Key-range-partitioned levels (ISSUE 9 tentpole): binary-searched
point reads that probe exactly one segment per level ≥ 1, range-pruned
k-way-merge scans with correct tombstone semantics across levels, the
compaction backpressure budget, and the ``seg_probe``/``compact_debt``
telemetry plumbed through the engine stats surface.

The probe-count tests hand-craft a three-level partitioned store by
writing segment files + a format-3 manifest directly: the compaction
machinery (covered in test_storage.py) would sink tiny fixtures to one
bottom level, while the read-path acceptance needs a *deep* tree with a
known shape — levels are a manifest property, so building one is
legitimate store surgery, not a bypass.
"""
import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import records as R
from repro.core.consistency import WikiWriter
from repro.core.engine import (D_COMPACT_DEBT, D_SEG_PROBE, HostEngine)
from repro.core.store import MemKV
from repro import obs
from repro.storage import DurableKV, open_durable_store, write_sstable
from repro.storage import manifest as MF
from repro.storage.sstable import TOMBSTONE


def _k(i: int) -> bytes:
    return f"k{i:04d}".encode()


def _write_level(d, manifest, items, level, n_parts):
    """Split ``items`` into ``n_parts`` contiguous partitions, write each
    as a segment file, and append range-accurate metas to ``manifest``."""
    per = (len(items) + n_parts - 1) // n_parts
    for p in range(n_parts):
        chunk = items[p * per:(p + 1) * per]
        if not chunk:
            continue
        name = manifest.alloc_segment()
        stats = write_sstable(os.path.join(d, name), chunk, sync=False,
                              bloom_bits_per_key=0)
        manifest.segments.append(MF.SegmentMeta(
            name=name, level=level, records=stats.n_records,
            bytes=stats.file_bytes, min_key=stats.min_key.hex(),
            max_key=stats.max_key.hex(), bloom_k=0, bloom_bits=0))


def _three_level_store(tmp_path):
    """A 3-level partitioned store with a known shadowing pattern over
    keys k0000..k0059: i ≡ 0 (mod 3) newest at L1, i ≡ 1 at L2, every
    key oldest at L3 — so L1/L2 shadow L3 for their residues and only
    i ≡ 2 keys fall all the way through.  Blooms are disabled so every
    candidate segment really is probed."""
    d = str(tmp_path / "kv")
    os.makedirs(d)
    m = MF.Manifest(epoch=1)
    _write_level(d, m, [(_k(i), b"L3") for i in range(60)], level=3,
                 n_parts=4)
    _write_level(d, m, [(_k(i), b"L2") for i in range(60) if i % 3 == 1],
                 level=2, n_parts=2)
    _write_level(d, m, [(_k(i), b"L1") for i in range(60) if i % 3 == 0],
                 level=1, n_parts=2)
    MF.store(d, m, sync=False)
    kv = DurableKV(d, sync="none")
    assert kv.level_counts() == {1: 2, 2: 2, 3: 4}
    assert all(v.partitioned for v in kv._levels), \
        "a handcrafted level fell back to probe-all"
    return kv


def _probe_delta(kv, keys):
    base = kv.op_counts().get("seg_probe", 0)
    out = [kv.get(k) for k in keys]
    return out, kv.op_counts().get("seg_probe", 0) - base


# ---------------------------------------------------------------------------
# the tentpole acceptance: one probe per level ≥ 1
# ---------------------------------------------------------------------------
def test_point_read_probes_exactly_one_segment_per_level(tmp_path):
    """ISSUE 9 acceptance: on a ≥3-level partitioned store, a cold point
    read probes exactly ONE segment per level ≥ 1 (manifest key ranges +
    per-level binary search), shown by the ``seg_probe`` counter."""
    kv = _three_level_store(tmp_path)
    # keys that miss L1 and L2 but sit inside every level's key range:
    # exactly 3 probes each (1 per level), hit lands at L3
    vals, delta = _probe_delta(kv, [_k(5), _k(23), _k(41)])
    assert vals == [b"L3"] * 3
    assert delta == 3 * 3, f"expected 1 probe/level, counted {delta}"
    # a key shadowed at L1 stops there: exactly 1 probe
    vals, delta = _probe_delta(kv, [_k(9)])
    assert vals == [b"L1"] and delta == 1
    # shadowed at L2: probes L1 (range hit, key miss) then L2
    vals, delta = _probe_delta(kv, [_k(22)])
    assert vals == [b"L2"] and delta == 2
    # a key outside every partition's range probes NOTHING
    vals, delta = _probe_delta(kv, [b"zzz"])
    assert vals == [None] and delta == 0
    kv.close()


def test_flat_reads_probe_every_shallower_segment(tmp_path):
    """The ``flat_reads`` A/B switch really is the pre-partitioned read
    path: the same miss-at-shallow-levels key probes every L1/L2 segment
    plus at least one L3 partition instead of one per level."""
    kv = _three_level_store(tmp_path)
    _, part = _probe_delta(kv, [_k(5)])
    assert part == 3
    kv.set_flat_reads(True)
    assert not any(v.partitioned for v in kv._levels)
    _, flat = _probe_delta(kv, [_k(5)])
    assert flat >= 2 + 2 + 1                # all of L1+L2, ≥1 of L3
    assert flat > part
    kv.set_flat_reads(False)
    _, again = _probe_delta(kv, [_k(5)])
    assert again == 3                       # the toggle round-trips
    kv.close()


# ---------------------------------------------------------------------------
# scan across partitioned levels
# ---------------------------------------------------------------------------
def test_scan_first_seen_wins_across_partitioned_levels(tmp_path):
    """The k-way merge keeps level order: the shallowest version of each
    key wins, partitions of one level interleave seamlessly."""
    kv = _three_level_store(tmp_path)
    got = dict(kv.scan(b"k"))
    want = {_k(i): (b"L1" if i % 3 == 0 else b"L2" if i % 3 == 1 else b"L3")
            for i in range(60)}
    assert got == want
    # range-pruning: a narrow prefix skips non-overlapping partitions
    base = kv.op_counts().get("scan_skip", 0)
    sub = dict(kv.scan(_k(7)[:5]))          # prefix b"k0007"
    assert sub == {_k(7): b"L2"}
    assert kv.op_counts().get("scan_skip", 0) > base
    kv.close()


def test_tombstones_interleaved_across_partitioned_levels(tmp_path):
    """Deletes layered above partitioned levels: scan and get drop the
    deleted keys, the tombstones themselves survive level merges while a
    deeper level remains, and a major compact finally drops them."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                   segment_target_bytes=32)
    for i in range(8):
        kv.put(_k(i), f"v{i}".encode())
    kv.commit_epoch(1)
    kv.compact()                             # partitioned bottom level
    bottom = max(m.level for m in kv._manifest.segments)
    assert bottom >= 1
    assert sum(1 for m in kv._manifest.segments if m.level == bottom) >= 2

    kv.delete(_k(2))
    kv.delete(_k(5))
    for i in range(8, 12):
        kv.put(_k(i), f"v{i}".encode())
    kv.commit_epoch(2)                       # spill 1
    for i in range(12, 16):
        kv.put(_k(i), f"v{i}".encode())
    kv.commit_epoch(3)                       # spill 2 → L0 merge above bottom
    want = {_k(i): f"v{i}".encode() for i in range(16) if i not in (2, 5)}
    assert dict(kv.scan(b"k")) == want
    assert kv.get(_k(2)) is None and kv.get(_k(5)) is None
    assert kv.get(_k(3)) == b"v3"
    # the tombstones were NOT dropped: the bottom level still holds the
    # old versions, so some shallower segment must carry them
    live_tombs = sum(1 for _, seg in kv._read_order
                     for _, v in seg.iter_all() if v is TOMBSTONE)
    assert live_tombs == 2, "tombstone dropped while a deeper level remained"

    kv.compact()                             # no deeper level ⇒ drop
    assert dict(kv.scan(b"k")) == want
    assert kv.get(_k(2)) is None
    assert sum(1 for _, seg in kv._read_order
               for _, v in seg.iter_all() if v is TOMBSTONE) == 0
    kv.close()


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_get_equals_scan_then_filter(tmp_path_factory, seed):
    """Property (ISSUE 9 satellite): for every key ever touched, point
    ``get`` agrees with a full ``scan`` materialized then filtered — the
    binary-searched path and the k-way-merge path are the same view."""
    import random
    rng = random.Random(seed)
    d = str(tmp_path_factory.mktemp("prop") / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                   segment_target_bytes=32)
    pool = [_k(i) for i in range(24)]
    epoch = 0
    for _ in range(rng.randint(3, 8)):
        for _ in range(rng.randint(1, 6)):
            k = rng.choice(pool)
            if rng.random() < 0.25:
                kv.delete(k)
            else:
                kv.put(k, f"s{seed}-{rng.randint(0, 99)}".encode())
        epoch += 1
        kv.commit_epoch(epoch)
        if rng.random() < 0.2:
            kv.compact()
    full = dict(kv.scan(b""))
    for k in pool:
        assert kv.get(k) == full.get(k)
    kv.close()


# ---------------------------------------------------------------------------
# compaction backpressure
# ---------------------------------------------------------------------------
def _burst(kv, waves, per_wave=8):
    """Drive ``waves`` write waves; → per-wave merged-bytes trace."""
    trace, n, epoch = [], 0, 0
    for _ in range(waves):
        for _ in range(per_wave):
            kv.put(_k(n), b"x" * 16)
            n += 1
        epoch += 1
        kv.commit_epoch(epoch)
        trace.append(kv.last_compact_bytes)
    return trace, epoch


def test_compact_budget_bounds_per_wave_merge_work(tmp_path):
    """ISSUE 9 acceptance (compaction-burst serving): with a budget set,
    the merge work charged to ANY wave boundary is bounded (p99 == max
    here — the trace is exact), debt accrues during the burst and drains
    after it; the identical unbudgeted workload pays for whole cascades
    inside single waves."""
    budget = 400
    kv = DurableKV(str(tmp_path / "budgeted"), memtable_limit=8,
                   sync="none", level_ratio=2, segment_target_bytes=64,
                   compact_budget_bytes=budget)
    trace, epoch = _burst(kv, waves=24)
    # bound: the budget plus at most one partition's overshoot
    slack = 300
    assert max(trace) <= budget + slack, trace
    assert kv.compact_debt() > 0, "a throttled burst should owe work"
    drain = 0
    while kv.compact_debt() > 0:             # idle waves pay the debt off
        epoch += 1
        kv.commit_epoch(epoch)
        assert kv.last_compact_bytes <= budget + slack
        drain += 1
        assert drain < 200, "debt never drained"
    assert dict(kv.scan(b"k")) == {_k(i): b"x" * 16 for i in range(24 * 8)}
    kv.close()

    kv2 = DurableKV(str(tmp_path / "unbounded"), memtable_limit=8,
                    sync="none", level_ratio=2, segment_target_bytes=64,
                    compact_budget_bytes=0)
    trace2, _ = _burst(kv2, waves=24)
    assert kv2.compact_debt() == 0           # unbounded never defers
    assert max(trace2) > budget + slack, \
        "the unbudgeted burst never stalled a wave — workload too small " \
        f"to prove throttling matters (max {max(trace2)})"
    assert dict(kv2.scan(b"k")) == {_k(i): b"x" * 16 for i in range(24 * 8)}
    kv2.close()


# ---------------------------------------------------------------------------
# stats plumbing: DurableKV → PathStore → HostEngine → obs snapshot
# ---------------------------------------------------------------------------
def test_seg_probe_and_compact_debt_reach_engine_stats(tmp_path):
    """``d_seg_probe`` (delta-synced counter) and ``d_compact_debt``
    (gauge) surface through ``QueryEngine.stats`` and nest under the
    snapshot's ``durable`` section, for both shard shapes."""
    for shards in (1, 2):
        root = str(tmp_path / f"s{shards}")
        store = open_durable_store(root, n_shards=shards, sync="none",
                                   memtable_limit=8,
                                   segment_target_bytes=64,
                                   compact_budget_bytes=512)
        eng = HostEngine(store)
        eng.writer.ensure_root("root")
        eng.admit_many([("/d", R.DirRecord(name="d", summary="dim"))])
        paths = [f"/d/e{i}" for i in range(24)]
        for lo in range(0, 24, 8):           # one wave per batch of 8
            eng.admit_many([
                (p, R.FileRecord(name=p.rsplit("/", 1)[1], text=f"body {p}"))
                for p in paths[lo:lo + 8]])
            eng.refresh(force=True)          # wave boundary: spill + merge
        eng.q1_get(paths)                    # cold-ish point reads
        eng.sync_durable_stats()
        assert eng.stats.ops.get(D_SEG_PROBE, 0) > 0
        assert D_COMPACT_DEBT in eng.stats.ops
        debt = eng.stats.ops[D_COMPACT_DEBT]
        assert debt == (store.compact_debt() or 0) >= 0
        snap = obs.build_snapshot(engine=eng)
        assert snap["durable"]["seg_probe"] == eng.stats.ops[D_SEG_PROBE]
        assert snap["durable"]["compact_debt"] == eng.stats.ops[D_COMPACT_DEBT]
        assert snap["durable"]["backpressure"] == bool(debt)
        store.close()


def test_volatile_store_reports_no_compact_debt():
    """A MemKV-backed engine must not grow a phantom debt gauge."""
    from repro.core.store import PathStore
    eng = HostEngine(PathStore(MemKV()))
    eng.writer.ensure_root("root")
    eng.sync_durable_stats()
    assert D_COMPACT_DEBT not in eng.stats.ops
    snap = obs.build_snapshot(engine=eng)
    assert snap["durable"]["compact_debt"] == 0
    assert snap["durable"]["backpressure"] is False
