"""Device-resident tensorized path index ≡ host PathStore (property)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core import tensorstore as TS
from repro.core.store import DictKV, PathStore

seg = st.text(alphabet="abcdefgh_", min_size=1, max_size=6)


def _store_from_paths(paths):
    ps = PathStore(DictKV())
    ps.put_record("/", R.DirRecord(name=""))
    for p in paths:
        rec = (R.DirRecord(name=P.basename(p)) if P.depth(p) < 2
               else R.FileRecord(name=P.basename(p), text="t"))
        ps.put_record(p, rec)
    return ps


@settings(max_examples=20, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=1, max_size=24))
def test_lookup_roundtrip(paths):
    norm = sorted({P.normalize(p) for p in paths})
    dims = sorted({P.parent(p) for p in norm})
    ps = _store_from_paths(dims + norm)
    wiki = TS.freeze(ps)
    rows = TS.batched_get(wiki, wiki.paths)
    assert all(wiki.paths[r] == p for r, p in zip(rows, wiki.paths))
    miss = TS.batched_get(wiki, ["/definitely/not_here"])
    assert miss[0] == -1


@settings(max_examples=20, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=1, max_size=20),
       seg)
def test_prefix_search_matches_host(paths, probe):
    norm = sorted({P.normalize(p) for p in paths})
    dims = sorted({P.parent(p) for p in norm})
    ps = _store_from_paths(dims + norm)
    wiki = TS.freeze(ps)
    prefix = "/" + probe
    host = set(ps.search(prefix))
    dev = set(TS.search_prefix(wiki, prefix))
    assert dev == host


def test_ls_rows_matches_children(built_wiki):
    pipe, _ = built_wiki
    wiki = TS.freeze(pipe.store)
    root_row = int(TS.batched_get(wiki, ["/"])[0])
    kid_rows = TS.ls_rows(wiki, root_row)
    kid_paths = {wiki.paths[r] for r in kid_rows}
    _, host_kids = pipe.store.ls("/")
    assert kid_paths == set(host_kids)


def test_navigate_rows(built_wiki):
    pipe, _ = built_wiki
    wiki = TS.freeze(pipe.store)
    ent = next(p for p in pipe.store.all_paths()
               if P.node_type(p) == P.NODE_ENTITY and not P.is_reserved(p))
    rows = TS.navigate_rows(wiki, ent)
    assert rows[-1] >= 0 and wiki.paths[rows[-1]] == ent
    assert rows[0] >= 0 and wiki.paths[rows[0]] == "/"


def test_pinned_prefix_counts_dimensions(built_wiki):
    pipe, _ = built_wiki
    wiki = TS.freeze(pipe.store)
    n_dims = sum(1 for p in pipe.store.all_paths() if P.depth(p) <= 1)
    assert wiki.n_pinned == n_dims


# ---------------------------------------------------------------------------
# ISSUE 2: TensorDelta incremental refresh ≡ full re-freeze (property)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=2, max_size=16),
       st.lists(st.tuples(st.sampled_from(["append", "overwrite", "unlink"]),
                          seg, seg),
                min_size=1, max_size=10))
def test_apply_delta_matches_refreeze(paths, mutations):
    norm = sorted({P.normalize(p) for p in paths})
    dims = sorted({P.parent(p) for p in norm})
    ps = _store_from_paths(dims + norm)
    wiki, recs = TS.freeze_with_records(ps)
    live = list(norm)
    upserts, unlinks = [], []
    for kind, a, b in mutations:
        if kind == "append":
            p = P.normalize(f"/{a}/x_{b}")
            rec = R.FileRecord(name=P.basename(p), text="new")
            ps.put_record(P.parent(p), R.DirRecord(name=P.basename(P.parent(p))))
            ps.put_record(p, rec)
            upserts.append((P.parent(p), ps.get(P.parent(p))))
            upserts.append((p, rec))
        elif kind == "overwrite" and live:
            p = live[len(a) % len(live)]
            rec = R.FileRecord(name=P.basename(p), text=f"over_{b}")
            ps.put_record(p, rec)
            upserts.append((p, rec))
        elif kind == "unlink" and len(live) > 1:
            p = live.pop(len(b) % len(live))
            ps.delete_record(p)
            unlinks.append(p)
            upserts = [(q, r) for q, r in upserts if q != p]
    delta = TS.TensorDelta(epoch=1, upserts=upserts, unlinks=unlinks)
    # mode="rebuild" is the byte-identical path (row ids re-rank exactly
    # like a fresh freeze); the in-place patch path is logically
    # equivalent but keeps stable row ids — tested separately below
    got_wiki, got_recs = TS.apply_delta(wiki, recs, delta, mode="rebuild")
    want_wiki, want_recs = TS.freeze_with_records(ps)
    assert got_wiki.paths == want_wiki.paths
    assert got_recs == want_recs
    assert np.array_equal(np.asarray(got_wiki.keys_hi),
                          np.asarray(want_wiki.keys_hi))
    assert np.array_equal(np.asarray(got_wiki.keys_lo),
                          np.asarray(want_wiki.keys_lo))
    assert np.array_equal(np.asarray(got_wiki.child_offsets),
                          np.asarray(want_wiki.child_offsets))
    assert np.array_equal(np.asarray(got_wiki.child_rows),
                          np.asarray(want_wiki.child_rows))
    assert np.array_equal(np.asarray(got_wiki.lex_tokens),
                          np.asarray(want_wiki.lex_tokens))
    assert got_wiki.n_pinned == want_wiki.n_pinned


# ---------------------------------------------------------------------------
# ISSUE 6: in-place patch refresh ≡ full rebuild (logical equivalence)
# ---------------------------------------------------------------------------
def _linked_store(norm):
    """Store whose DirRecords actually advertise their children, so the
    children CSR / overlay paths carry real content."""
    kids: dict[str, set] = {}
    for p in norm:
        kids.setdefault(P.parent(p), set()).add(P.basename(p))
    ps = PathStore(DictKV())
    ps.put_record("/", R.DirRecord(
        name="", sub_dirs=sorted(P.basename(d) for d in kids)))
    for d in sorted(kids):
        ps.put_record(d, R.DirRecord(name=P.basename(d),
                                     files=sorted(kids[d])))
    for p in norm:
        ps.put_record(p, R.FileRecord(name=P.basename(p), text="t"))
    return ps, kids


def _apply_linked_mutations(ps, kids, live, mutations):
    """Mutate the linked store + build the matching TensorDelta rows
    (parent records ride along, like WikiWriter admissions would)."""
    ups: dict[str, object] = {}
    unlinks: list[str] = []

    def _upsert_parent(dim):
        rec = R.DirRecord(name=P.basename(dim), files=sorted(kids[dim]))
        ps.put_record(dim, rec)
        ups[dim] = rec

    def _upsert_root():
        rec = R.DirRecord(name="", sub_dirs=sorted(
            P.basename(d) for d in kids if kids[d]))
        ps.put_record("/", rec)
        ups["/"] = rec

    for kind, a, b in mutations:
        if kind == "append":
            p = P.normalize(f"/{a}/x_{b}")
            dim = P.parent(p)
            if dim not in kids or not kids[dim]:
                kids.setdefault(dim, set())
                _upsert_root()
            kids[dim].add(P.basename(p))
            _upsert_parent(dim)
            rec = R.FileRecord(name=P.basename(p), text="new")
            ps.put_record(p, rec)
            ups[p] = rec
            if p not in live:
                live.append(p)
            unlinks = [q for q in unlinks if q != p]
        elif kind == "overwrite" and live:
            p = live[len(a) % len(live)]
            rec = R.FileRecord(name=P.basename(p), text=f"over_{b}")
            ps.put_record(p, rec)
            ups[p] = rec
        elif kind == "unlink" and len(live) > 1:
            p = live.pop(len(b) % len(live))
            dim = P.parent(p)
            kids[dim].discard(P.basename(p))
            ps.delete_record(p)
            _upsert_parent(dim)
            unlinks.append(p)
            ups.pop(p, None)
    return list(ups.items()), unlinks


@settings(max_examples=25, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=2, max_size=16),
       st.lists(st.tuples(st.sampled_from(["append", "overwrite", "unlink"]),
                          seg, seg),
                min_size=1, max_size=10))
def test_patch_matches_rebuild_logical(paths, mutations):
    norm = sorted({P.normalize(p) for p in paths})
    ps, kids = _linked_store(norm)
    wiki, recs = TS.freeze_with_records(ps)
    upserts, unlinks = _apply_linked_mutations(ps, kids, list(norm), mutations)
    delta = TS.TensorDelta(epoch=1, upserts=upserts, unlinks=unlinks)
    got_wiki, got_recs, info = TS.apply_delta_ex(wiki, recs, delta)
    want_wiki, want_recs = TS.freeze_with_records(ps)
    assert TS.logical_state(got_wiki, got_recs) == \
        TS.logical_state(want_wiki, want_recs), info
    # the query helpers run over the patched views too
    live_paths = sorted(got_wiki.row_of)
    rows = TS.batched_get(got_wiki, live_paths)
    assert all(got_wiki.paths[r] == p for r, p in zip(rows, live_paths))
    assert TS.batched_get(got_wiki, ["/definitely/not_here"])[0] == -1
    for probe in [p for p in live_paths if P.depth(p) >= 2][:3]:
        assert sorted(TS.search_prefix(got_wiki, P.parent(probe))) == \
            sorted(ps.search(P.parent(probe)))


def test_small_delta_patches_in_place():
    norm = [f"/d{i}/f{j}" for i in range(4) for j in range(8)]
    ps, kids = _linked_store(norm)
    wiki, recs = TS.freeze_with_records(ps)
    rows_before = dict(wiki.row_of)
    upserts, unlinks = _apply_linked_mutations(
        ps, kids, list(norm),
        [("append", "d1", "aa"), ("overwrite", "x", "y"),
         ("unlink", "q", "zz")])
    delta = TS.TensorDelta(epoch=1, upserts=upserts, unlinks=unlinks)
    got, recs2, info = TS.apply_delta_ex(wiki, recs, delta, mode="patch")
    assert info.kind == "patch" and got.refresh_kind == "patch"
    assert got.n_dead == len(unlinks)
    # stable row ids: every surviving path keeps its slot
    for p, r in got.row_of.items():
        if p in rows_before:
            assert rows_before[p] == r
    # appended rows land in the slack region, capacity untouched
    assert got.cap == wiki.cap and got.n_rows == len(rows_before) + 1
    want_wiki, want_recs = TS.freeze_with_records(ps)
    assert TS.logical_state(got, recs2) == \
        TS.logical_state(want_wiki, want_recs)
    # ls through the children overlay sees the appended file
    d1 = int(TS.batched_get(got, ["/d1"])[0])
    kid_paths = {got.paths[r] for r in TS.ls_rows(got, d1)}
    assert kid_paths == set(ps.search("/d1")) - {"/d1"}


def test_unlink_heavy_delta_compacts():
    norm = [f"/d0/f{j}" for j in range(12)]
    ps, kids = _linked_store(norm)
    wiki, recs = TS.freeze_with_records(ps)
    muts = [("unlink", "a", f"{'b' * (j % 7)}") for j in range(8)]
    upserts, unlinks = _apply_linked_mutations(ps, kids, list(norm), muts)
    delta = TS.TensorDelta(epoch=1, upserts=upserts, unlinks=unlinks)
    got, recs2, info = TS.apply_delta_ex(wiki, recs, delta)
    assert info.kind == "rebuild" and "tombstone" in info.reason
    assert got.n_dead == 0 and sorted(got.paths) == sorted(ps.all_paths())


def test_slack_exhaustion_compacts():
    norm = [f"/d0/f{j}" for j in range(4)]
    ps, kids = _linked_store(norm)
    wiki, recs = TS.freeze_with_records(ps)
    seen = set()
    epoch = 0
    for batch in range(24):
        muts = [("append", "d0", f"g{batch}_{i}") for i in range(8)]
        upserts, unlinks = _apply_linked_mutations(ps, kids, list(norm), muts)
        epoch += 1
        delta = TS.TensorDelta(epoch=epoch, upserts=upserts, unlinks=unlinks)
        wiki, recs, info = TS.apply_delta_ex(wiki, recs, delta)
        seen.add(info.kind)
        if info.kind == "rebuild":
            assert "slack" in info.reason or "delta too large" in info.reason
            break
    assert seen == {"patch", "rebuild"}
    want_wiki, want_recs = TS.freeze_with_records(ps)
    assert TS.logical_state(wiki, recs) == \
        TS.logical_state(want_wiki, want_recs)


def test_patch_updates_pinned_count():
    norm = [f"/d{i}/f0" for i in range(3)]
    ps, kids = _linked_store(norm)
    wiki, recs = TS.freeze_with_records(ps)
    n0 = wiki.n_pinned
    upserts, unlinks = _apply_linked_mutations(
        ps, kids, list(norm), [("append", "newdim", "f")])
    delta = TS.TensorDelta(epoch=1, upserts=upserts, unlinks=unlinks)
    got, recs2, info = TS.apply_delta_ex(wiki, recs, delta, mode="patch")
    assert info.kind == "patch" and info.pinned_changed
    assert got.n_pinned == n0 + 1           # "/newdim" joined the hot set
    want_wiki, _ = TS.freeze_with_records(ps)
    assert got.n_pinned == want_wiki.n_pinned
    assert sorted(got.paths[r] for r in got.pinned_rows()) == \
        sorted(p for p in ps.all_paths() if P.depth(p) <= 1)
