"""Device-resident tensorized path index ≡ host PathStore (property)."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core import tensorstore as TS
from repro.core.store import DictKV, PathStore

seg = st.text(alphabet="abcdefgh_", min_size=1, max_size=6)


def _store_from_paths(paths):
    ps = PathStore(DictKV())
    ps.put_record("/", R.DirRecord(name=""))
    for p in paths:
        rec = (R.DirRecord(name=P.basename(p)) if P.depth(p) < 2
               else R.FileRecord(name=P.basename(p), text="t"))
        ps.put_record(p, rec)
    return ps


@settings(max_examples=20, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=1, max_size=24))
def test_lookup_roundtrip(paths):
    norm = sorted({P.normalize(p) for p in paths})
    dims = sorted({P.parent(p) for p in norm})
    ps = _store_from_paths(dims + norm)
    wiki = TS.freeze(ps)
    rows = TS.batched_get(wiki, wiki.paths)
    assert all(wiki.paths[r] == p for r, p in zip(rows, wiki.paths))
    miss = TS.batched_get(wiki, ["/definitely/not_here"])
    assert miss[0] == -1


@settings(max_examples=20, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=1, max_size=20),
       seg)
def test_prefix_search_matches_host(paths, probe):
    norm = sorted({P.normalize(p) for p in paths})
    dims = sorted({P.parent(p) for p in norm})
    ps = _store_from_paths(dims + norm)
    wiki = TS.freeze(ps)
    prefix = "/" + probe
    host = set(ps.search(prefix))
    dev = set(TS.search_prefix(wiki, prefix))
    assert dev == host


def test_ls_rows_matches_children(built_wiki):
    pipe, _ = built_wiki
    wiki = TS.freeze(pipe.store)
    root_row = int(TS.batched_get(wiki, ["/"])[0])
    kid_rows = TS.ls_rows(wiki, root_row)
    kid_paths = {wiki.paths[r] for r in kid_rows}
    _, host_kids = pipe.store.ls("/")
    assert kid_paths == set(host_kids)


def test_navigate_rows(built_wiki):
    pipe, _ = built_wiki
    wiki = TS.freeze(pipe.store)
    ent = next(p for p in pipe.store.all_paths()
               if P.node_type(p) == P.NODE_ENTITY and not P.is_reserved(p))
    rows = TS.navigate_rows(wiki, ent)
    assert rows[-1] >= 0 and wiki.paths[rows[-1]] == ent
    assert rows[0] >= 0 and wiki.paths[rows[0]] == "/"


def test_pinned_prefix_counts_dimensions(built_wiki):
    pipe, _ = built_wiki
    wiki = TS.freeze(pipe.store)
    n_dims = sum(1 for p in pipe.store.all_paths() if P.depth(p) <= 1)
    assert wiki.n_pinned == n_dims


# ---------------------------------------------------------------------------
# ISSUE 2: TensorDelta incremental refresh ≡ full re-freeze (property)
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.sets(st.builds(lambda a, b: f"/{a}/{b}", seg, seg),
               min_size=2, max_size=16),
       st.lists(st.tuples(st.sampled_from(["append", "overwrite", "unlink"]),
                          seg, seg),
                min_size=1, max_size=10))
def test_apply_delta_matches_refreeze(paths, mutations):
    norm = sorted({P.normalize(p) for p in paths})
    dims = sorted({P.parent(p) for p in norm})
    ps = _store_from_paths(dims + norm)
    wiki, recs = TS.freeze_with_records(ps)
    live = list(norm)
    upserts, unlinks = [], []
    for kind, a, b in mutations:
        if kind == "append":
            p = P.normalize(f"/{a}/x_{b}")
            rec = R.FileRecord(name=P.basename(p), text="new")
            ps.put_record(P.parent(p), R.DirRecord(name=P.basename(P.parent(p))))
            ps.put_record(p, rec)
            upserts.append((P.parent(p), ps.get(P.parent(p))))
            upserts.append((p, rec))
        elif kind == "overwrite" and live:
            p = live[len(a) % len(live)]
            rec = R.FileRecord(name=P.basename(p), text=f"over_{b}")
            ps.put_record(p, rec)
            upserts.append((p, rec))
        elif kind == "unlink" and len(live) > 1:
            p = live.pop(len(b) % len(live))
            ps.delete_record(p)
            unlinks.append(p)
            upserts = [(q, r) for q, r in upserts if q != p]
    delta = TS.TensorDelta(epoch=1, upserts=upserts, unlinks=unlinks)
    got_wiki, got_recs = TS.apply_delta(wiki, recs, delta)
    want_wiki, want_recs = TS.freeze_with_records(ps)
    assert got_wiki.paths == want_wiki.paths
    assert got_recs == want_recs
    assert np.array_equal(np.asarray(got_wiki.keys_hi),
                          np.asarray(want_wiki.keys_hi))
    assert np.array_equal(np.asarray(got_wiki.keys_lo),
                          np.asarray(want_wiki.keys_lo))
    assert np.array_equal(np.asarray(got_wiki.child_offsets),
                          np.asarray(want_wiki.child_offsets))
    assert np.array_equal(np.asarray(got_wiki.child_rows),
                          np.asarray(want_wiki.child_rows))
    assert np.array_equal(np.asarray(got_wiki.lex_tokens),
                          np.asarray(want_wiki.lex_tokens))
    assert got_wiki.n_pinned == want_wiki.n_pinned
