"""Per-arch smoke (reduced configs) + decode↔prefill consistency +
training sanity.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config
from repro.models import model as M
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_init


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.RandomState(seed)
    toks = rng.randint(4, cfg.vocab, size=(B, S + 1)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jnp.asarray(
            rng.randn(B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.randn(B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_arch_smoke_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, seed=0)
    batch = _batch(cfg, B=2, S=16)
    logits = T.forward(params, batch, cfg)
    S_total = 16 + (cfg.n_prefix_embeds if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = T.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", all_arch_ids())
def test_arch_smoke_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, seed=0)
    opt = adamw_init(params, AdamWConfig(lr=1e-3))
    step = M.make_train_step(cfg, AdamWConfig(lr=1e-3))
    batch = _batch(cfg)
    p2, o2, aux = step(params, opt, batch)
    assert bool(jnp.isfinite(aux["loss"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", all_arch_ids())
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, seed=0)
    B = 2
    state = T.init_decode_state(cfg, B, 32)
    serve = M.make_serve_step(cfg)
    batch = {"tokens": jnp.ones((B,), jnp.int32),
             "lengths": jnp.zeros((B,), jnp.int32)}
    if cfg.is_encdec:
        batch["enc_out"] = jnp.zeros((B, 8, cfg.d_model), jnp.float32)
    tok, logits, state2 = serve(params, state, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert tok.shape == (B,)
    if cfg.padded_vocab != cfg.vocab:
        assert int(tok.max()) < cfg.vocab   # pad ids masked


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "xlstm-350m",
                                  "jamba-v0.1-52b", "dbrx-132b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode over t tokens reproduces the prefill logits —
    the KV-cache/recurrent-state correctness test, per family.

    MoE capacity is raised so prefill (8 tokens) and decode (1 token) see
    identical routing — capacity drops are load-dependent by design and
    tested separately (test_moe_capacity_drop_graceful)."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        from dataclasses import replace
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=64.0))
    params = M.init_params(cfg, seed=1)
    B, S = 1, 8
    rng = np.random.RandomState(0)
    toks = rng.randint(4, cfg.vocab, size=(B, S)).astype(np.int32)
    full_logits = T.forward(params, {"tokens": jnp.asarray(toks)}, cfg)
    state = T.init_decode_state(cfg, B, 32)
    got = []
    for t in range(S):
        logits, state = T.decode_step(
            params, state, jnp.asarray(toks[:, t]),
            jnp.full((B,), t, jnp.int32), cfg)
        got.append(np.asarray(logits))
    got = np.stack(got, axis=1)            # (B, S, V)
    np.testing.assert_allclose(got, np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_router_training_reduces_loss():
    cfg = get_config("wikikv-router").reduced(d_model=64, vocab=512)
    params = M.init_params(cfg, seed=0)
    opt = adamw_init(params, AdamWConfig(lr=3e-3))
    step = jax.jit(M.make_train_step(cfg, AdamWConfig(lr=3e-3),
                                     total_steps=30))
    batch = _batch(cfg, B=8, S=32, seed=3)
    losses = []
    for _ in range(30):
        params, opt, aux = step(params, opt, batch)
        losses.append(float(aux["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_moe_capacity_drop_graceful():
    """Tokens beyond expert capacity drop without NaNs (GShard behavior)."""
    cfg = get_config("dbrx-132b").reduced()
    from dataclasses import replace
    cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=0.25))
    params = M.init_params(cfg, seed=0)
    loss = T.loss_fn(params, _batch(cfg), cfg)
    assert bool(jnp.isfinite(loss))


def test_model_flops_accounting():
    cfg = get_config("kimi-k2-1t-a32b")
    total = sum(np.prod(l.shape)
                for l in jax.tree.leaves(M.abstract_params(cfg)))
    active = M._active_params(cfg)
    assert total > 1.0e12                  # the 1T config is real
    assert 25e9 < active < 40e9            # ≈ a32b
    mf = M.model_flops(cfg, M.SHAPES["train_4k"])
    assert abs(mf - 6 * active * 4096 * 256) / mf < 1e-6
