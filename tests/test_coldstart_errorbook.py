"""IASI cold-start (filter Φ, positioning 𝒫, scaffold) + Error Book."""
import json

from repro.core import paths as P
from repro.core import records as R
from repro.core.coldstart import (POSITIONING_PATH, cold_start,
                                  ingestion_filter, load_positioning,
                                  sample_corpus)
from repro.core.consistency import WikiWriter
from repro.core.errorbook import ErrorBook, detect_errors, run_errorbook
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import ConstructionPipeline, PipelineConfig
from repro.core.schema import SchemaParams
from repro.core.store import DictKV, PathStore


def test_filter_drops_seven_categories():
    docs = [
        {"id": "greet", "text": "Happy new year to all readers! " * 5},
        {"id": "event", "text": "Announcing our meetup, save the date. " * 5},
        {"id": "ad", "text": "Limited time offer: discount inside! " * 5},
        {"id": "links", "text": "http://a.x http://b.x http://c.x h " * 4},
        {"id": "short", "text": "ok."},
        {"id": "real1", "text": "A reflective essay on the author's craft, "
                                "with sustained original analysis of the "
                                "period, its debates and its letters."},
        {"id": "real1b", "text": "A reflective essay on the author's craft, "
                                 "with sustained original analysis of the "
                                 "period, its debates and its letters."},
    ]
    rep = ingestion_filter(docs)
    kept_ids = {d["id"] for d in rep.kept}
    assert kept_ids == {"real1"}
    assert rep.dropped["republication"] == ["real1b"]
    assert rep.dropped["seasonal_greeting"] == ["greet"]
    assert rep.dropped["event_announcement"] == ["event"]
    assert rep.dropped["advertisement"] == ["ad"]
    assert rep.dropped["link_farm"] == ["links"]
    assert rep.dropped["too_short"] == ["short"]


def test_sample_fixed_size_and_stable_under_append(corpus_and_questions):
    docs, _ = corpus_and_questions
    s1 = sample_corpus(docs, 10, seed=3)
    s2 = sample_corpus(docs + [{"id": "zzz_new", "text": "x" * 200}],
                       10, seed=3)
    ids1 = [d["id"] for d in s1]
    # stability: appending corpus changes the sample by at most one element
    ids2 = [d["id"] for d in s2]
    assert len(set(ids1) & set(ids2)) >= 9


def test_coldstart_materializes_scaffold_and_positioning(corpus_and_questions):
    docs, _ = corpus_and_questions
    store = PathStore(DictKV())
    w = WikiWriter(store)
    res = cold_start(w, docs, HeuristicOracle(), SchemaParams(),
                     sample_size=16)
    assert res.n_dimensions >= 2
    root = store.get("/")
    assert isinstance(root, R.DirRecord) and len(root.sub_dirs) >= 2
    # 𝒫 is durable, first-class, but unadvertised
    pos = load_positioning(store)
    assert pos and "focus" in pos and "ingestion_bias" in pos
    assert "_meta" not in root.sub_dirs


def test_errorbook_detects_and_repairs():
    store = PathStore(DictKV())
    w = WikiWriter(store)
    w.ensure_root()
    w.admit("/d", R.DirRecord(name="d"))
    w.admit("/sources/digests/ok", R.FileRecord(name="ok", text="digest"))
    w.admit("/d/bad_links", R.FileRecord(
        name="bad_links",
        text="see [[/sources/digests/missing]] and [[/sources/digests/ok]]",
        meta=R.FileMeta(sources=["/sources/digests/ok", "http://external"])))
    w.admit("/d/unsupported", R.FileRecord(
        name="unsupported", text="fact: year=1923", meta=R.FileMeta()))
    w.admit("/d/contra_a", R.FileRecord(
        name="contra_a", text="fact: birth=1881",
        meta=R.FileMeta(sources=["/sources/digests/ok"])))
    w.admit("/d/contra_b", R.FileRecord(
        name="contra_b", text="fact: birth=1882", meta=R.FileMeta()))

    book, report = run_errorbook(w, HeuristicOracle(), with_llm_pass=True)
    assert report.found.get("dangling_wikilink")
    assert report.found.get("malformed_citation")
    assert report.found.get("unsupported_fact")
    assert report.found.get("cross_page_contradiction")
    # deterministic repairs applied
    rec = store.get("/d/bad_links")
    assert "[[/sources/digests/missing]]" not in rec.text
    assert "[[/sources/digests/ok]]" in rec.text          # good link kept
    assert all(P.is_prefix(P.SOURCES_PREFIX, s) for s in rec.meta.sources)
    assert store.get("/d/unsupported").meta.confidence <= 0.3
    # llm repair: contradiction resolved toward the sourced binding
    assert "fact: birth=1881" in store.get("/d/contra_b").text
    # constraint rules accumulated + persisted
    assert "do-not-link:/sources/digests/missing" in book.rules
    book2 = ErrorBook.load(store)
    assert book2.rules == book.rules                      # cross-run persist


def test_errorbook_constraints_prevent_reintroduction():
    """Rules persisted in an earlier run keep taking effect later."""
    store = PathStore(DictKV())
    book = ErrorBook()
    book.add_rule("do-not-link:/sources/digests/bad")
    book.bad_link_targets.append("/sources/digests/bad")
    book.save(store)
    book2 = ErrorBook.load(store)
    assert "/sources/digests/bad" in book2.bad_link_targets
    assert book2.ingestion_constraints() == book.rules


def test_pipeline_end_to_end(built_wiki):
    pipe, questions = built_wiki
    stats = pipe.stats
    assert stats.ingested > 30
    assert stats.digests == stats.ingested
    # sources hoisted once (no duplication under entities)
    for path in pipe.store.all_paths():
        if P.node_type(path) == P.NODE_ENTITY:
            rec = pipe.store.get(path)
            if isinstance(rec, R.FileRecord):
                for s in rec.meta.sources:
                    assert P.is_prefix(P.SOURCES_PREFIX, s)
