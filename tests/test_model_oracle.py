"""ModelOracle: a zoo LM behind the Oracle interface, driving NAV."""
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.navigate import Navigator, UnitBudget, check_progressive
from repro.core.oracle import ROUTE_ENUMERATE
from repro.data.tokenizer import HashTokenizer
from repro.models import model as M
from repro.runtime.model_oracle import ModelOracle


def _oracle():
    cfg = get_config("wikikv-router").reduced(d_model=32, vocab=512,
                                              n_layers=2)
    tok = HashTokenizer(vocab_size=cfg.vocab).fit(
        ["the quick brown fox jumps over the lazy dog " * 4])
    params = M.init_params(cfg, seed=0)
    return ModelOracle(cfg, params, tok)


def test_classify_regex_fast_path():
    o = _oracle()
    assert o.classify_query("Which dimensions exist?") == ROUTE_ENUMERATE


def test_classify_lm_path_deterministic():
    o = _oracle()
    c1 = o.classify_query("tell me about the estrangement")
    c2 = o.classify_query("tell me about the estrangement")
    assert c1 == c2 and c1 in ("LOOKUP", "AGGREGATE")


def test_needs_deeper_empty_content():
    o = _oracle()
    assert o.needs_deeper("anything at all", "") is True


def test_model_oracle_drives_nav(built_wiki):
    pipe, questions = built_wiki
    o = _oracle()
    nav = Navigator(pipe.store, o)
    results, trace = nav.nav(questions[0].text, UnitBudget(200))
    assert check_progressive(results)
    assert trace.tool_calls > 0
