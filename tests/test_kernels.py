"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp oracles,
interpret mode (kernel-body semantics validated on CPU; TPU is target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_router import moe_router
from repro.kernels.path_lookup import pad_keys, path_lookup
from repro.kernels.prefix_search import prefix_search
from repro.kernels.rmsnorm import rmsnorm

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,causal",
    [
        (2, 4, 2, 64, 64, 32, True),
        (1, 8, 1, 32, 128, 16, True),     # chunked prefill: Sq < Skv
        (2, 2, 2, 64, 64, 64, False),
        (1, 4, 4, 128, 128, 8, True),
    ])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, causal, dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (B, Hq, Sq, D), dtype)
    k = jax.random.normal(kk, (B, Hkv, Skv, D), dtype)
    v = jax.random.normal(kv, (B, Hkv, Skv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D,block_k",
    [(2, 8, 4, 256, 32, 64), (1, 4, 1, 512, 64, 128), (3, 2, 2, 128, 16, 32)])
def test_decode_attention_sweep(B, Hq, Hkv, S, D, block_k, dtype):
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (B, Hq, D), dtype)
    kc = jax.random.normal(kk, (B, Hkv, S, D), dtype)
    vc = jax.random.normal(kv, (B, Hkv, S, D), dtype)
    lens = jnp.asarray([(S // 2 + 7 * i) % S + 1 for i in range(B)], jnp.int32)
    out = decode_attention(q, kc, vc, lens, block_k=block_k)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_chunked_attention_matches_full():
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (2, 4, 2048, 32))
    k = jax.random.normal(kk, (2, 2, 2048, 32))
    v = jax.random.normal(kv, (2, 2, 2048, 32))
    a = ref.attention_ref(q, k, v, causal=True)
    b = ref.chunked_attention_ref(q, k, v, causal=True, chunk=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-6)


@pytest.mark.parametrize("T,E,k,bt", [(256, 16, 4, 64), (128, 384, 8, 128),
                                      (512, 8, 2, 256)])
def test_moe_router_sweep(T, E, k, bt):
    logits = jax.random.normal(KEY, (T, E), jnp.float32)
    w, i = moe_router(logits, k, block_t=bt)
    wr, ir = ref.moe_router_ref(logits, k)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)
    assert jnp.all(i == ir)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,scaled", [((7, 130), True), ((4, 32, 64), True),
                                          ((16, 256), False)])
def test_rmsnorm_sweep(shape, scaled, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32) \
        if scaled else None
    out = rmsnorm(x, s, block_t=8)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("N,Q,bq", [(1000, 301, 128), (130, 40, 32),
                                    (5000, 64, 64)])
def test_path_lookup_sweep(N, Q, bq):
    rs = np.random.RandomState(N)
    keys64 = np.unique(rs.randint(0, 2**63, size=N).astype(np.uint64))
    khi = (keys64 >> np.uint64(32)).astype(np.uint32)
    klo = (keys64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    khi_p, klo_p = pad_keys(khi, klo)
    qidx = rs.randint(0, len(keys64), size=Q)
    qhi = np.concatenate([khi[qidx], np.array([1, 2], np.uint32)])
    qlo = np.concatenate([klo[qidx], np.array([3, 4], np.uint32)])
    got = path_lookup(jnp.asarray(khi_p), jnp.asarray(klo_p),
                      jnp.asarray(qhi), jnp.asarray(qlo), block_q=bq)
    want = ref.path_lookup_ref(jnp.asarray(khi), jnp.asarray(klo),
                               jnp.asarray(qhi), jnp.asarray(qlo))
    assert jnp.all(got == want)


def test_prefix_search_semantics():
    paths = ["/", "/a", "/a/b", "/ab", "/a/bc", "/sources/digests/x", "/b/c"]
    L = 32
    toks = np.zeros((len(paths), L), np.uint8)
    for i, p in enumerate(paths):
        b = p.encode()
        toks[i, :len(b)] = np.frombuffer(b, np.uint8)
    prefs = np.zeros((2, L), np.uint8)
    for i, p in enumerate(["/a", "/sources"]):
        b = p.encode()
        prefs[i, :len(b)] = np.frombuffer(b, np.uint8)
    plens = np.array([2, 8], np.int32)
    bm = np.asarray(prefix_search(jnp.asarray(toks), jnp.asarray(prefs),
                                  jnp.asarray(plens), block_n=4))
    col = bm[:, 0]
    assert col[1] and col[2] and col[4]
    assert not col[3] and not col[0]       # "/ab" and "/" excluded
    assert bm[5, 1] and bm[:, 1].sum() == 1


@pytest.mark.parametrize("N,L,Q,bn", [(100, 48, 3, 32), (513, 96, 5, 128)])
def test_prefix_search_sweep(N, L, Q, bn):
    rs = np.random.RandomState(L)
    alphabet = np.frombuffer(b"abcd/", np.uint8)
    toks = alphabet[rs.randint(0, 5, size=(N, L))].astype(np.uint8)
    toks[:, 0] = ord("/")
    prefs = alphabet[rs.randint(0, 5, size=(Q, L))].astype(np.uint8)
    prefs[:, 0] = ord("/")
    plens = rs.randint(1, 10, size=Q).astype(np.int32)
    got = prefix_search(jnp.asarray(toks), jnp.asarray(prefs),
                        jnp.asarray(plens), block_n=bn)
    want = jnp.stack(
        [ref.prefix_search_ref(jnp.asarray(toks), jnp.asarray(prefs[i]),
                               jnp.asarray(plens[i])) for i in range(Q)],
        axis=1)
    assert jnp.all(got == want)


@pytest.mark.parametrize("N,Q,n_pin,bq", [(1000, 301, 5, 128),
                                          (130, 40, 1, 32),
                                          (5000, 64, 33, 64),
                                          (512, 96, 0, 32)])
def test_path_lookup_pinned_parity(N, Q, n_pin, bq):
    """Level-0 VMEM pinned probe: kernel ≡ pinned oracle ≡ plain binary
    search (a consistent staging must never change any answer — pinned
    hits short-circuit, everything else falls through to HBM)."""
    from repro.kernels.path_lookup import pad_pinned
    rs = np.random.RandomState(N + n_pin)
    keys64 = np.unique(rs.randint(0, 2**63, size=N).astype(np.uint64))
    khi = (keys64 >> np.uint64(32)).astype(np.uint32)
    klo = (keys64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    khi_p, klo_p = pad_keys(khi, klo)
    # pin a deterministic subset; staged position == sorted-table rank
    pin_rows = rs.choice(len(keys64), size=min(n_pin, len(keys64)),
                         replace=False).astype(np.int32)
    pinned = pad_pinned(khi[pin_rows], klo[pin_rows], pin_rows)
    # queries: pinned hits, unpinned hits, misses
    qidx = rs.randint(0, len(keys64), size=Q)
    qhi = np.concatenate([khi[qidx], khi[pin_rows], np.array([1, 2], np.uint32)])
    qlo = np.concatenate([klo[qidx], klo[pin_rows], np.array([3, 4], np.uint32)])
    got = path_lookup(jnp.asarray(khi_p), jnp.asarray(klo_p),
                      jnp.asarray(qhi), jnp.asarray(qlo),
                      pinned=tuple(jnp.asarray(a) for a in pinned),
                      block_q=bq)
    oracle = ref.path_lookup_pinned_ref(
        jnp.asarray(khi), jnp.asarray(klo), jnp.asarray(qhi),
        jnp.asarray(qlo), *(jnp.asarray(a) for a in pinned))
    plain = ref.path_lookup_ref(jnp.asarray(khi), jnp.asarray(klo),
                                jnp.asarray(qhi), jnp.asarray(qlo))
    assert jnp.all(got == oracle)
    assert jnp.all(got == plain)
