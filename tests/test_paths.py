"""Path-as-key encoding laws (paper §IV-A) — property-based."""
import pytest
from hypothesis import given, strategies as st

from repro.core import paths as P

segment = st.text(
    alphabet=st.characters(blacklist_characters="/\x00",
                           blacklist_categories=("Cs",)),
    min_size=1, max_size=12,
).filter(lambda s: s.strip() and s not in (".", ".."))

path_strategy = st.lists(segment, min_size=0, max_size=5).map(
    lambda segs: "/" + "/".join(segs))


@given(path_strategy)
def test_normalize_idempotent(p):
    n = P.normalize(p)
    assert P.normalize(n) == n


@given(path_strategy)
def test_normalize_no_trailing_slash(p):
    n = P.normalize(p)
    assert n == "/" or not n.endswith("/")


@given(st.lists(segment, min_size=1, max_size=5))
def test_parent_child_roundtrip(segs):
    p = P.normalize("/" + "/".join(segs))
    for seg in ["x1", "y_2"]:
        c = P.child(p, seg)
        assert P.parent(c) == p
        assert P.basename(c) == seg


@given(path_strategy, path_strategy)
def test_prefix_segment_aware(a, b):
    a, b = P.normalize(a), P.normalize(b)
    if P.is_prefix(a, b):
        assert b == a or b.startswith(a + "/") or a == "/"


def test_prefix_not_substring():
    assert P.is_prefix("/a", "/a/b")
    assert not P.is_prefix("/a", "/ab")
    assert P.is_prefix("/", "/anything")


@given(path_strategy)
def test_hash_deterministic_and_64bit(p):
    n = P.normalize(p)
    h1, h2 = P.path_hash(n), P.path_hash(n)
    assert h1 == h2
    assert 0 <= h1 < 2 ** 64
    assert len(P.key_bytes(n)) == 8


@given(st.lists(path_strategy, min_size=2, max_size=20, unique=True))
def test_hash_collision_free_smallsets(ps):
    norm = {P.normalize(p) for p in ps}
    hashes = {P.path_hash(p) for p in norm}
    assert len(hashes) == len(norm)


def test_depth_budget_enforced():
    with pytest.raises(P.PathError):
        P.normalize("/a/b/c/d/e/f")          # depth 6 > D=5
    P.normalize("/a/b/c/d/e")                # depth 5 ok


def test_node_type_binding():
    assert P.node_type("/") == P.NODE_INDEX
    assert P.node_type("/dim") == P.NODE_DIMENSION
    assert P.node_type("/dim/ent") == P.NODE_ENTITY
    assert P.node_type("/sources/digests/t") == P.NODE_DIGEST
    assert P.node_type("/sources/articles/t") == P.NODE_DOCUMENT


def test_ancestors_order():
    assert list(P.ancestors("/a/b/c")) == ["/", "/a", "/a/b"]
