"""Theorem 2 (no partial reads) + OCC + invalidation — property-based.

The Theorem 2 test drives the *stepwise* writer so hypothesis can place
reader operations between the child write and the parent update (every
schedule of the two-step protocol), asserting the skip-on-miss reader
never returns an advertised-but-missing child.
"""
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core.consistency import (CASConflict, ConsistentReader,
                                    InvalidationBus, WikiWriter)
from repro.core.store import DictKV, PathStore


def _fresh():
    store = PathStore(DictKV())
    bus = InvalidationBus()
    w = WikiWriter(store, bus=bus)
    w.ensure_root()
    w.admit("/d", R.DirRecord(name="d"))
    return store, bus, w


def _check_no_partial(reader: ConsistentReader, path: str):
    out = reader.ls(path)
    if out is None:
        return
    _, resolved = out
    for cp, crec in resolved:
        assert crec is not None  # skip-on-miss never yields ⊥ children


@settings(max_examples=60, deadline=None)
@given(st.lists(st.sampled_from(["reader_ls", "reader_get"]),
                min_size=0, max_size=4),
       st.integers(0, 3))
def test_theorem2_interleavings(reads_between, n_new):
    """Interleave reads at every point of the two-step admission."""
    store, _, w = _fresh()
    reader = ConsistentReader(store)
    for i in range(n_new):
        steps = w.admit_steps(f"/d/e{i}", R.FileRecord(name=f"e{i}", text="x"))
        next(steps)                      # step 1: child written, unlinked
        for op in reads_between:
            if op == "reader_ls":
                _check_no_partial(reader, "/d")
            else:
                reader.get(f"/d/e{i}")
        # invariant mid-protocol: either unadvertised or fully readable
        _check_no_partial(reader, "/d")
        next(steps, None)                # step 2: parent updated
        _check_no_partial(reader, "/d")
        # R1 read-after-write: once admitted, the child is listed
        _, resolved = reader.ls("/d")
        assert f"/d/e{i}" in [cp for cp, _ in resolved]


def test_orphan_is_harmless():
    """A failed parent update leaves an unadvertised orphan (paper §IV-C)."""
    store, _, w = _fresh()
    reader = ConsistentReader(store)
    steps = w.admit_steps("/d/orphan", R.FileRecord(name="orphan"))
    next(steps)                          # child written; never link parent
    _, resolved = reader.ls("/d")
    assert "/d/orphan" not in [cp for cp, _ in resolved]
    assert reader.get("/d/orphan") is not None   # directly addressable


def test_unlink_reverse_order():
    store, _, w = _fresh()
    reader = ConsistentReader(store)
    w.admit("/d/e", R.FileRecord(name="e"))
    w.unlink("/d/e")
    _check_no_partial(reader, "/d")
    assert reader.get("/d/e") is None
    _, resolved = reader.ls("/d")
    assert resolved == []


def test_occ_version_cas():
    store, _, w = _fresh()
    w.admit("/d/e", R.FileRecord(name="e", text="v0"))

    def bump(rec):
        return R.FileRecord(name=rec.name, text=rec.text + "+",
                            meta=rec.meta)

    r1 = w.update_file("/d/e", bump)
    assert r1.meta.version == 1
    r2 = w.update_file("/d/e", bump)
    assert r2.meta.version == 2 and r2.text == "v0++"


def test_occ_concurrent_counter():
    """N threads increment one counter page through CAS; no lost updates."""
    store, _, w = _fresh()
    w.admit("/d/cnt", R.FileRecord(name="cnt", text="0"))

    def worker():
        for _ in range(25):
            w.update_file(
                "/d/cnt",
                lambda r: R.FileRecord(name=r.name,
                                       text=str(int(r.text) + 1),
                                       meta=r.meta),
                max_retries=200)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert store.get("/d/cnt").text == "100"
    assert store.get("/d/cnt").meta.version == 100


def test_invalidation_bounded_staleness():
    """R3: after drain (Δ), the new state is universally visible."""
    store, bus, w = _fresh()
    seen = []
    bus.subscribe(lambda ev: seen.append(ev.path))
    w.admit("/d/e", R.FileRecord(name="e"))
    assert bus.pending() > 0
    n = bus.drain()
    assert n >= 2                         # child + parent events
    assert "/d/e" in seen and "/d" in seen
    assert bus.pending() == 0


def test_cas_exhaustion_raises():
    store, _, w = _fresh()
    w.admit("/d/e", R.FileRecord(name="e", text="x"))

    real_get = store.get
    # adversarial store: version changes under the writer every read
    state = {"n": 0}

    def flaky_get(path):
        rec = real_get(path)
        if path == "/d/e" and isinstance(rec, R.FileRecord):
            state["n"] += 1
            from dataclasses import replace
            return replace(rec, meta=replace(rec.meta,
                                             version=state["n"] * 1000))
        return rec

    store.get = flaky_get
    with pytest.raises(CASConflict):
        w.update_file("/d/e", lambda r: r, max_retries=3)


def test_admission_publishes_whole_ancestor_chain():
    """The bus must be a COMPLETE dirty-path log (the device mirror's
    TensorDelta is materialized from it): admitting a deep path with no
    existing parents publishes every auto-created ancestor level."""
    store = PathStore(DictKV())
    bus = InvalidationBus()
    w = WikiWriter(store, bus=bus)
    w.ensure_root()
    seen: list[str] = []
    bus.subscribe(lambda ev: seen.append(ev.path))
    w.admit("/a/b/c", R.FileRecord(name="c", text="x"))
    bus.drain()
    # /a and /a/b were auto-created and root's child list changed
    assert {"/a/b/c", "/a/b", "/a", "/"} <= set(seen)


def test_writer_passthrough_primitives_publish():
    store = PathStore(DictKV())
    bus = InvalidationBus()
    w = WikiWriter(store, bus=bus)
    seen: list[str] = []
    bus.subscribe(lambda ev: seen.append(ev.path))
    w.put_record("/d", R.DirRecord(name="d"))
    w.delete_record("/d")
    assert w.get("/d") is None
    bus.drain()
    assert seen == ["/d", "/d"]


def test_unlink_under_navigation_skip_on_miss():
    """A reader that cached a directory listing across an unlink wave
    still never returns an advertised-but-missing child (skip-on-miss),
    and the bus carries both the parent and child invalidations."""
    store, bus, w = _fresh()
    reader = ConsistentReader(store)
    for i in range(4):
        w.admit(f"/d/e{i}", R.FileRecord(name=f"e{i}", text="x"))
    bus.drain()
    # interleave: unlink two children mid-"navigation"
    out = store.ls("/d")          # raw listing captured before the unlink
    assert out is not None
    _, advertised = out
    w.unlink("/d/e1")
    w.unlink("/d/e3")
    # the raw listing is stale, but the protocol reader drops ⊥ children
    resolved = reader.ls("/d")[1]
    got = {cp for cp, _ in resolved}
    assert "/d/e1" not in got and "/d/e3" not in got
    assert {"/d/e0", "/d/e2"} <= got
    seen: list[str] = []
    bus.subscribe(lambda ev: seen.append(ev.path))
    bus.drain()
    assert {"/d/e1", "/d/e3", "/d"} <= set(seen)
