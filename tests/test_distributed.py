"""Multi-device semantics (8 virtual CPU devices via subprocess — keeps
the main test process at 1 device as required).

Checks: (a) expert-parallel MoE ≡ single-device MoE, (b) the GPipe
schedule ≡ sequential stage application, (c) sharded train_step runs and
matches the unsharded loss, (d) a tiny dry-run cell lowers+compiles.
"""
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((2, 4), ("data", "model"))

# (a) EP MoE == local MoE
from repro.configs import get_config
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models import model as M
from dataclasses import replace
cfg = get_config("dbrx-132b").reduced()
cfg = replace(cfg, moe=replace(cfg.moe, n_experts=8, capacity_factor=8.0))
params, specs = T.init_params(jax.random.PRNGKey(0), cfg)
moe_p = params["body"]["slot0"]["moe"]
moe_p0 = jax.tree.map(lambda x: x[0], moe_p)   # one period slice
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
local = MOE.moe_apply(moe_p0, x, cfg, mesh=None)
with mesh:
    ep = jax.jit(lambda p, x: MOE.moe_apply(p, x, cfg, mesh=mesh))(moe_p0, x)
err = float(jnp.max(jnp.abs(local - ep)))
assert err < 2e-4, f"EP vs local mismatch {err}"
print("EP==local OK", err)

# (b) pipeline schedule == sequential
from repro.distributed.pipeline import PipelineSchedule, pipeline_apply
pmesh = make_mesh_compat((4, 2), ("pod", "model"))
S, Mb, F = 4, 6, 8
ws = jax.random.normal(jax.random.PRNGKey(2), (S, F, F)) * 0.3
xs = jax.random.normal(jax.random.PRNGKey(3), (Mb, 5, F))
def stage_fn(w, x):
    return jnp.tanh(x @ w)
sched = PipelineSchedule(n_stages=S, n_micro=Mb, axis="pod")
with pmesh:
    got = jax.jit(lambda w, x: pipeline_apply(stage_fn, w, x, sched, pmesh))(ws, xs)
want = xs
for i in range(S):
    want = stage_fn(ws[i], want)
err = float(jnp.max(jnp.abs(got - want)))
assert err < 1e-5, f"pipeline mismatch {err}"
print("pipeline OK", err, "bubble", sched.bubble_fraction)

# (c) sharded train step == unsharded loss
from repro.optim.adamw import AdamWConfig, adamw_init
cfg2 = get_config("qwen3-1.7b").reduced()
params2, _ = T.init_params(jax.random.PRNGKey(0), cfg2)
opt = adamw_init(params2, AdamWConfig())
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(4, cfg2.vocab, (4, 32)), jnp.int32),
         "labels": jnp.asarray(rng.randint(4, cfg2.vocab, (4, 32)), jnp.int32)}
loss_1dev = float(T.loss_fn(params2, batch, cfg2))
pspecs = M.spec_tree(cfg2)
pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                      is_leaf=lambda s: isinstance(s, P))
step = M.make_train_step(cfg2, AdamWConfig(), mesh)
with mesh:
    p2, o2, aux = jax.jit(step)(jax.device_put(params2, pshard), opt, batch)
loss_8dev = float(aux["loss"])
assert abs(loss_1dev - loss_8dev) < 5e-2, (loss_1dev, loss_8dev)
print("sharded train OK", loss_1dev, loss_8dev)

# (d) tiny dry-run style lower+compile on a 2x4 mesh (full API path)
bshard = {k: NamedSharding(mesh, P("data") if v.ndim == 1 else P("data", None))
          for k, v in batch.items()}
jitted = jax.jit(step, in_shardings=(pshard, None, bshard))
with mesh:
    compiled = jitted.lower(params2, opt, batch).compile()
assert compiled.cost_analysis() is not None
print("lower+compile OK")
print("ALL DISTRIBUTED OK")
"""


@pytest.mark.slow
def test_distributed_semantics():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "ALL DISTRIBUTED OK" in res.stdout
