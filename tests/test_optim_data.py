"""Optimizer (incl. int8 moments + compression) and data substrates."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.corpus import (AuthTraceConfig, bucket, generate_authtrace,
                               score_answer)
from repro.data.pipeline import DataPipeline
from repro.data.tokenizer import HashTokenizer
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import compress_grads, decompress_grads
from repro.optim.schedule import cosine_schedule


def _tiny_params(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "w": jax.random.normal(k, (300, 40)),          # quantizable ≥ 2D
        "b": jnp.zeros((40,)),
    }


def test_adamw_reference_behavior():
    params = _tiny_params()
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    g = jax.tree.map(jnp.ones_like, params)
    p2, opt2 = adamw_update(params, g, opt, cfg)
    # first Adam step ≈ -lr * sign(g) with bias correction
    delta = np.asarray(p2["b"] - params["b"])
    np.testing.assert_allclose(delta, -1e-2, rtol=1e-3)
    assert int(opt2["step"]) == 1


def test_int8_moments_track_f32():
    """Quantized-moment AdamW stays close to the f32 trajectory."""
    def run(state_dtype, steps=20):
        params = {"w": jnp.ones((512, 256)) * 0.5}
        cfg = AdamWConfig(lr=1e-2, state_dtype=state_dtype, weight_decay=0.0)
        opt = adamw_init(params, cfg)
        k = jax.random.PRNGKey(0)
        for i in range(steps):
            g = {"w": jax.random.normal(jax.random.fold_in(k, i),
                                        (512, 256)) * 0.1 + 0.05}
            params, opt = adamw_update(params, g, opt, cfg)
        return np.asarray(params["w"])

    ref = run("float32")
    q = run("int8")
    # trajectories agree to within a few percent of the update magnitude
    assert np.abs(ref - q).mean() < 0.02 * np.abs(ref - 0.5).mean() + 1e-3


def test_int8_state_is_small():
    params = {"w": jnp.ones((1024, 512))}
    opt = adamw_init(params, AdamWConfig(state_dtype="int8"))
    m = opt["m"]["w"]
    assert m["q"].dtype == jnp.int8 and m["q"].shape == (1024, 512)
    assert m["scale"].shape == (1024,)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_compression_error_feedback(seed):
    """EF property: quantization error is carried, so the *sum* of
    decompressed grads tracks the sum of true grads."""
    k = jax.random.PRNGKey(seed)
    true_sum = jnp.zeros((64, 33))
    sent_sum = jnp.zeros((64, 33))
    resid = None
    for i in range(6):
        g = {"w": jax.random.normal(jax.random.fold_in(k, i), (64, 33))}
        comp, resid = compress_grads(g, resid)
        deq = decompress_grads(comp)
        true_sum = true_sum + g["w"]
        sent_sum = sent_sum + deq["w"]
    err = jnp.abs(true_sum - sent_sum).max()
    # bounded by one quantization step, not accumulating over rounds
    assert float(err) < 0.1, float(err)


def test_schedule_shape():
    # first step trains at lr/warmup, not zero
    first = float(cosine_schedule(0, warmup=10, total=100))
    assert 0.05 < first <= 0.101   # 1/warmup (f32)
    assert float(cosine_schedule(10, warmup=10, total=100)) >= 0.99
    end = float(cosine_schedule(100, warmup=10, total=100))
    assert 0.05 < end < 0.15


# ---------------------------------------------------------------------------
def test_corpus_determinism_and_buckets():
    cfg = AuthTraceConfig(n_docs=40, n_questions=30, seed=11)
    d1, q1 = generate_authtrace(cfg)
    d2, q2 = generate_authtrace(cfg)
    assert [d["text"] for d in d1] == [d["text"] for d in d2]
    assert [q.text for q in q1] == [q.text for q in q2]
    buckets = {bucket(q) for q in q1}
    assert buckets == {"single", "low_multi", "high_multi"}
    # every fact shard is really placed in its fan-in many docs
    by_id = {d["id"]: d for d in d1}
    for q in q1:
        assert len(q.doc_ids) == q.fan_in
        for did, shard in zip(q.doc_ids, q.answer_shards):
            assert shard in by_id[did]["text"].lower()


def test_scoring_pack_level():
    _, qs = generate_authtrace(AuthTraceConfig(n_docs=30, n_questions=10))
    q = next(x for x in qs if x.fan_in >= 2)
    full = " ".join(q.answer_shards)
    partial = q.answer_shards[0]
    assert score_answer(full, q) == 1.0
    assert score_answer(partial, q) == 0.0


def test_tokenizer_roundtrip():
    tok = HashTokenizer(vocab_size=512).fit(["the quick brown fox " * 8])
    ids = tok.encode("the quick fox")
    assert ids[0] == 1 and ids[-1] == 2
    assert all(0 <= i < 512 for i in ids)
    assert "quick" in tok.decode(ids)


def test_pipeline_resume_exact():
    """Crash-restart determinism: resume from a snapshot replays the exact
    same batch sequence."""
    docs = [list(range(5 + i, 50 + i)) for i in range(20)]
    p1 = DataPipeline(docs, seq_len=16, global_batch=4, seed=5)
    batches = [p1.next_batch() for _ in range(6)]
    snap = None
    p2 = DataPipeline(docs, seq_len=16, global_batch=4, seed=5)
    for i in range(3):
        p2.next_batch()
    snap = p2.snapshot()
    p3 = DataPipeline(docs, seq_len=16, global_batch=4, seed=5)
    p3.restore(snap)
    for i in range(3, 6):
        b = p3.next_batch()
        np.testing.assert_array_equal(b["tokens"], batches[i]["tokens"])


def test_pipeline_dp_sharding_partitions_batch():
    docs = [list(range(100))] * 8
    full = DataPipeline(docs, seq_len=8, global_batch=4, seed=1)
    shards = [DataPipeline(docs, seq_len=8, global_batch=4, seed=1,
                           dp_rank=r, dp_size=2) for r in range(2)]
    b_full = full.next_batch()
    parts = [s.next_batch() for s in shards]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(b_full["tokens"], stacked)
