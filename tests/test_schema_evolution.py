"""Schema cost (Eq. 1), MI estimation, evolution operators, Theorem 1."""
from dataclasses import replace

from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core.consistency import WikiWriter
from repro.core.evolution import (AccessLog, CoAccessSketch, SplitCandidate,
                                  apply_access_log, apply_page_split,
                                  evolution_pass, merge_candidates,
                                  _Snapshot)
from repro.core.oracle import HeuristicOracle
from repro.core.schema import SchemaParams, schema_cost
from repro.core.store import DictKV, PathStore


def _wiki(n_dims=4, ents_per_dim=3):
    store = PathStore(DictKV())
    w = WikiWriter(store)
    w.ensure_root()
    for d in range(n_dims):
        w.admit(f"/dim{d}", R.DirRecord(name=f"dim{d}"))
        for e in range(ents_per_dim):
            w.admit(f"/dim{d}/e{e}",
                    R.FileRecord(name=f"e{e}", text=f"content {d} {e}",
                                 meta=R.FileMeta(confidence=0.7)))
    return store, w


def test_cost_terms():
    store, _ = _wiki()
    c = schema_cost(store, SchemaParams(alpha=1, beta=2, gamma=3))
    assert c.n_nodes == 1 + 4 + 12
    assert c.storage == 17
    assert c.descent > 0
    assert not c.violations


def test_fanout_violation_detected():
    store, w = _wiki(n_dims=1, ents_per_dim=3)
    params = SchemaParams(k_max=2)
    c = schema_cost(store, params)
    assert any("fanout" in v for v in c.violations)


def test_mi_coaccess():
    sketch = CoAccessSketch()
    log = AccessLog()
    # dim0+dim1 always co-accessed; dim2 independent
    for i in range(40):
        log.record({"/dim0", "/dim1"})
        log.record({"/dim2"} if i % 2 else {"/dim3"})
    sketch.merge_log(log)
    mi_01 = sketch.mutual_information("/dim0", "/dim1")
    mi_02 = sketch.mutual_information("/dim0", "/dim2")
    assert mi_01 > 0.1
    assert mi_01 > mi_02


def test_access_log_merges_into_meta():
    store, w = _wiki()
    log = AccessLog()
    log.record({"/dim0", "/dim0/e0"})
    log.record({"/dim0"})
    apply_access_log(w, log)
    assert store.get("/dim0").meta.access_count == 2
    assert store.get("/dim0/e0").meta.access_count == 1
    sk = CoAccessSketch.load(store)
    assert sk.n_queries == 2


def test_merge_candidates_and_apply():
    store, w = _wiki()
    log = AccessLog()
    for _ in range(50):
        log.record({"/dim0", "/dim1"})
        log.record({"/dim2"})
    sketch = apply_access_log(w, log)
    params = SchemaParams(theta_merge=0.05)
    cands = merge_candidates(store, sketch, params)
    assert cands and {cands[0][0], cands[0][1]} == {"/dim0", "/dim1"}
    results = evolution_pass(w, HeuristicOracle(), params, sketch=sketch)
    merged = [r for r in results if r.op == "merge" and r.committed]
    assert merged, results
    # d2 folded into d1: children reachable under the surviving dimension
    root = store.get("/")
    assert "dim1" not in root.sub_dirs
    rec, kids = store.ls("/dim0")
    # same-name entities union at segment level, contents concatenated
    assert len(kids) == 3
    e0 = store.get("/dim0/e0")
    assert "content 0 0" in e0.text and "content 1 0" in e0.text
    # access counts summed on merge
    assert rec.meta.access_count >= 50
    # Safety: every entity still reachable
    for e in range(3):
        assert store.get(f"/dim0/e{e}") is not None
    assert store.get("/dim1") is None


def _oversized_page_wiki():
    store = PathStore(DictKV())
    w = WikiWriter(store)
    w.ensure_root()
    w.admit("/dim0", R.DirRecord(name="dim0"))
    paras = []
    for head in ("alpha", "beta"):
        for i in range(6):
            paras.append(f"{head} topic paragraph {i} " + "filler words " * 40)
    w.admit("/dim0/big", R.FileRecord(
        name="big", text="\n\n".join(paras),
        meta=R.FileMeta(confidence=0.4, access_count=500)))
    # give the rest of the wiki some access mass
    w.admit("/dim0/small", R.FileRecord(
        name="small", text="tiny", meta=R.FileMeta(access_count=100)))
    return store, w


def test_page_split_applies():
    store, w = _oversized_page_wiki()
    cand = SplitCandidate(path="/dim0/big", heads=["alpha", "beta"])
    snap = _Snapshot(store)
    apply_page_split(w, cand, snap)
    hub = store.get("/dim0/big")
    assert isinstance(hub, R.DirRecord)
    a = store.get("/dim0/big/alpha")
    b = store.get("/dim0/big/beta")
    assert isinstance(a, R.FileRecord) and "alpha topic" in a.text
    assert isinstance(b, R.FileRecord) and "beta topic" in b.text
    assert "alpha topic" not in b.text     # paragraphs bucketed by head
    # rollback restores the original page exactly
    snap.rollback()
    orig = store.get("/dim0/big")
    assert isinstance(orig, R.FileRecord)
    assert store.get("/dim0/big/alpha") is None


def test_theorem1_monotone_improvement():
    """C non-increasing along the greedy trajectory (measured, not just
    estimated — the Arbiter verifies each commit)."""
    store, w = _oversized_page_wiki()
    params = SchemaParams(alpha=0.05, beta=1.0, gamma=20.0,
                          theta_merge=0.05, l_max=500)
    oracle = HeuristicOracle()
    costs = [schema_cost(store, params).total]
    for _ in range(3):
        evolution_pass(w, oracle, params)
        costs.append(schema_cost(store, params).total)
    for a, b in zip(costs, costs[1:]):
        assert b <= a + 1e-9, costs


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(1, 4), st.integers(0, 3))
def test_theorem1_random_wikis(n_dims, ents, seed):
    """Property: no evolution pass ever increases measured cost."""
    store, w = _wiki(n_dims=n_dims, ents_per_dim=ents)
    log = AccessLog()
    import random
    r = random.Random(seed)
    dims = [f"/dim{d}" for d in range(n_dims)]
    for _ in range(30):
        log.record(set(r.sample(dims, r.randint(1, min(2, n_dims)))))
    sketch = apply_access_log(w, log)
    params = SchemaParams(theta_merge=0.02)
    before = schema_cost(store, params).total
    evolution_pass(w, HeuristicOracle(), params, sketch=sketch)
    after = schema_cost(store, params).total
    assert after <= before + 1e-9
