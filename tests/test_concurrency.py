"""ISSUE 10: parallel shard fan-out, pipelined group commit, background
compaction — the concurrency surface.

Four invariant families:

1. **Scatter parity** — ``REPRO_SHARD_WORKERS=4`` returns bit-identical
   results to the serial loops for every fan-out shape (point batches,
   namespace scans, k-way merges), and executor failures propagate to
   the caller only after every sibling task has finished.
2. **Thread-safe telemetry** (satellite 3) — hammering one durable
   engine from many threads never drops an op-counter increment, and
   the block-cache hit+miss total stays exact under contention.
3. **Pipelined commit** — the advertised durable epoch only ever trails
   the sealed epoch by the one in-flight wave, worker failures re-raise
   on the caller thread before the epoch is advertised, and a drained
   pipelined store reopens byte-identical to a synchronous one.
4. **Δ = 1 under full concurrency** (satellite 4) — the epoch-pinning /
   one-wave-staleness property holds with the fan-out pool, the commit
   pipeline, and background compaction all enabled at once.
"""
import threading
import time

import pytest

from repro.core import paths as P
from repro.core import records as R
from repro.core.consistency import WikiWriter
from repro.core.engine import (BatchPlanner, DeviceEngine, HostEngine,
                               ShardedPathStore)
from repro.core.executor import CommitSequencer, ShardExecutor
from repro.core.store import MemKV, PathStore
from repro.storage import DurableKV, open_durable_store
from repro.storage import failpoints as FPS

from test_engine import _query_batches, _random_wiki


# ---------------------------------------------------------------------------
# 1. scatter parity + executor semantics
# ---------------------------------------------------------------------------
def _pair(seed: int) -> tuple[ShardedPathStore, ShardedPathStore]:
    serial = ShardedPathStore(n_shards=8, memtable_limit=32,
                              shard_workers=0)
    fanned = ShardedPathStore(n_shards=8, memtable_limit=32,
                              shard_workers=4)
    mat = _random_wiki(serial, seed)
    _random_wiki(fanned, seed)
    return serial, fanned, mat


@pytest.mark.parametrize("seed", [3, 17, 59])
def test_parallel_fanout_parity(seed):
    """Workers change WHERE per-shard work runs, never what it returns."""
    serial, fanned, mat = _pair(seed)
    q1, q2, q3, prefixes, tokens = _query_batches(mat)
    assert fanned.get_many(q1) == [serial.get(p) for p in q1]
    assert fanned.ls_many(q2) == [serial.ls(p) for p in q2]
    assert fanned.navigate_many(q3) == [serial.navigate(p) for p in q3]
    for pre in prefixes:
        assert fanned.search(pre) == serial.search(pre)
        assert fanned.search(pre, limit=3) == serial.search(pre, limit=3)
    for tok in tokens:
        assert fanned.search_contains(tok) == serial.search_contains(tok)
    assert fanned.all_paths() == serial.all_paths()
    assert fanned.count() == serial.count()
    # the batched APIs are what HostEngine routes through
    hs, hf = HostEngine(serial), HostEngine(fanned)
    assert hs.q1_get(q1) == hf.q1_get(q1)
    assert hs.q2_ls(q2) == hf.q2_ls(q2)
    assert hs.q3_navigate(q3) == hf.q3_navigate(q3)


def test_merge_is_ordered_and_limit_correct():
    """The k-way merge keeps global path order and the global first
    ``limit`` paths (each shard over-fetches its own first ``limit``)."""
    store = ShardedPathStore(n_shards=4, memtable_limit=64, shard_workers=2)
    w = WikiWriter(store, clock=lambda: 0.0)
    w.ensure_root("root")
    w.admit("/d", R.DirRecord(name="d"))
    paths = [f"/d/n{i:03d}" for i in range(40)]
    for p in paths:
        w.admit(p, R.FileRecord(name=P.basename(p), text=p))
    got = store.search("/d/")
    assert got == sorted(got) and set(paths) <= set(got)
    for lim in (1, 7, 100):
        assert store.search("/d/", limit=lim) == got[:lim]
    assert store.all_paths() == sorted(store.all_paths())


def test_executor_failure_waits_for_siblings():
    """The first scatter failure re-raises on the caller — but only
    after every sibling finished (no stray work left mutating shards)."""
    ex = ShardExecutor(workers=4)
    done = []

    def fn(i, item):
        if i == 1:
            raise RuntimeError("shard 1 down")
        time.sleep(0.02)
        done.append(i)
        return i

    with pytest.raises(RuntimeError, match="shard 1 down"):
        ex.scatter(fn, list(range(6)))
    assert sorted(done) == [0, 2, 3, 4, 5]
    ex.close()


def test_executor_serial_mode_is_inline():
    """workers=0 runs on the caller thread in item order (the RPC-shaped
    seam degrades to exactly the pre-executor for-loop)."""
    ex = ShardExecutor(workers=0)
    seen = []
    out = ex.scatter(lambda i, s: seen.append((i, threading.get_ident()))
                     or i * 10, ["a", "b", "c"])
    assert out == [0, 10, 20]
    assert [i for i, _ in seen] == [0, 1, 2]
    assert {t for _, t in seen} == {threading.get_ident()}


# ---------------------------------------------------------------------------
# 2. durable-stat thread safety (satellite 3)
# ---------------------------------------------------------------------------
def test_op_counters_exact_under_hammer(tmp_path):
    """8 threads × 300 ops: every ``_count`` increment lands (the
    read-modify-write is locked), and the block-cache hit+miss TOTAL
    equals the lookup count even though the hit/miss split is
    schedule-dependent."""
    from repro.storage.sstable import BlockCache
    kv = DurableKV(str(tmp_path / "kv"), memtable_limit=8, sync="none",
                   segment_target_bytes=64,
                   block_cache=BlockCache(capacity_bytes=256))
    keys = [f"h{i:03d}".encode() for i in range(64)]
    for i, k in enumerate(keys):
        kv.put(k, b"v" * 16)
        if i % 8 == 7:
            kv.commit_epoch(i)          # spill → reads go through segments
    kv.spill()
    base = kv.op_counts()
    n_threads, n_ops = 8, 300
    errs = []

    def hammer(t):
        try:
            for j in range(n_ops):
                assert kv.get(keys[(t * 7 + j) % len(keys)]) is not None
        except BaseException as e:      # noqa: BLE001 - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    counts = kv.op_counts()
    total = n_threads * n_ops
    assert counts["get"] - base.get("get", 0) == total
    # every probed block does exactly one cache lookup: hit+miss is exact
    lookups = (counts.get("cache_hit", 0) + counts.get("cache_miss", 0)
               - base.get("cache_hit", 0) - base.get("cache_miss", 0))
    probes = counts.get("seg_probe", 0) - base.get("seg_probe", 0)
    assert lookups >= total              # ≥1 block read per segment get
    assert probes >= total
    kv.close()


# ---------------------------------------------------------------------------
# 3. pipelined group commit
# ---------------------------------------------------------------------------
def _durable_sharded(root, **kw):
    kw.setdefault("n_shards", 2)
    kw.setdefault("sync", "none")
    return open_durable_store(str(root), **kw)


def test_pipeline_advertises_only_landed_epochs(tmp_path):
    store = _durable_sharded(tmp_path / "w", shard_workers=2,
                             commit_pipeline=True)
    w = WikiWriter(store, clock=lambda: 0.0)
    w.ensure_root("root")
    assert store.durable_epoch() == store.last_epoch()
    w.admit("/a", R.DirRecord(name="a"))
    store.commit_epoch(1)
    # wave 1 is sealed (visible, owns the epoch) but its WAL write may
    # still be in flight: the advertised durable epoch must not lead it
    assert store.last_epoch() == 1
    assert store.durable_epoch() <= 1
    assert store.commit_pipeline_depth() in (0, 1)
    w.admit("/a/b", R.FileRecord(name="b", text="b"))
    store.commit_epoch(2)               # joins wave 1 first (depth 1)
    assert store.durable_epoch() >= 1
    store.flush()                        # drain: everything durable
    assert store.durable_epoch() == store.last_epoch() == 2
    assert store.commit_pipeline_depth() == 0
    store.close()


def test_pipelined_store_reopens_identical(tmp_path):
    """Pipelined waves + close() drain a store that reopens exactly as a
    synchronous-commit twin of the same schedule."""
    roots = (tmp_path / "pipe", tmp_path / "sync")
    stores = (_durable_sharded(roots[0], shard_workers=2,
                               commit_pipeline=True),
              _durable_sharded(roots[1], commit_pipeline=False))
    for s in stores:
        _random_wiki(s, 23)
        for e in range(1, 4):
            s.put_record(f"/wave{e}", R.FileRecord(name=f"wave{e}",
                                                   text=str(e)))
            s.commit_epoch(e)
        s.close()
    a = open_durable_store(str(roots[0]), sync="none")
    b = open_durable_store(str(roots[1]), sync="none")
    assert a.all_paths() == b.all_paths()
    assert a.last_epoch() == b.last_epoch()
    for p in a.all_paths():
        assert a.get(p) == b.get(p)
    a.close()
    b.close()


def test_pipeline_worker_failure_reraises_before_advertising(tmp_path):
    """An injected crash in the off-thread WAL write parks in the
    sequencer; the NEXT commit re-raises it on the caller thread and the
    wounded epoch is never advertised durable."""
    store = _durable_sharded(tmp_path / "w", shard_workers=2,
                             commit_pipeline=True)
    w = WikiWriter(store, clock=lambda: 0.0)
    w.ensure_root("root")
    store.flush()                        # root wave durable, pipeline empty
    before = store.durable_epoch()
    w.admit("/x", R.DirRecord(name="x"))
    with FPS.armed(FPS.FailPlan(crash_at=1,
                                sites=frozenset({"wal.commit"}))):
        store.commit_epoch(before + 1)   # seal ok; off-thread write dies
        with pytest.raises(FPS.InjectedCrash):
            store.commit_epoch(before + 2)
    assert store.durable_epoch() == before
    store._sequencer = None              # wounded wave abandoned (crash)
    store.close()


def test_sequencer_empty_wave_advances_immediately():
    ex = ShardExecutor(workers=2)
    seq = CommitSequencer(ex, durable_epoch=5)
    seq.submit(6, [])
    assert seq.durable_epoch() == 6 and seq.depth() == 0
    fired = []
    seq.submit(7, [lambda: fired.append(1)])
    assert seq.depth() == 1
    seq.wait()
    assert fired == [1] and seq.durable_epoch() == 7
    seq.close()
    ex.close()


# ---------------------------------------------------------------------------
# 4. background compaction + the full-concurrency Δ = 1 property
# ---------------------------------------------------------------------------
def _drain_bg(kv, deadline=10.0):
    t0 = time.monotonic()
    while kv.compact_debt() > 0:
        if time.monotonic() - t0 > deadline:
            pytest.fail("background compaction never drained")
        time.sleep(0.005)


def test_bg_compaction_drains_off_thread(tmp_path):
    """Commits enqueue merge debt for the daemon worker instead of
    paying it inline; the worker drains it and reads stay exact."""
    kv = DurableKV(str(tmp_path / "kv"), memtable_limit=4, sync="none",
                   level_ratio=2, segment_target_bytes=48,
                   compact_budget_bytes=150, bg_compact=True)
    assert kv._bg_thread is not None and kv._bg_thread.is_alive()
    expect = {}
    for i in range(48):
        k = f"k{i % 12:02d}".encode()
        v = f"v{i:03d}".encode()
        kv.put(k, v)
        expect[k] = v
        if i % 4 == 3:
            kv.commit_epoch(i)
    _drain_bg(kv)
    assert dict(kv.scan(b"")) == expect
    kv.close()


def test_bg_worker_failure_is_sticky(tmp_path):
    """A parked background failure re-raises on the next mutation AND on
    close() — a wounded store is never cleanly committed."""
    kv = DurableKV(str(tmp_path / "kv"), memtable_limit=4, sync="none",
                   bg_compact=True)
    kv.put(b"a", b"1")
    kv.commit_epoch(1)
    kv._stop_bg()                        # park deterministically
    kv._bg_exc = RuntimeError("merge died")
    with pytest.raises(RuntimeError, match="merge died"):
        kv.put(b"b", b"2")
    with pytest.raises(RuntimeError, match="merge died"):
        kv.close()
    kv._bg_exc = None                    # abandon like a dead process
    kv._wal._f.close()
    for t in kv._tables.values():
        t.close()
    reopened = DurableKV(str(tmp_path / "kv"), memtable_limit=4,
                         sync="none")
    assert dict(reopened.scan(b"")) == {b"a": b"1"}
    reopened.close()


def test_delta_one_wave_all_features_on(tmp_path):
    """Satellite 4: the epoch-pinning / Δ = 1 staleness property with
    the fan-out pool, the commit pipeline, and background compaction all
    enabled.  Every read wave sees exactly the epoch it pinned; the
    advertised durable epoch never trails the pinned epoch by more than
    the one in-flight wave; the final state converges to a fresh
    freeze."""
    store = _durable_sharded(tmp_path / "w", n_shards=4, shard_workers=4,
                             commit_pipeline=True, bg_compact=True,
                             memtable_limit=8, segment_target_bytes=64)
    w = WikiWriter(store, clock=lambda: 0.0)
    w.ensure_root("root")
    for d in range(2):
        w.admit(f"/d{d}", R.DirRecord(name=f"d{d}", summary=f"dim {d}"))
        for e in range(3):
            w.admit(f"/d{d}/e{e}", R.FileRecord(name=f"e{e}", text=f"{d}:{e}"))
    dev = DeviceEngine.from_store(store)
    pl = BatchPlanner(dev)

    def snapshot():
        return {p: store.get(p) for p in store.all_paths()}

    pinned = snapshot()
    schedule = [("admit", d, e) for d in range(2) for e in range(3, 7)] + \
               [("unlink", d, e) for d in range(2) for e in range(3, 5)]
    for i, (kind, d, e) in enumerate(schedule):
        path = f"/d{d}/p{e}"
        probe = sorted(set(pinned) | {path})
        futs = [pl.get(p) for p in probe]
        if kind == "admit":
            pl.admit(path, R.FileRecord(name=f"p{e}", text=f"w{i}"))
        else:
            pl.unlink(path)
        pl.flush()
        for p, f in zip(probe, futs):
            assert f.value == pinned.get(p), \
                f"wave {i}: read of {p} escaped its pinned epoch"
        dev.refresh()
        assert store.last_epoch() - store.durable_epoch() <= 1
        pinned = snapshot()
    store.flush()
    assert store.durable_epoch() == store.last_epoch()
    fresh = DeviceEngine.from_store(store)
    paths = store.all_paths()
    assert dev.q1_get(paths) == fresh.q1_get(paths)
    store.close()
