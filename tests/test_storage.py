"""Durable storage tier (ISSUE 3): WAL framing + CRC, SSTable segments,
DurableKV crash recovery, and byte-identical store reopen."""
import os
import shutil

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import ConstructionPipeline, PipelineConfig
from repro.core.store import MemKV, PathStore
from repro.data.corpus import AuthTraceConfig, generate_authtrace
from repro.storage import (DurableKV, SSTable, open_durable_store,
                           write_sstable)
from repro.storage import manifest as MF
from repro.storage import wal as W
from repro.storage.lsm import WAL_NAME
from repro.storage.sstable import MISSING, TOMBSTONE


# ---------------------------------------------------------------------------
# WAL
# ---------------------------------------------------------------------------
def test_wal_commit_boundaries_and_replay(tmp_path):
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"a", b"1")
    w.append_delete(b"b")
    w.append_inval("/d0/e0")
    w.commit(1)
    w.append_put(b"c", b"3")
    w.commit(2)
    w.append_put(b"never", b"committed")   # buffered, no commit
    w.close()
    res = W.replay(p)
    assert len(res.waves) == 2
    kinds = [rec.kind for rec in res.waves[0]]
    assert kinds == [W.PUT, W.DEL, W.INV, W.COMMIT]
    assert res.waves[0][2].path == "/d0/e0"
    assert res.waves[1][0].key == b"c"
    assert res.waves[1][-1].epoch == 2
    assert res.dropped_records == 0 and not res.corrupt_tail
    assert res.valid_end == os.path.getsize(p)   # buffer never hit disk


def test_wal_corrupt_tail_detected_and_dropped(tmp_path):
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"k", b"v")
    w.commit(1)
    w.close()
    good = os.path.getsize(p)
    # flip a byte inside an appended (committed-looking) record
    w2 = W.WAL(p, sync="none")
    w2.append_put(b"x", b"y")
    w2.commit(2)
    w2.close()
    with open(p, "rb+") as f:
        f.seek(good + 10)
        b = f.read(1)
        f.seek(good + 10)
        f.write(bytes([b[0] ^ 0xFF]))
    res = W.replay(p)
    assert res.corrupt_tail
    assert len(res.waves) == 1                    # only the intact wave
    assert res.valid_end == good


def test_wal_zero_filled_torn_tail(tmp_path):
    """A zero-filled tail (torn page after power loss) frames as
    crc=0/len=0, which crc32(b'') would pass — replay must still treat
    it as corrupt and the store must reopen cleanly."""
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"k", b"v")
    w.commit(1)
    w.close()
    good = os.path.getsize(p)
    with open(p, "ab") as f:
        f.write(b"\x00" * 64)
    res = W.replay(p)
    assert res.corrupt_tail and res.valid_end == good
    assert res.waves[-1][-1].epoch == 1
    d = str(tmp_path / "kv")
    kv = DurableKV(d, sync="none")
    kv.put(b"a", b"1")
    kv.commit_epoch(1)
    kv.close()
    with open(os.path.join(d, WAL_NAME), "ab") as f:
        f.write(b"\x00" * 64)
    kv2 = DurableKV(d, sync="none")               # must not raise
    assert kv2.recovery_corrupt_tail and kv2.get(b"a") == b"1"
    kv2.close()


def test_compact_after_reopen_preserves_committed_epoch(tmp_path):
    """Regression: the manifest written by a post-reopen spill/compact
    must carry the WAL-replayed epoch — the spill truncates the WAL that
    was the only record of it."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=10**9, sync="none")
    for e in range(1, 6):
        kv.put(f"k{e}".encode(), b"v")
        kv.commit_epoch(e)
    kv.close()
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 5
    kv2.compact()                                 # spills + truncates WAL
    kv2.close()
    kv3 = DurableKV(d, sync="none")
    assert kv3.last_epoch() == 5, "compaction regressed the committed epoch"
    kv3.close()


def test_wal_torn_partial_frame(tmp_path):
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"k", b"v")
    w.commit(3)
    w.close()
    good = os.path.getsize(p)
    with open(p, "ab") as f:
        f.write(b"\x07\x00")                      # half a header
    res = W.replay(p)
    assert res.corrupt_tail and res.valid_end == good
    assert res.waves[-1][-1].epoch == 3


# ---------------------------------------------------------------------------
# SSTable
# ---------------------------------------------------------------------------
def test_sstable_get_scan_tombstones(tmp_path):
    items = sorted({f"k{i:03d}".encode(): f"v{i}".encode()
                    for i in range(100)}.items())
    items[7] = (items[7][0], TOMBSTONE)
    p = str(tmp_path / "a.seg")
    write_sstable(p, items, sync=False)
    t = SSTable(p)
    assert t.n_records == 100
    assert t.get(b"k005") == b"v5"
    assert t.get(items[7][0]) is TOMBSTONE        # delete persisted as such
    assert t.get(b"k0999") is MISSING
    assert t.get(b"a") is MISSING                 # before first key
    got = dict(t.scan(b"k01"))
    assert len(got) == 10 and got[b"k012"] == b"v12"
    assert len(list(t.iter_all())) == 100
    t.close()


def test_sstable_sparse_index_boundaries(tmp_path):
    # exactly SPARSE_EVERY-aligned + not-aligned sizes, single record
    for n in (1, 16, 17, 31):
        items = [(f"{i:04d}".encode(), b"x" * i) for i in range(n)]
        p = str(tmp_path / f"s{n}.seg")
        write_sstable(p, items, sync=False)
        t = SSTable(p)
        for k, v in items:
            assert t.get(k) == v
        assert t.get(b"zzzz") is MISSING
        t.close()


# ---------------------------------------------------------------------------
# DurableKV — crash recovery + MemKV parity
# ---------------------------------------------------------------------------
def test_tombstone_survives_spill_and_reopen(tmp_path):
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none")
    for i in range(6):
        kv.put(f"k{i}".encode(), b"old")
    kv.commit_epoch(1)                 # spills: all six live in segment 1
    kv.delete(b"k3")
    kv.put(b"k0", b"new")
    for i in range(6, 12):
        kv.put(f"k{i}".encode(), b"fresh")
    kv.commit_epoch(2)                 # spills again: tombstone in segment 2
    assert len(kv._manifest.segments) == 2
    kv.close()
    kv2 = DurableKV(d, sync="none")
    assert kv2.get(b"k3") is None, "delete resurrected across reopen"
    assert kv2.get(b"k0") == b"new"
    assert b"k3" not in dict(kv2.scan(b"k"))
    kv2.compact()                      # full merge may now drop the tombstone
    kv2.close()
    kv3 = DurableKV(d, sync="none")
    assert kv3.get(b"k3") is None
    assert kv3.get(b"k11") == b"fresh"
    kv3.close()


def test_crash_between_segment_write_and_manifest_swap(tmp_path):
    """The spill order is segment → manifest → WAL truncate; a crash
    after the segment write but before the manifest swap must lose
    nothing (WAL still holds the wave) and resurrect nothing (the orphan
    segment is swept)."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=10**9, sync="none")
    kv.put(b"a", b"1")
    kv.commit_epoch(1)
    kv.close()
    # simulate the crashed spill: an orphan segment containing records
    # that were NEVER committed, plus one committed key with a bogus value
    write_sstable(os.path.join(d, "seg_000042.seg"),
                  [(b"a", b"bogus"), (b"ghost", b"uncommitted")], sync=False)
    kv2 = DurableKV(d, sync="none")
    assert kv2.get(b"a") == b"1"                 # WAL replay wins
    assert kv2.get(b"ghost") is None             # orphan swept, not adopted
    assert not os.path.exists(os.path.join(d, "seg_000042.seg"))
    assert kv2.last_epoch() == 1
    kv2.close()


def test_uncommitted_wave_lost_committed_waves_exact(tmp_path):
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none")
    committed = {}
    for wave in range(5):
        for i in range(3):
            k = f"w{wave}k{i}".encode()
            kv.put(k, f"{wave}:{i}".encode())
            committed[k] = f"{wave}:{i}".encode()
        kv.commit_epoch(wave + 1)
    kv.put(b"uncommitted", b"x")                 # crash before commit
    del kv
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 5
    assert kv2.get(b"uncommitted") is None
    assert dict(kv2.scan(b"")) == committed
    kv2.close()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["put", "delete", "commit"]),
                          st.integers(0, 30), st.binary(min_size=0, max_size=6)),
                min_size=1, max_size=80))
def test_durablekv_matches_memkv_and_survives_reopen(tmp_path_factory, ops):
    """Acceptance property: the same op sequence applied to MemKV and
    DurableKV yields identical get/scan results — before close, and
    byte-identical again after close + reopen from disk."""
    d = str(tmp_path_factory.mktemp("kv"))
    ref = MemKV(memtable_limit=7)
    kv = DurableKV(d, memtable_limit=7, sync="none")
    epoch = 0
    for op, ki, v in ops:
        k = f"{ki:04d}".encode()
        if op == "put":
            ref.put(k, v)
            kv.put(k, v)
        elif op == "delete":
            ref.delete(k)
            kv.delete(k)
        else:
            epoch += 1
            kv.commit_epoch(epoch)
    keys = [f"{i:04d}".encode() for i in range(31)]
    assert [kv.get(k) for k in keys] == [ref.get(k) for k in keys]
    assert list(kv.scan(b"")) == list(ref.scan(b""))
    assert list(kv.scan(b"001")) == list(ref.scan(b"001"))
    kv.close()                                   # commits the open tail
    kv2 = DurableKV(d, sync="none")
    assert [kv2.get(k) for k in keys] == [ref.get(k) for k in keys]
    assert list(kv2.scan(b"")) == list(ref.scan(b""))
    kv2.close()


def test_commit_epoch_monotone_and_advance_durable(tmp_path):
    """Regression: a lagging engine (device mirror with a trailing
    counter) must not move the committed epoch backwards, and an epoch
    ADVANCE is recorded durably even when the wave carried no content."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=10**9, sync="none")
    kv.put(b"a", b"1")
    kv.commit_epoch(3)
    kv.commit_epoch(1)                 # lagging caller: clamped, no regress
    assert kv.last_epoch() == 3
    kv.commit_epoch(4)                 # content-free advance: still durable
    kv.close()
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 4
    kv2.close()


def test_manifest_atomic_and_orphan_sweep(tmp_path):
    d = str(tmp_path)
    m = MF.Manifest(segments=["seg_000001.seg"], next_seg=2, epoch=7,
                    device_epoch=5, pending_inval=["/a"])
    MF.store(d, m, sync=False)
    assert not os.path.exists(os.path.join(d, MF.MANIFEST_NAME + ".tmp"))
    m2 = MF.load(d)
    assert (m2.segments, m2.next_seg, m2.epoch, m2.device_epoch,
            m2.pending_inval) == (["seg_000001.seg"], 2, 7, 5, ["/a"])
    open(os.path.join(d, "seg_000009.seg"), "wb").close()
    removed = MF.sweep_orphans(d, m2)
    assert removed == ["seg_000009.seg"]


# ---------------------------------------------------------------------------
# PathStore / ShardedPathStore over the durable tier
# ---------------------------------------------------------------------------
def _store_signature(store):
    """Byte-level signature of every Q1/Q3/Q4 surface the wiki exposes."""
    paths = store.all_paths()
    sig = {"paths": paths}
    sig["records"] = {p: R.encode(store.get(p)) for p in paths}
    sig["navigate"] = {p: [R.encode(r) for r in store.navigate(p)]
                       for p in paths}
    prefixes = sorted({"/" + P.segments(p)[0] for p in paths if p != "/"})
    sig["search"] = {pref: store.search(pref) for pref in prefixes}
    sig["contains"] = {tok: store.search_contains(tok)
                       for tok in ("rel", "zhou", "nothere")}
    return sig


def test_pipeline_built_sharded_durable_reopens_byte_identical(tmp_path):
    """ISSUE 3 acceptance: a DurableKV-backed ShardedPathStore built by
    the construction pipeline can be closed and reopened from disk with
    byte-identical get/navigate/search results — zero re-ingestion."""
    root = str(tmp_path / "wiki")
    store = open_durable_store(root, n_shards=3, memtable_limit=64,
                               sync="none")
    docs, _ = generate_authtrace(AuthTraceConfig(n_docs=24, n_questions=4,
                                                 seed=11))
    pipe = ConstructionPipeline(PipelineConfig(), HeuristicOracle(),
                                store=store)
    pipe.bootstrap(docs)
    pipe.ingest(docs)
    assert store.durable
    before = _store_signature(store)
    assert len(before["paths"]) > 20
    store.close()

    # reopen picks up the persisted shard count (routing-compatible)
    reopened = open_durable_store(root, sync="none")
    assert reopened.n_shards == 3
    assert _store_signature(reopened) == before
    # the namespace really is spread over per-shard directories on disk
    shard_dirs = [n for n in sorted(os.listdir(root)) if n.startswith("shard_")]
    assert len(shard_dirs) == 3
    per_shard = [s.count() for s in reopened.shards]
    assert sum(per_shard) == len(before["paths"]) and max(per_shard) < sum(per_shard)
    reopened.close()


def test_host_only_durable_store_does_not_journal(tmp_path):
    """The WAL invalidation journal is attached only by a device
    consumer: a pipeline/host-only durable store must not accumulate an
    unbounded pending_invalidations list."""
    root = str(tmp_path / "wiki")
    store = open_durable_store(root, sync="none")
    docs, _ = generate_authtrace(AuthTraceConfig(n_docs=12, n_questions=2,
                                                 seed=3))
    pipe = ConstructionPipeline(PipelineConfig(), HeuristicOracle(),
                                store=store)
    pipe.bootstrap(docs)
    pipe.ingest(docs)
    assert pipe.bus.journal is None
    assert store.pending_invalidations() == []
    store.close()
    reopened = open_durable_store(root, sync="none")
    assert reopened.pending_invalidations() == []
    reopened.close()


def test_reopen_with_wrong_shard_count_refuses(tmp_path):
    root = str(tmp_path / "wiki")
    open_durable_store(root, n_shards=2, sync="none").close()
    with pytest.raises(ValueError, match="n_shards"):
        open_durable_store(root, n_shards=4, sync="none")


def test_single_shard_store_roundtrip(tmp_path):
    root = str(tmp_path / "solo")
    store = open_durable_store(root, sync="none")
    assert isinstance(store, PathStore) and isinstance(store.engine, DurableKV)
    store.put_record("/", R.DirRecord(name=""))
    store.put_record("/dim", R.DirRecord(name="dim"))
    store.put_record("/dim/leaf", R.FileRecord(name="leaf", text="payload"))
    store.flush()
    store.close()
    again = open_durable_store(root, sync="none")
    assert again.get("/dim/leaf").text == "payload"
    assert again.search("/dim") == ["/dim", "/dim/leaf"]
    assert again.search_contains("leaf") == ["/dim/leaf"]
    again.close()


def test_sync_mode_env_knob(tmp_path, monkeypatch):
    monkeypatch.setenv(W.SYNC_ENV, "none")
    kv = DurableKV(str(tmp_path / "kv"))
    assert kv._sync == "none"
    kv.close()
    monkeypatch.setenv(W.SYNC_ENV, "bogus")
    with pytest.raises(ValueError, match="sync mode"):
        DurableKV(str(tmp_path / "kv2"))


def test_wal_directory_cleanup_shapes(tmp_path):
    """The scratch layout smoke.sh sweeps: *.wal + *.seg under the store
    dir, nothing else leaking elsewhere."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=2, sync="none")
    for i in range(8):
        kv.put(f"k{i}".encode(), b"v")
    kv.commit_epoch(1)
    kv.close()
    names = sorted(os.listdir(d))
    assert WAL_NAME in names
    assert any(n.endswith(".seg") for n in names)
    shutil.rmtree(d)
