"""Durable storage tier (ISSUE 3): WAL framing + CRC, SSTable segments,
DurableKV crash recovery, and byte-identical store reopen."""
import os
import shutil

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core.oracle import HeuristicOracle
from repro.core.pipeline import ConstructionPipeline, PipelineConfig
from repro.core.store import MemKV, PathStore
from repro.data.corpus import AuthTraceConfig, generate_authtrace
from repro.storage import (DurableKV, SSTable, open_durable_store,
                           write_sstable)
from repro.storage import failpoints as FPS
from repro.storage import manifest as MF
from repro.storage import wal as W
from repro.storage.lsm import WAL_NAME
from repro.storage.sstable import MISSING, TOMBSTONE


# ---------------------------------------------------------------------------
# WAL
# ---------------------------------------------------------------------------
def test_wal_commit_boundaries_and_replay(tmp_path):
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"a", b"1")
    w.append_delete(b"b")
    w.append_inval("/d0/e0")
    w.commit(1)
    w.append_put(b"c", b"3")
    w.commit(2)
    w.append_put(b"never", b"committed")   # buffered, no commit
    w.close()
    res = W.replay(p)
    assert len(res.waves) == 2
    kinds = [rec.kind for rec in res.waves[0]]
    assert kinds == [W.PUT, W.DEL, W.INV, W.COMMIT]
    assert res.waves[0][2].path == "/d0/e0"
    assert res.waves[1][0].key == b"c"
    assert res.waves[1][-1].epoch == 2
    assert res.dropped_records == 0 and not res.corrupt_tail
    assert res.valid_end == os.path.getsize(p)   # buffer never hit disk


def test_wal_corrupt_tail_detected_and_dropped(tmp_path):
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"k", b"v")
    w.commit(1)
    w.close()
    good = os.path.getsize(p)
    # flip a byte inside an appended (committed-looking) record
    w2 = W.WAL(p, sync="none")
    w2.append_put(b"x", b"y")
    w2.commit(2)
    w2.close()
    with open(p, "rb+") as f:
        f.seek(good + 10)
        b = f.read(1)
        f.seek(good + 10)
        f.write(bytes([b[0] ^ 0xFF]))
    res = W.replay(p)
    assert res.corrupt_tail
    assert len(res.waves) == 1                    # only the intact wave
    assert res.valid_end == good


def test_wal_zero_filled_torn_tail(tmp_path):
    """A zero-filled tail (torn page after power loss) frames as
    crc=0/len=0, which crc32(b'') would pass — replay must still treat
    it as corrupt and the store must reopen cleanly."""
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"k", b"v")
    w.commit(1)
    w.close()
    good = os.path.getsize(p)
    with open(p, "ab") as f:
        f.write(b"\x00" * 64)
    res = W.replay(p)
    assert res.corrupt_tail and res.valid_end == good
    assert res.waves[-1][-1].epoch == 1
    d = str(tmp_path / "kv")
    kv = DurableKV(d, sync="none")
    kv.put(b"a", b"1")
    kv.commit_epoch(1)
    kv.close()
    with open(os.path.join(d, WAL_NAME), "ab") as f:
        f.write(b"\x00" * 64)
    kv2 = DurableKV(d, sync="none")               # must not raise
    assert kv2.recovery_corrupt_tail and kv2.get(b"a") == b"1"
    kv2.close()


def test_compact_after_reopen_preserves_committed_epoch(tmp_path):
    """Regression: the manifest written by a post-reopen spill/compact
    must carry the WAL-replayed epoch — the spill truncates the WAL that
    was the only record of it."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=10**9, sync="none")
    for e in range(1, 6):
        kv.put(f"k{e}".encode(), b"v")
        kv.commit_epoch(e)
    kv.close()
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 5
    kv2.compact()                                 # spills + truncates WAL
    kv2.close()
    kv3 = DurableKV(d, sync="none")
    assert kv3.last_epoch() == 5, "compaction regressed the committed epoch"
    kv3.close()


def test_wal_torn_partial_frame(tmp_path):
    p = str(tmp_path / "t.wal")
    w = W.WAL(p, sync="none")
    w.append_put(b"k", b"v")
    w.commit(3)
    w.close()
    good = os.path.getsize(p)
    with open(p, "ab") as f:
        f.write(b"\x07\x00")                      # half a header
    res = W.replay(p)
    assert res.corrupt_tail and res.valid_end == good
    assert res.waves[-1][-1].epoch == 3


# ---------------------------------------------------------------------------
# SSTable
# ---------------------------------------------------------------------------
def test_sstable_get_scan_tombstones(tmp_path):
    items = sorted({f"k{i:03d}".encode(): f"v{i}".encode()
                    for i in range(100)}.items())
    items[7] = (items[7][0], TOMBSTONE)
    p = str(tmp_path / "a.seg")
    write_sstable(p, items, sync=False)
    t = SSTable(p)
    assert t.n_records == 100
    assert t.get(b"k005") == b"v5"
    assert t.get(items[7][0]) is TOMBSTONE        # delete persisted as such
    assert t.get(b"k0999") is MISSING
    assert t.get(b"a") is MISSING                 # before first key
    got = dict(t.scan(b"k01"))
    assert len(got) == 10 and got[b"k012"] == b"v12"
    assert len(list(t.iter_all())) == 100
    t.close()


def test_sstable_sparse_index_boundaries(tmp_path):
    # exactly SPARSE_EVERY-aligned + not-aligned sizes, single record
    for n in (1, 16, 17, 31):
        items = [(f"{i:04d}".encode(), b"x" * i) for i in range(n)]
        p = str(tmp_path / f"s{n}.seg")
        write_sstable(p, items, sync=False)
        t = SSTable(p)
        for k, v in items:
            assert t.get(k) == v
        assert t.get(b"zzzz") is MISSING
        t.close()


# ---------------------------------------------------------------------------
# DurableKV — crash recovery + MemKV parity
# ---------------------------------------------------------------------------
def test_tombstone_survives_spill_and_reopen(tmp_path):
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none")
    for i in range(6):
        kv.put(f"k{i}".encode(), b"old")
    kv.commit_epoch(1)                 # spills: all six live in segment 1
    kv.delete(b"k3")
    kv.put(b"k0", b"new")
    for i in range(6, 12):
        kv.put(f"k{i}".encode(), b"fresh")
    kv.commit_epoch(2)                 # spills again: tombstone in segment 2
    assert len(kv._manifest.segments) == 2
    kv.close()
    kv2 = DurableKV(d, sync="none")
    assert kv2.get(b"k3") is None, "delete resurrected across reopen"
    assert kv2.get(b"k0") == b"new"
    assert b"k3" not in dict(kv2.scan(b"k"))
    kv2.compact()                      # full merge may now drop the tombstone
    kv2.close()
    kv3 = DurableKV(d, sync="none")
    assert kv3.get(b"k3") is None
    assert kv3.get(b"k11") == b"fresh"
    kv3.close()


def test_crash_between_segment_write_and_manifest_swap(tmp_path):
    """The spill order is segment → manifest → WAL truncate; a crash
    after the segment write but before the manifest swap must lose
    nothing (WAL still holds the wave) and resurrect nothing (the orphan
    segment is swept)."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=10**9, sync="none")
    kv.put(b"a", b"1")
    kv.commit_epoch(1)
    kv.close()
    # simulate the crashed spill: an orphan segment containing records
    # that were NEVER committed, plus one committed key with a bogus value
    write_sstable(os.path.join(d, "seg_000042.seg"),
                  [(b"a", b"bogus"), (b"ghost", b"uncommitted")], sync=False)
    kv2 = DurableKV(d, sync="none")
    assert kv2.get(b"a") == b"1"                 # WAL replay wins
    assert kv2.get(b"ghost") is None             # orphan swept, not adopted
    assert not os.path.exists(os.path.join(d, "seg_000042.seg"))
    assert kv2.last_epoch() == 1
    kv2.close()


def test_uncommitted_wave_lost_committed_waves_exact(tmp_path):
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none")
    committed = {}
    for wave in range(5):
        for i in range(3):
            k = f"w{wave}k{i}".encode()
            kv.put(k, f"{wave}:{i}".encode())
            committed[k] = f"{wave}:{i}".encode()
        kv.commit_epoch(wave + 1)
    kv.put(b"uncommitted", b"x")                 # crash before commit
    del kv
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 5
    assert kv2.get(b"uncommitted") is None
    assert dict(kv2.scan(b"")) == committed
    kv2.close()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["put", "delete", "commit"]),
                          st.integers(0, 30), st.binary(min_size=0, max_size=6)),
                min_size=1, max_size=80))
def test_durablekv_matches_memkv_and_survives_reopen(tmp_path_factory, ops):
    """Acceptance property: the same op sequence applied to MemKV and
    DurableKV yields identical get/scan results — before close, and
    byte-identical again after close + reopen from disk."""
    d = str(tmp_path_factory.mktemp("kv"))
    ref = MemKV(memtable_limit=7)
    kv = DurableKV(d, memtable_limit=7, sync="none")
    epoch = 0
    for op, ki, v in ops:
        k = f"{ki:04d}".encode()
        if op == "put":
            ref.put(k, v)
            kv.put(k, v)
        elif op == "delete":
            ref.delete(k)
            kv.delete(k)
        else:
            epoch += 1
            kv.commit_epoch(epoch)
    keys = [f"{i:04d}".encode() for i in range(31)]
    assert [kv.get(k) for k in keys] == [ref.get(k) for k in keys]
    assert list(kv.scan(b"")) == list(ref.scan(b""))
    assert list(kv.scan(b"001")) == list(ref.scan(b"001"))
    kv.close()                                   # commits the open tail
    kv2 = DurableKV(d, sync="none")
    assert [kv2.get(k) for k in keys] == [ref.get(k) for k in keys]
    assert list(kv2.scan(b"")) == list(ref.scan(b""))
    kv2.close()


def test_commit_epoch_monotone_and_advance_durable(tmp_path):
    """Regression: a lagging engine (device mirror with a trailing
    counter) must not move the committed epoch backwards, and an epoch
    ADVANCE is recorded durably even when the wave carried no content."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=10**9, sync="none")
    kv.put(b"a", b"1")
    kv.commit_epoch(3)
    kv.commit_epoch(1)                 # lagging caller: clamped, no regress
    assert kv.last_epoch() == 3
    kv.commit_epoch(4)                 # content-free advance: still durable
    kv.close()
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 4
    kv2.close()


def test_manifest_atomic_and_orphan_sweep(tmp_path):
    d = str(tmp_path)
    meta = MF.SegmentMeta(name="seg_000001.seg", level=2, records=10,
                          bytes=123, min_key=b"a".hex(), max_key=b"z".hex(),
                          bloom_k=7, bloom_bits=640)
    m = MF.Manifest(segments=[meta], next_seg=2, epoch=7,
                    device_epoch=5, pending_inval=["/a"])
    MF.store(d, m, sync=False)
    assert not os.path.exists(os.path.join(d, MF.MANIFEST_NAME + ".tmp"))
    m2 = MF.load(d)
    assert (m2.segments, m2.next_seg, m2.epoch, m2.device_epoch,
            m2.pending_inval) == ([meta], 2, 7, 5, ["/a"])
    assert m2.segment_names() == ["seg_000001.seg"]
    assert m2.level_counts() == {2: 1}
    open(os.path.join(d, "seg_000009.seg"), "wb").close()
    removed = MF.sweep_orphans(d, m2)
    assert removed == ["seg_000009.seg"]


# ---------------------------------------------------------------------------
# PathStore / ShardedPathStore over the durable tier
# ---------------------------------------------------------------------------
def _store_signature(store):
    """Byte-level signature of every Q1/Q3/Q4 surface the wiki exposes."""
    paths = store.all_paths()
    sig = {"paths": paths}
    sig["records"] = {p: R.encode(store.get(p)) for p in paths}
    sig["navigate"] = {p: [R.encode(r) for r in store.navigate(p)]
                       for p in paths}
    prefixes = sorted({"/" + P.segments(p)[0] for p in paths if p != "/"})
    sig["search"] = {pref: store.search(pref) for pref in prefixes}
    sig["contains"] = {tok: store.search_contains(tok)
                       for tok in ("rel", "zhou", "nothere")}
    return sig


def test_pipeline_built_sharded_durable_reopens_byte_identical(tmp_path):
    """ISSUE 3 acceptance: a DurableKV-backed ShardedPathStore built by
    the construction pipeline can be closed and reopened from disk with
    byte-identical get/navigate/search results — zero re-ingestion."""
    root = str(tmp_path / "wiki")
    store = open_durable_store(root, n_shards=3, memtable_limit=64,
                               sync="none")
    docs, _ = generate_authtrace(AuthTraceConfig(n_docs=24, n_questions=4,
                                                 seed=11))
    pipe = ConstructionPipeline(PipelineConfig(), HeuristicOracle(),
                                store=store)
    pipe.bootstrap(docs)
    pipe.ingest(docs)
    assert store.durable
    before = _store_signature(store)
    assert len(before["paths"]) > 20
    store.close()

    # reopen picks up the persisted shard count (routing-compatible)
    reopened = open_durable_store(root, sync="none")
    assert reopened.n_shards == 3
    assert _store_signature(reopened) == before
    # the namespace really is spread over per-shard directories on disk
    shard_dirs = [n for n in sorted(os.listdir(root)) if n.startswith("shard_")]
    assert len(shard_dirs) == 3
    per_shard = [s.count() for s in reopened.shards]
    assert sum(per_shard) == len(before["paths"]) and max(per_shard) < sum(per_shard)
    reopened.close()


def test_host_only_durable_store_does_not_journal(tmp_path):
    """The WAL invalidation journal is attached only by a device
    consumer: a pipeline/host-only durable store must not accumulate an
    unbounded pending_invalidations list."""
    root = str(tmp_path / "wiki")
    store = open_durable_store(root, sync="none")
    docs, _ = generate_authtrace(AuthTraceConfig(n_docs=12, n_questions=2,
                                                 seed=3))
    pipe = ConstructionPipeline(PipelineConfig(), HeuristicOracle(),
                                store=store)
    pipe.bootstrap(docs)
    pipe.ingest(docs)
    assert pipe.bus.journal is None
    assert store.pending_invalidations() == []
    store.close()
    reopened = open_durable_store(root, sync="none")
    assert reopened.pending_invalidations() == []
    reopened.close()


def test_reopen_with_wrong_shard_count_refuses(tmp_path):
    root = str(tmp_path / "wiki")
    open_durable_store(root, n_shards=2, sync="none").close()
    with pytest.raises(ValueError, match="n_shards"):
        open_durable_store(root, n_shards=4, sync="none")


def test_single_shard_store_roundtrip(tmp_path):
    root = str(tmp_path / "solo")
    store = open_durable_store(root, sync="none")
    assert isinstance(store, PathStore) and isinstance(store.engine, DurableKV)
    store.put_record("/", R.DirRecord(name=""))
    store.put_record("/dim", R.DirRecord(name="dim"))
    store.put_record("/dim/leaf", R.FileRecord(name="leaf", text="payload"))
    store.flush()
    store.close()
    again = open_durable_store(root, sync="none")
    assert again.get("/dim/leaf").text == "payload"
    assert again.search("/dim") == ["/dim", "/dim/leaf"]
    assert again.search_contains("leaf") == ["/dim/leaf"]
    again.close()


def test_sync_mode_env_knob(tmp_path, monkeypatch):
    monkeypatch.setenv(W.SYNC_ENV, "none")
    kv = DurableKV(str(tmp_path / "kv"))
    assert kv._sync == "none"
    kv.close()
    monkeypatch.setenv(W.SYNC_ENV, "bogus")
    with pytest.raises(ValueError, match="sync mode"):
        DurableKV(str(tmp_path / "kv2"))


def test_wal_directory_cleanup_shapes(tmp_path):
    """The scratch layout smoke.sh sweeps: *.wal + *.seg under the store
    dir, nothing else leaking elsewhere."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=2, sync="none")
    for i in range(8):
        kv.put(f"k{i}".encode(), b"v")
    kv.commit_epoch(1)
    kv.close()
    names = sorted(os.listdir(d))
    assert WAL_NAME in names
    assert any(n.endswith(".seg") for n in names)
    shutil.rmtree(d)

# ---------------------------------------------------------------------------
# ISSUE 7: leveled compaction, bloom filters, block cache
# ---------------------------------------------------------------------------
import json
import struct

from repro.storage.lsm import default_block_cache, resolve_level_ratio
from repro.storage.sstable import (END_MAGIC, END_MAGIC_V1, MAGIC,
                                   SPARSE_EVERY, BlockCache, BloomFilter)


def _fill(kv, lo, hi, commit_epoch):
    for i in range(lo, hi):
        kv.put(f"k{i:05d}".encode(), f"v{i}".encode())
    kv.commit_epoch(commit_epoch)


def test_leveled_compaction_merges_only_triggering_level(tmp_path):
    """ISSUE 7 acceptance: the online trigger merges the triggering
    level's run into ONE next-level segment and touches nothing else —
    asserted via per-level segment counts AND the untouched segment's
    file name surviving the merge."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=3)
    # two spills: L0 = 2 (below the ratio-3 trigger)
    _fill(kv, 0, 4, 1)
    _fill(kv, 4, 8, 2)
    assert kv.level_counts() == {0: 2}
    # third spill trips the trigger: L0's 3-segment run merges into ONE
    # L1 segment; nothing else existed, so the tree is exactly {1: 1}
    _fill(kv, 8, 12, 3)
    assert kv.level_counts() == {1: 1}
    l1_name = kv._manifest.segments[0].name
    # two more spills: L0 grows beside the L1 segment, no trigger
    _fill(kv, 12, 16, 4)
    _fill(kv, 16, 20, 5)
    assert kv.level_counts() == {0: 2, 1: 1}
    # the next spill merges ONLY level 0: the L1 segment file must
    # survive untouched (same name — it was not rewritten), L1 grows to 2
    _fill(kv, 20, 24, 6)
    assert kv.level_counts() == {1: 2}
    survivors = [m.name for m in kv._manifest.segments if m.level == 1]
    assert l1_name in survivors, "merge rewrote a non-triggering level"
    # every key remains visible through the tree
    assert kv.get(b"k00000") == b"v0"
    assert kv.get(b"k00023") == b"v23"
    assert len(dict(kv.scan(b"k"))) == 24
    kv.close()


def test_leveled_cascade_and_major_compact(tmp_path):
    """ratio-2 store with a tiny partition target cascades beyond L1 as
    the byte caps overflow; ``compact()`` then collapses the whole tree
    into one bottom level of disjoint partitions."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=2, sync="none", level_ratio=2,
                   segment_target_bytes=32)
    for w in range(8):
        _fill(kv, 2 * w, 2 * w + 2, w + 1)
    counts = kv.level_counts()
    assert sum(counts.values()) >= 1 and max(counts) >= 2, counts
    assert len(dict(kv.scan(b"k"))) == 16
    kv.compact()
    counts = kv.level_counts()
    assert len(counts) == 1, counts          # one (bottom) level remains
    assert max(counts) >= 2                  # stayed at the bottom level
    # ... and its partitions are disjoint, range-known, and findable
    metas = [m for m in kv._manifest.segments]
    spans = sorted((bytes.fromhex(m.min_key), bytes.fromhex(m.max_key))
                   for m in metas)
    assert all(spans[i][0] > spans[i - 1][1] for i in range(1, len(spans)))
    assert len(dict(kv.scan(b"k"))) == 16
    assert kv.get(b"k00000") == b"v0" and kv.get(b"k00015") == b"v15"
    kv.close()


def test_tombstones_survive_level_merge_until_bottom(tmp_path):
    """A tombstone must out-live any level merge while deeper (older)
    data still holds the key, and only disappear once the merge output
    is the oldest data in the store."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=2, sync="none", level_ratio=2)
    _fill(kv, 0, 2, 1)
    _fill(kv, 2, 4, 2)                       # cascade → L1 holds k0..k3
    assert kv.level_counts() == {1: 1}
    kv.delete(b"k00000")
    kv.put(b"x", b"1")
    kv.commit_epoch(3)                       # spill: tombstone now in L0
    _fill(kv, 4, 6, 4)                       # L0=2 → merges into L1 (older L1 seg exists)
    assert kv.get(b"k00000") is None, "tombstone dropped above older data"
    assert b"k00000" not in dict(kv.scan(b"k"))
    kv.close()
    kv2 = DurableKV(d, sync="none", level_ratio=2)
    assert kv2.get(b"k00000") is None
    kv2.compact()                            # bottom merge may now drop it
    assert kv2.get(b"k00000") is None
    kv2.close()


def _abandon(kv):
    """Drop a wounded engine without close(): release file handles the
    way a dead process would (no commit, no manifest write)."""
    try:
        kv._wal._f.close()
    except Exception:
        pass
    for t in kv._tables.values():
        try:
            t.close()
        except Exception:
            pass


def _live_seg_names(kv):
    """Every .seg name the manifest considers paid-for: live segments
    plus a paused merge's recorded outputs."""
    live = set(kv._manifest.segment_names())
    if kv._manifest.compaction is not None:
        live.update(o.name for o in kv._manifest.compaction.outputs)
    return live


# the durability-critical IO sites a merge/spill walks through (WAL
# sites are excluded on purpose: these schedules crash *after* the
# wave's group commit, so the expected recovered content is exact)
_MERGE_SITES = frozenset({"segment.write", "manifest.write",
                          "manifest.replace"})


@pytest.mark.parametrize("mode", ["fail", "torn"])
def test_crash_during_partitioned_merge_every_boundary(tmp_path, mode):
    """ISSUE 9 acceptance (PR-5 crash tests, parametrized over
    partitioned merges): crash at EVERY segment-write / manifest-write /
    manifest-swap boundary of a wave whose spill triggers a
    multi-partition L0→L1 merge — cleanly or with a torn prefix — and
    recovery must lose nothing, resurrect nothing, and leave no
    unreferenced .seg behind.  The schedule length is discovered with a
    counting plan first, so every boundary is exercised, not a
    hand-picked few."""
    def build(d):
        return DurableKV(d, memtable_limit=2, sync="none", level_ratio=2,
                         segment_target_bytes=32)

    def preload(kv):
        _fill(kv, 0, 2, 1)
        _fill(kv, 2, 4, 2)                   # L0 merge → partitioned L1
        _fill(kv, 4, 6, 3)                   # L0 = 1 beside L1

    # pass 0: count the faultable ops in the triggering wave
    kv = build(str(tmp_path / "count"))
    preload(kv)
    with FPS.armed(FPS.FailPlan(crash_at=0, sites=_MERGE_SITES)) as counter:
        _fill(kv, 6, 8, 4)                   # spill + partitioned merge
    kv.close()
    n_ops = len(counter.hits)
    # spill (seg + manifest) + multi-partition merge (≥ 2 segs + manifest)
    assert n_ops >= 5, counter.hits

    expected = {f"k{i:05d}".encode(): f"v{i}".encode() for i in range(8)}
    for nth in range(1, n_ops + 1):
        d = str(tmp_path / f"kv_{mode}_{nth}")
        kv = build(d)
        preload(kv)
        with FPS.armed(FPS.FailPlan(crash_at=nth, mode=mode,
                                    sites=_MERGE_SITES)):
            with pytest.raises(FPS.InjectedCrash):
                _fill(kv, 6, 8, 4)
        _abandon(kv)

        kv2 = build(d)
        assert dict(kv2.scan(b"k")) == expected, f"boundary {nth}"
        for k, v in expected.items():
            assert kv2.get(k) == v
        # recovery swept everything the manifest does not pay for
        on_disk = {n for n in os.listdir(d) if n.endswith(".seg")}
        assert on_disk == _live_seg_names(kv2), f"boundary {nth}"
        # and the store still moves forward after the crash
        _fill(kv2, 8, 10, 5)
        assert len(dict(kv2.scan(b"k"))) == 10
        kv2.close()


def test_budget_pause_and_resume(tmp_path):
    """A merge that exhausts ``compact_budget_bytes`` pauses resumably:
    the completed partitions + resume key are durable in the manifest,
    reads stay correct off the still-live inputs, ``compact_debt``
    reports the remainder, and later commit boundaries finish the merge
    and drain the debt to zero."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                   segment_target_bytes=32, compact_budget_bytes=150)
    _fill(kv, 0, 4, 1)
    _fill(kv, 4, 8, 2)                       # L0=2 → merge, pauses on budget
    st = kv._manifest.compaction
    assert st is not None and st.next_key and st.outputs
    assert kv.compact_debt() > 0
    # the paused state is DURABLE, not just in memory
    with open(os.path.join(d, MF.MANIFEST_NAME), encoding="utf-8") as f:
        on_disk = json.load(f)
    assert on_disk["compaction"] is not None
    assert on_disk["compaction"]["next_key"] == st.next_key
    # reads while paused: inputs still live, view identical
    expected = {f"k{i:05d}".encode(): f"v{i}".encode() for i in range(8)}
    assert dict(kv.scan(b"k")) == expected
    # epoch-advancing commits drain the debt a budget-slice at a time
    epoch, waves = 2, 0
    while kv.compact_debt() > 0:
        epoch += 1
        kv.commit_epoch(epoch)
        assert kv.last_compact_bytes <= 150 + 200, \
            "a resume slice blew through the budget"
        waves += 1
        assert waves < 50, "debt never drained"
    assert waves >= 1
    assert kv._manifest.compaction is None
    assert dict(kv.scan(b"k")) == expected
    # the settled tree keeps the tentpole invariant: every level ≥ 1 is
    # a partitioned (range-disjoint, binary-searchable) view
    assert all(m.level >= 1 for m in kv._manifest.segments)
    for view in kv._levels:
        assert view.partitioned, f"level {view.level} fell back to probe-all"
    kv.close()


def test_budget_pause_survives_reopen_and_resumes(tmp_path):
    """The resumable-merge state round-trips a clean close/reopen: the
    reopened store still owes the debt and finishes the same merge."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                   segment_target_bytes=32, compact_budget_bytes=150)
    _fill(kv, 0, 4, 1)
    _fill(kv, 4, 8, 2)
    assert kv._manifest.compaction is not None
    paused_outputs = [o.name for o in kv._manifest.compaction.outputs]
    kv.close()

    kv2 = DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                    segment_target_bytes=32, compact_budget_bytes=150)
    st = kv2._manifest.compaction
    assert st is not None
    assert [o.name for o in st.outputs] == paused_outputs, \
        "recovery swept a paused merge's paid-for outputs"
    assert kv2.compact_debt() > 0
    epoch = 2
    while kv2.compact_debt() > 0:
        epoch += 1
        kv2.commit_epoch(epoch)
        assert epoch < 50
    assert dict(kv2.scan(b"k")) == {f"k{i:05d}".encode(): f"v{i}".encode()
                                    for i in range(8)}
    kv2.close()


@pytest.mark.parametrize("mode", ["fail", "torn"])
def test_crash_during_resumed_merge_every_boundary(tmp_path, mode):
    """ISSUE 9 acceptance (mid-resume crash points): pause a merge on
    budget, then crash the RESUMING wave at every IO boundary.  Recovery
    must keep the recorded pre-pause partitions, re-merge only from the
    resume key, and still converge to the oracle view."""
    def build(d):
        return DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                         segment_target_bytes=32, compact_budget_bytes=150)

    def pause(kv):
        _fill(kv, 0, 4, 1)
        _fill(kv, 4, 8, 2)
        assert kv._manifest.compaction is not None, "merge did not pause"

    # count the resuming wave's faultable ops
    kv = build(str(tmp_path / "count"))
    pause(kv)
    with FPS.armed(FPS.FailPlan(crash_at=0, sites=_MERGE_SITES)) as counter:
        epoch = 3
        while kv._manifest.compaction is not None:
            kv.commit_epoch(epoch)
            epoch += 1
    kv.close()
    n_ops = len(counter.hits)
    assert n_ops >= 2, counter.hits

    expected = {f"k{i:05d}".encode(): f"v{i}".encode() for i in range(8)}
    for nth in range(1, n_ops + 1):
        d = str(tmp_path / f"kv_{mode}_{nth}")
        kv = build(d)
        pause(kv)
        with FPS.armed(FPS.FailPlan(crash_at=nth, mode=mode,
                                    sites=_MERGE_SITES)):
            with pytest.raises(FPS.InjectedCrash):
                epoch = 3
                while kv._manifest.compaction is not None:
                    kv.commit_epoch(epoch)
                    epoch += 1
        _abandon(kv)

        kv2 = build(d)
        assert dict(kv2.scan(b"k")) == expected, f"resume boundary {nth}"
        on_disk = {n for n in os.listdir(d) if n.endswith(".seg")}
        assert on_disk == _live_seg_names(kv2), f"resume boundary {nth}"
        epoch = 20                           # drain the debt for real
        while kv2.compact_debt() > 0:
            kv2.commit_epoch(epoch)
            epoch += 1
            assert epoch < 90
        assert dict(kv2.scan(b"k")) == expected
        assert kv2._manifest.compaction is None
        kv2.close()


def test_major_compact_abandons_paused_merge(tmp_path):
    """``compact()`` supersedes a paused merge: the recorded outputs are
    deleted (they are copies of still-live inputs), the state clears,
    and the full view survives in bottom-level partitions."""
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=2,
                   segment_target_bytes=32, compact_budget_bytes=150)
    _fill(kv, 0, 4, 1)
    _fill(kv, 4, 8, 2)
    st = kv._manifest.compaction
    assert st is not None
    orphan_candidates = [o.name for o in st.outputs]
    kv.compact()
    assert kv._manifest.compaction is None
    on_disk = {n for n in os.listdir(d) if n.endswith(".seg")}
    assert not (on_disk & set(orphan_candidates)), \
        "abandoned merge outputs leaked"
    assert on_disk == set(kv._manifest.segment_names())
    assert dict(kv.scan(b"k")) == {f"k{i:05d}".encode(): f"v{i}".encode()
                                   for i in range(8)}
    assert kv.compact_debt() == 0
    kv.close()


def test_bloom_filter_no_false_negatives_and_fpr():
    """Property: every inserted key passes; the false-positive rate on
    disjoint probes stays near the design point (~0.8% at 10 bits/key —
    assert a generous < 3%)."""
    present = [f"in:{i}".encode() for i in range(2000)]
    bf = BloomFilter.build(present, bits_per_key=10)
    assert all(bf.may_contain(k) for k in present), "false negative"
    absent = [f"out:{i}".encode() for i in range(10000)]
    fpr = sum(bf.may_contain(k) for k in absent) / len(absent)
    assert fpr < 0.03, f"FPR {fpr:.4f} too high for 10 bits/key"


@given(st.lists(st.binary(min_size=0, max_size=12), unique=True,
                min_size=1, max_size=200))
@settings(max_examples=30, deadline=None)
def test_bloom_filter_never_false_negative_property(keys):
    bf = BloomFilter.build(keys, bits_per_key=10)
    assert all(bf.may_contain(k) for k in keys)


def test_durablekv_bloom_skips_segments_on_miss(tmp_path):
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=4, sync="none", level_ratio=100)
    for w in range(4):
        _fill(kv, 4 * w, 4 * w + 4, w + 1)
    assert kv.level_counts() == {0: 4}
    base = kv.op_counts().get("bloom_neg", 0)
    for i in range(50):
        assert kv.get(f"absent{i}".encode()) is None
    negs = kv.op_counts()["bloom_neg"] - base
    # 50 misses x 4 segments = 200 probes; ~all should be bloom-skipped
    assert negs >= 190, f"only {negs}/200 probes bloom-skipped"
    kv.close()


def test_block_cache_hit_accounting_and_eviction(tmp_path):
    cache = BlockCache(capacity_bytes=1 << 20)
    d = str(tmp_path / "kv")
    kv = DurableKV(d, memtable_limit=64, sync="none", block_cache=cache)
    _fill(kv, 0, 64, 1)                      # one spilled segment
    assert kv.level_counts() == {0: 1}
    assert kv.get(b"k00003") == b"v3"        # first touch parses the block
    c0 = kv.op_counts()
    assert c0.get("cache_miss", 0) >= 1
    for _ in range(10):
        assert kv.get(b"k00003") == b"v3"
    c1 = kv.op_counts()
    assert c1["cache_hit"] >= c0.get("cache_hit", 0) + 10
    assert c1["cache_miss"] == c0["cache_miss"]   # same block, no re-parse
    assert cache.hits >= 10 and len(cache) >= 1
    # compaction closes the old segment -> its blocks are evicted
    kv.compact()
    assert all(k[0].endswith(kv._manifest.segments[0].name)
               for k in cache._d), "stale blocks survived segment delete"
    kv.close()

    # eviction under a tiny budget: walk many blocks, stay under capacity
    tiny = BlockCache(capacity_bytes=600)
    kv2 = DurableKV(str(tmp_path / "kv2"), memtable_limit=256, sync="none",
                    block_cache=tiny)
    _fill(kv2, 0, 256, 1)
    for i in range(0, 256, SPARSE_EVERY):    # one get per index block
        assert kv2.get(f"k{i:05d}".encode()) == f"v{i}".encode()
    assert tiny.used_bytes() <= 600
    assert len(tiny) < 256 // SPARSE_EVERY, "nothing was ever evicted"
    kv2.close()


def test_block_cache_disabled_by_env_zero(monkeypatch):
    monkeypatch.setenv("REPRO_BLOCK_CACHE_BYTES", "0")
    assert default_block_cache() is None
    monkeypatch.setenv("REPRO_BLOCK_CACHE_BYTES", "1024")
    c = default_block_cache()
    assert isinstance(c, BlockCache) and c.capacity == 1024
    monkeypatch.setenv("REPRO_LEVEL_RATIO", "1")
    with pytest.raises(ValueError, match="level_ratio"):
        resolve_level_ratio()


def test_pr3_manifest_and_segments_migrate(tmp_path):
    """ISSUE 7 acceptance: a PR-3 store (format-1 manifest naming bare
    segment files, v1 segments without blooms) opens as all-level-0,
    serves reads, and migrates to the leveled format-2 manifest on the
    first compaction — round-tripped through a reopen."""
    d = str(tmp_path / "kv")
    os.makedirs(d)
    # v1 bytes via the compatibility writer (bloom_bits_per_key=0)
    write_sstable(os.path.join(d, "seg_000001.seg"),
                  [(b"a", b"1"), (b"b", b"2")], sync=False,
                  bloom_bits_per_key=0)
    write_sstable(os.path.join(d, "seg_000002.seg"),
                  [(b"b", b"22"), (b"c", b"3")], sync=False,
                  bloom_bits_per_key=0)
    with open(os.path.join(d, MF.MANIFEST_NAME), "w", encoding="utf-8") as f:
        json.dump({"format": 1,
                   "segments": ["seg_000001.seg", "seg_000002.seg"],
                   "next_seg": 3, "epoch": 7, "device_epoch": 7,
                   "pending_inval": []}, f)

    kv = DurableKV(d, sync="none", memtable_limit=4)
    assert kv.last_epoch() == 7
    assert kv.level_counts() == {0: 2}       # PR-3 segments open at level 0
    for meta, seg in kv._read_order:
        assert seg.bloom is None and meta.bloom_bits == 0
    assert kv.get(b"a") == b"1"
    assert kv.get(b"b") == b"22"             # newer segment shadows older
    assert dict(kv.scan(b"")) == {b"a": b"1", b"b": b"22", b"c": b"3"}
    kv.compact()                             # first manifest write migrates
    kv.close()

    with open(os.path.join(d, MF.MANIFEST_NAME), encoding="utf-8") as f:
        o = json.load(f)
    assert o["format"] == MF.FORMAT == 3
    assert all(isinstance(s, dict) and "level" in s for s in o["segments"])
    assert o["compaction"] is None
    kv2 = DurableKV(d, sync="none")
    assert kv2.last_epoch() == 7
    assert dict(kv2.scan(b"")) == {b"a": b"1", b"b": b"22", b"c": b"3"}
    # post-migration segments carry blooms at the default budget
    assert all(seg.bloom is not None for _, seg in kv2._read_order)
    kv2.close()


def test_format2_manifest_migrates_to_format3(tmp_path):
    """A leveled (format-2, PR-5) manifest opens with no pending merge
    and the first manifest write migrates it to format 3 with an
    explicit ``compaction: null`` field."""
    d = str(tmp_path / "kv")
    os.makedirs(d)
    write_sstable(os.path.join(d, "seg_000001.seg"),
                  [(b"a", b"1"), (b"b", b"2")], sync=False)
    with open(os.path.join(d, MF.MANIFEST_NAME), "w", encoding="utf-8") as f:
        json.dump({"format": 2,
                   "segments": [{"name": "seg_000001.seg", "level": 1,
                                 "records": 2, "bytes": 64,
                                 "min_key": b"a".hex(),
                                 "max_key": b"b".hex(),
                                 "bloom_k": 0, "bloom_bits": 0}],
                   "next_seg": 2, "epoch": 3, "device_epoch": 3,
                   "pending_inval": []}, f)

    kv = DurableKV(d, sync="none", memtable_limit=4)
    assert kv._manifest.compaction is None   # format 2 ⇒ nothing pending
    assert kv.level_counts() == {1: 1}
    assert kv.get(b"a") == b"1"
    for k in (b"c", b"d", b"e", b"f"):
        kv.put(k, b"3")
    kv.commit_epoch(4)                       # spill ⇒ first manifest write
    kv.close()

    with open(os.path.join(d, MF.MANIFEST_NAME), encoding="utf-8") as f:
        o = json.load(f)
    assert o["format"] == 3 and "compaction" in o
    kv2 = DurableKV(d, sync="none")
    assert dict(kv2.scan(b"")) == {b"a": b"1", b"b": b"2", b"c": b"3",
                                   b"d": b"3", b"e": b"3", b"f": b"3"}
    kv2.close()


def test_block_cache_no_stale_blocks_across_store_generations(tmp_path):
    """ISSUE 9 satellite: a shared BlockCache must never serve a dead
    generation's blocks.  Recreating a store at the SAME directory (same
    segment file names) with different values — the shape of a
    crash-restore or a test harness reusing a path — must read the new
    bytes even when the old generation's blocks are still cached."""
    d = str(tmp_path / "kv")
    cache = default_block_cache(1 << 20)
    kv = DurableKV(d, memtable_limit=2, sync="none", block_cache=cache)
    _fill(kv, 0, 2, 1)                       # spill → seg_000001.seg
    assert kv.get(b"k00000") == b"v0"        # populate the cache
    assert len(cache) > 0
    _abandon(kv)                             # die without close()

    shutil.rmtree(d)                         # new lineage, same path
    kv2 = DurableKV(d, memtable_limit=2, sync="none", block_cache=cache)
    kv2.put(b"k00000", b"NEW")
    kv2.put(b"k00001", b"NEW")
    kv2.commit_epoch(1)                      # spill → seg_000001.seg again
    assert [m.name for m in kv2._manifest.segments] == ["seg_000001.seg"]
    assert kv2.get(b"k00000") == b"NEW", \
        "shared BlockCache served a stale block from a dead generation"
    assert dict(kv2.scan(b"k")) == {b"k00000": b"NEW", b"k00001": b"NEW"}
    kv2.close()


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["put", "delete", "commit"]),
                          st.integers(0, 30), st.binary(min_size=0, max_size=6)),
                min_size=20, max_size=120))
def test_multilevel_durablekv_matches_memkv(tmp_path_factory, ops):
    """MemKV parity under an aggressively leveled tree: tiny memtable +
    ratio 2 force frequent spills and cascading merges, with the shared
    block cache attached — then byte-identical again after reopen."""
    d = str(tmp_path_factory.mktemp("kv"))
    ref = MemKV(memtable_limit=7)
    kv = DurableKV(d, memtable_limit=3, sync="none", level_ratio=2,
                   block_cache=BlockCache(1 << 16))
    epoch = 0
    for op, ki, v in ops:
        k = f"{ki:04d}".encode()
        if op == "put":
            ref.put(k, v)
            kv.put(k, v)
        elif op == "delete":
            ref.delete(k)
            kv.delete(k)
        else:
            epoch += 1
            kv.commit_epoch(epoch)
    keys = [f"{i:04d}".encode() for i in range(31)]
    assert [kv.get(k) for k in keys] == [ref.get(k) for k in keys]
    assert list(kv.scan(b"")) == list(ref.scan(b""))
    kv.close()
    kv2 = DurableKV(d, memtable_limit=3, sync="none", level_ratio=2)
    assert [kv2.get(k) for k in keys] == [ref.get(k) for k in keys]
    assert list(kv2.scan(b"")) == list(ref.scan(b""))
    kv2.close()


def test_segment_footer_matches_documented_layout(tmp_path):
    """ISSUE 7 acceptance: the docs/STORAGE.md byte layout is asserted
    against a real segment file — v2 footer ``<QQIIIQ`` + WEND2 and the
    v1 compatibility footer ``<QII`` + WEND1 — by parsing raw bytes with
    nothing but the documented offsets."""
    items = [(f"k{i:03d}".encode(), f"v{i}".encode()) for i in range(40)]

    p2 = str(tmp_path / "v2.seg")
    stats = write_sstable(p2, items, sync=False, bloom_bits_per_key=10)
    raw = open(p2, "rb").read()
    assert raw[:6] == MAGIC == b"WSEG1\n"
    assert raw[-6:] == END_MAGIC == b"WEND2\n"
    footer = struct.Struct("<QQIIIQ")               # as documented
    (index_off, bloom_off, n_index, n_records,
     bloom_k, bloom_nbits) = footer.unpack(raw[-6 - footer.size:-6])
    assert n_records == 40
    assert n_index == (40 + SPARSE_EVERY - 1) // SPARSE_EVERY == 3
    assert bloom_k == stats.bloom_k and bloom_nbits == stats.bloom_nbits
    assert bloom_nbits == 40 * 10                   # n * bits_per_key
    # section order and sizes: data | index | bloom | footer
    assert 6 < index_off < bloom_off < len(raw)
    assert bloom_off + (bloom_nbits + 7) // 8 == len(raw) - footer.size - 6
    # first record at the documented offset: key_len u32 | val_len u32 | ...
    klen, vlen = struct.unpack_from("<II", raw, 6)
    assert raw[14:14 + klen] == b"k000" and klen == 4
    assert raw[14 + klen:14 + klen + vlen] == b"v0"
    # first index entry points back at the first record
    iklen, = struct.unpack_from("<I", raw, index_off)
    ikey = raw[index_off + 4: index_off + 4 + iklen]
    ioff, = struct.unpack_from("<Q", raw, index_off + 4 + iklen)
    assert ikey == b"k000" and ioff == 6
    assert stats.file_bytes == len(raw)

    p1 = str(tmp_path / "v1.seg")
    write_sstable(p1, items, sync=False, bloom_bits_per_key=0)
    raw1 = open(p1, "rb").read()
    assert raw1[-6:] == END_MAGIC_V1 == b"WEND1\n"
    f1 = struct.Struct("<QII")
    index_off1, n_index1, n_records1 = f1.unpack(raw1[-6 - f1.size:-6])
    assert (n_records1, n_index1) == (40, 3)
    # v1 == v2 minus the bloom section and the wider footer
    assert raw1[:index_off1] == raw[:index_off]
    t = SSTable(p1)
    assert t.bloom is None and t.get(b"k007") == b"v7"
    t.close()
