"""Storage engines + the four query operators across backends."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import paths as P
from repro.core import records as R
from repro.core.backends import ALL_BACKENDS
from repro.core.store import DictKV, MemKV, PathStore


def _mini_wiki():
    items = [
        ("/", R.DirRecord(name="", sub_dirs=["rel", "style"])),
        ("/rel", R.DirRecord(name="rel", files=["lu_xun", "zhou"])),
        ("/style", R.DirRecord(name="style", files=["satire"])),
        ("/rel/lu_xun", R.FileRecord(name="lu_xun", text="the author")),
        ("/rel/zhou", R.FileRecord(name="zhou", text="the brother")),
        ("/style/satire", R.FileRecord(name="satire", text="sharp prose")),
    ]
    return items


def test_memkv_lsm_semantics():
    kv = MemKV(memtable_limit=4, auto_compact_runs=2)
    for i in range(20):
        kv.put(f"k{i:03d}".encode(), f"v{i}".encode())
    assert kv.get(b"k005") == b"v5"
    kv.delete(b"k005")
    assert kv.get(b"k005") is None          # tombstone across runs
    kv.put(b"k005", b"v5b")
    assert kv.get(b"k005") == b"v5b"        # newest wins
    got = dict(kv.scan(b"k01"))
    assert set(got) == {f"k{i:03d}".encode() for i in range(10, 20)}
    kv.compact()
    assert kv.get(b"k005") == b"v5b"


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 50), st.binary(max_size=8)),
                min_size=1, max_size=60),
       st.lists(st.integers(0, 50), max_size=10))
def test_memkv_matches_dict(puts, deletes):
    kv = MemKV(memtable_limit=5, auto_compact_runs=3)
    ref = {}
    for k, v in puts:
        kb = f"{k:04d}".encode()
        kv.put(kb, v)
        ref[kb] = v
    for k in deletes:
        kb = f"{k:04d}".encode()
        kv.delete(kb)
        ref.pop(kb, None)
    for kb in {f"{k:04d}".encode() for k, _ in puts}:
        assert kv.get(kb) == ref.get(kb)
    assert [k for k, _ in kv.scan(b"")] == sorted(ref)


def test_pathstore_q1_q2_q3_q4():
    ps = PathStore(MemKV())
    for path, rec in _mini_wiki():
        ps.put_record(path, rec)
    # Q1
    rec = ps.get("/rel/lu_xun")
    assert isinstance(rec, R.FileRecord) and rec.text == "the author"
    assert ps.get("/missing") is None
    # Q2 ≡ one point lookup: children come from the directory record
    rec, kids = ps.ls("/rel")
    assert kids == ["/rel/lu_xun", "/rel/zhou"]
    # Q3: one record per level
    chain = ps.navigate("/rel/lu_xun")
    assert len(chain) == 3
    # Q4: segment-aware prefix
    assert ps.search("/rel") == ["/rel", "/rel/lu_xun", "/rel/zhou"]
    assert ps.search("/re") == []           # "/re" is not a segment prefix
    assert ps.count() == 6


@pytest.mark.parametrize("name", sorted(ALL_BACKENDS))
def test_backends_agree(name):
    be = ALL_BACKENDS[name]()
    try:
        be.load(_mini_wiki())
        rec = be.q1_get("/rel/zhou")
        assert isinstance(rec, R.FileRecord) and rec.text == "the brother"
        assert be.q1_get("/nope") is None
        kids = be.q2_ls("/rel")
        assert sorted(kids) == ["/rel/lu_xun", "/rel/zhou"]
        assert len(be.q3_navigate("/style/satire")) == 3
        hits = be.q4_search("/rel")
        assert set(hits) >= {"/rel", "/rel/lu_xun", "/rel/zhou"}
        assert "/style/satire" not in hits
    finally:
        be.close()


def test_q2_is_single_point_lookup():
    """The paper's O(1) claim: LS must not scan the keyspace."""
    ps = PathStore(DictKV())
    for path, rec in _mini_wiki():
        ps.put_record(path, rec)
    before = ps.engine.op_counts()
    ps.ls("/rel")
    after = ps.engine.op_counts()
    assert after.get("get", 0) - before.get("get", 0) == 1
    assert after.get("scan", 0) == before.get("scan", 0)
