"""The trip-count-aware HLO cost walker — the §Roofline/§Perf measurement
tool — validated against hand-computable programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_walk as HW


def _walk(f, *specs):
    comp = jax.jit(f).lower(*specs).compile()
    return HW.walk(comp.as_text())


def test_plain_dot_flops():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    res = _walk(lambda x, w: x @ w, x, w)
    assert res.flops == 2 * 64 * 128 * 32


def test_scan_trip_count_multiplies():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=4)
        return out.sum()

    res = _walk(f, x, w)
    assert res.flops == 4 * 2 * 128 * 256 * 256
    assert 4 in res.while_trips


def test_nested_scan():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out.sum()

    res = _walk(g, x, w)
    assert res.flops == 5 * 3 * 2 * 128 * 256 * 256
    assert sorted(res.while_trips) == [3, 5]


def test_cost_analysis_undercounts_scans_but_walker_does_not():
    """The motivating bug: XLA visits while bodies once."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out.sum()

    comp = jax.jit(f).lower(x, w).compile()
    from repro.jax_compat import cost_analysis_dict
    ca = cost_analysis_dict(comp).get("flops", 0)
    res = HW.walk(comp.as_text())
    one_dot = 2 * 64 * 64 * 64
    assert res.flops == 8 * one_dot
    assert ca < res.flops          # cost_analysis counted the body ~once


def test_bytes_scale_with_tensor_size():
    a = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    small = _walk(lambda x: x + 1.0,
                  jax.ShapeDtypeStruct((32, 32), jnp.float32))
    big = _walk(lambda x: x + 1.0, a)
    assert big.bytes > 100 * small.bytes


def test_collective_parsing_on_sharded_program():
    """all-reduce bytes appear under SPMD (uses the session's 1 device —
    sharding over a single-device mesh still emits the SPMD structure; we
    assert no crash and sane totals)."""
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    with mesh:
        comp = jax.jit(lambda x: (x @ x).sum()).lower(x).compile()
    res = HW.walk(comp.as_text())
    assert res.flops == 2 * 64 * 64 * 64
    assert res.collective_bytes >= 0
