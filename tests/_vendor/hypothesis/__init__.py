"""Minimal, deterministic fallback for the ``hypothesis`` API surface this
test suite uses.  Loaded by ``tests/conftest.py`` ONLY when the real
package is not installed (the pinned container image ships without it);
any genuine hypothesis install shadows this shim.

Scope: ``given``/``settings`` decorators plus the strategy combinators the
tests call (integers, lists, tuples, sets, text, characters, binary,
sampled_from, builds) with ``.map``/``.filter``.  Generation is seeded per
test name so failures reproduce exactly; there is no shrinking — a failing
example is reported verbatim via the assertion that raised.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable

__version__ = "0.0-repro-shim"

_DEFAULT_MAX_EXAMPLES = 30


class SearchStrategy:
    """Base strategy: ``draw(rnd)`` produces one example."""

    def draw(self, rnd: random.Random) -> Any:
        raise NotImplementedError

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def draw(self, rnd):
        return self.fn(self.base.draw(rnd))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def draw(self, rnd):
        for _ in range(1000):
            x = self.base.draw(rnd)
            if self.pred(x):
                return x
        raise RuntimeError("filter predicate rejected 1000 straight draws")


class _Integers(SearchStrategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2 ** 16) if min_value is None else min_value
        self.hi = 2 ** 16 if max_value is None else max_value

    def draw(self, rnd):
        # bias toward the boundaries — cheap edge-case coverage
        r = rnd.random()
        if r < 0.1:
            return self.lo
        if r < 0.2:
            return self.hi
        return rnd.randint(self.lo, self.hi)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None, unique=False,
                 unique_by=None):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 8
        self.unique = unique or unique_by is not None
        self.key = unique_by or (lambda x: x)

    def draw(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        out, seen = [], set()
        attempts = 0
        while len(out) < n and attempts < 50 * (n + 1):
            attempts += 1
            x = self.elements.draw(rnd)
            if self.unique:
                k = self.key(x)
                if k in seen:
                    continue
                seen.add(k)
            out.append(x)
        return out


class _Sets(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None):
        self._lists = _Lists(elements, min_size=min_size, max_size=max_size,
                             unique=True)

    def draw(self, rnd):
        return set(self._lists.draw(rnd))


class _Tuples(SearchStrategy):
    def __init__(self, *parts):
        self.parts = parts

    def draw(self, rnd):
        return tuple(p.draw(rnd) for p in self.parts)


class _SampledFrom(SearchStrategy):
    def __init__(self, options):
        self.options = list(options)

    def draw(self, rnd):
        return rnd.choice(self.options)


#: default character pool: printable ASCII plus a few multi-byte UTF-8
#: codepoints so path/segment properties see non-ASCII input
_CHAR_POOL = ([chr(c) for c in range(32, 127)]
              + list("éßøñλΩ中文писатель"))


class _Characters(SearchStrategy):
    def __init__(self, blacklist_characters="", blacklist_categories=(),
                 whitelist_categories=None, **_ignored):
        del whitelist_categories  # pool is pre-vetted; surrogates excluded
        self.pool = [c for c in _CHAR_POOL if c not in set(blacklist_characters)]

    def draw(self, rnd):
        return rnd.choice(self.pool)


class _Text(SearchStrategy):
    def __init__(self, alphabet=None, min_size=0, max_size=None):
        if alphabet is None:
            self.alpha = _Characters()
        elif isinstance(alphabet, SearchStrategy):
            self.alpha = alphabet
        else:
            self.alpha = _SampledFrom(list(alphabet))
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def draw(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        return "".join(self.alpha.draw(rnd) for _ in range(n))


class _Binary(SearchStrategy):
    def __init__(self, min_size=0, max_size=None):
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 8

    def draw(self, rnd):
        n = rnd.randint(self.min_size, self.max_size)
        return bytes(rnd.randrange(256) for _ in range(n))


class _Builds(SearchStrategy):
    def __init__(self, target, *args, **kwargs):
        self.target, self.args, self.kwargs = target, args, kwargs

    def draw(self, rnd):
        return self.target(*(a.draw(rnd) for a in self.args),
                           **{k: v.draw(rnd) for k, v in self.kwargs.items()})


class _Strategies:
    integers = staticmethod(_Integers)
    lists = staticmethod(_Lists)
    sets = staticmethod(_Sets)
    tuples = staticmethod(_Tuples)
    sampled_from = staticmethod(_SampledFrom)
    characters = staticmethod(_Characters)
    text = staticmethod(_Text)
    binary = staticmethod(_Binary)
    builds = staticmethod(_Builds)


strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats: SearchStrategy, **kw_strats: SearchStrategy):
    """Bind the trailing positional parameters of the test to strategy
    draws (leading parameters stay visible to pytest as fixtures), run
    ``max_examples`` seeded examples."""

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        n_pos = len(strats)
        kw_names = set(kw_strats)
        # strategies bind the TRAILING positional parameters; everything
        # before them stays visible to pytest as fixtures
        strat_names = [p.name for p in params[len(params) - n_pos:]]
        fixture_params = [p for p in params[: len(params) - n_pos]
                          if p.name not in kw_names]
        fixture_names = [p.name for p in fixture_params]

        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kwargs):
            base_kw = dict(zip(fixture_names, fixture_args))
            base_kw.update(fixture_kwargs)
            max_examples = getattr(fn, "_shim_max_examples",
                                   _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(f"shim:{fn.__module__}.{fn.__qualname__}")
            for i in range(max_examples):
                call_kw = dict(base_kw)
                call_kw.update(zip(strat_names,
                                   (s.draw(rnd) for s in strats)))
                call_kw.update((k, s.draw(rnd))
                               for k, s in kw_strats.items())
                try:
                    fn(**call_kw)
                except Exception as e:
                    shown = {k: v for k, v in call_kw.items()
                             if k not in fixture_names}
                    raise AssertionError(
                        f"falsifying example #{i}: {shown!r}") from e

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        return wrapper

    return deco


def assume(condition: bool) -> None:
    """Shim: hard-skip unsupported; treat failed assumptions as no-ops for
    the draws our suite makes (none currently call assume)."""
    if not condition:
        raise AssertionError("assume() failed under the hypothesis shim")


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    function_scoped_fixture = "function_scoped_fixture"


__all__ = ["given", "settings", "strategies", "assume", "HealthCheck",
           "SearchStrategy"]
