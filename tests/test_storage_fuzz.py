"""Crash-injection fuzz harness (ISSUE 9 satellite 1).

Every case is a SEEDED, fully deterministic two-pass experiment:

1. **Counting pass** — run a randomized put/delete/commit/spill/compact/
   reopen workload against a real ``DurableKV`` with a pure-counting
   failpoint plan armed, learning how many faultable IO operations
   (WAL appends/commits/fsyncs, segment writes, manifest writes/swaps)
   the schedule performs.
2. **Crash pass** — rerun the *identical* workload from scratch with a
   crash injected at a seed-chosen operation index, either failing the
   IO cleanly or tearing the write (a prefix reaches the disk).  The
   wounded store is abandoned mid-flight, reopened, and must recover to
   **byte equality** with an in-memory oracle that replayed only the
   outcomes a crash permits: the state as of the last durable commit,
   or that plus the in-flight wave (the crash may land after the wave's
   group commit but during spill/merge).  The store then keeps serving:
   a post-recovery wave must commit and read back exactly.

The workload's geometry (tiny segment target, ratio 2, sometimes a
merge budget) makes partitioned multi-segment merges and budget-paused
resumable merges common, so crash points land inside them — the states
ISSUE 9's tentpole added.

``test_storage_fuzz_seeded`` (tier-1) samples a small number of seeds
via the (possibly vendored) hypothesis ``@given``.  The extended sweep
``test_storage_fuzz_extended`` is opt-in — set ``REPRO_FUZZ_CASES``
(the CI storage-fuzz leg uses 200); it prints the failing seed so any
crash schedule reproduces from the command line.
"""
import os
import random
import shutil
import tempfile

import pytest
from hypothesis import given, settings, strategies as st

from repro.storage import DurableKV
from repro.storage import failpoints as FPS
from repro.storage import manifest as MF

_POOL = [f"k{i:04d}".encode() for i in range(16)]
_OPS = ("put", "put", "put", "delete", "commit", "commit", "spill",
        "compact", "reopen")


def _apply(base: dict, wave: list) -> dict:
    out = dict(base)
    for op in wave:
        if op[0] == "put":
            out[op[1]] = op[2]
        else:
            out.pop(op[1], None)
    return out


class _Oracle:
    """Durable state (``base``) + the open wave's ops (``wave``)."""

    def __init__(self):
        self.base: dict = {}
        self.wave: list = []

    def committed(self):
        """The wave became durable: fold it in."""
        self.base = _apply(self.base, self.wave)
        self.wave = []

    def allowed(self) -> tuple[dict, dict]:
        """The two states a crash can legally recover to."""
        return self.base, _apply(self.base, self.wave)


def _open(d: str, budget: int, sync: str = "none") -> DurableKV:
    return DurableKV(d, memtable_limit=4, sync=sync, level_ratio=2,
                     segment_target_bytes=48, compact_budget_bytes=budget)


class _Workload:
    """One seeded op schedule, replayed identically in both passes."""

    def __init__(self, d: str, seed: int, n_ops: int = 40):
        self.d = d
        self.rng = random.Random(seed)
        self.budget = self.rng.choice([0, 0, 150])
        # mostly sync="none" for speed; some seeds fsync so the
        # *.fsync failpoint sites land in the crash-schedule space too
        self.sync = self.rng.choice(["none", "none", "none", "fsync"])
        self.n_ops = n_ops
        self.oracle = _Oracle()
        self.epoch = 0
        self.kv = _open(d, self.budget, self.sync)

    def run(self) -> None:
        for _ in range(self.n_ops):
            self.step()

    def step(self) -> None:
        op = self.rng.choice(_OPS)
        if op == "put":
            k = self.rng.choice(_POOL)
            v = f"v{self.rng.randint(0, 999)}".encode()
            self.kv.put(k, v)
            self.oracle.wave.append(("put", k, v))
        elif op == "delete":
            k = self.rng.choice(_POOL)
            self.kv.delete(k)
            self.oracle.wave.append(("del", k))
        elif op == "commit":
            self.epoch += 1
            self.kv.commit_epoch(self.epoch)
            self.oracle.committed()
        elif op == "spill":
            self.kv.spill()
            self.oracle.committed()
        elif op == "compact":
            self.kv.compact()
            self.oracle.committed()
        else:                                # reopen (clean close commits)
            self.kv.close()
            self.oracle.committed()
            self.kv = _open(self.d, self.budget, self.sync)

    def abandon(self) -> None:
        """Release handles like a dead process (no commit).  The
        background compaction worker is stopped first — a dead process
        has no threads, and a live one would keep mutating the store
        we are about to declare dead."""
        self.kv._stop_bg()
        try:
            self.kv._wal._f.close()
        except Exception:
            pass
        for t in getattr(self.kv, "_tables", {}).values():
            try:
                t.close()
            except Exception:
                pass


def _check_invariants(kv: DurableKV, d: str, seed: int) -> None:
    """No orphans, no unpaid-for files, partitioned-level sanity.

    Runs under ``kv._lock``: the background compaction worker mutates
    the manifest, the levels, and the segment files atomically w.r.t.
    that lock, so a locked read always sees a consistent cut."""
    with kv._lock:
        live = set(kv._manifest.segment_names())
        if kv._manifest.compaction is not None:
            live.update(o.name for o in kv._manifest.compaction.outputs)
        on_disk = {n for n in os.listdir(d) if n.endswith(".seg")}
        assert on_disk == live, f"seed {seed}: disk/manifest drift"
        for view in kv._levels:
            if view.partitioned:
                for a, b in zip(view.entries, view.entries[1:]):
                    assert bytes.fromhex(b[0].min_key) > \
                        bytes.fromhex(a[0].max_key), \
                        f"seed {seed}: level {view.level} ranges overlap"


def _fuzz_one(root: str, seed: int) -> None:
    """One full counting-pass + crash-pass experiment under ``root``."""
    # pass 1: count the schedule's faultable IO ops
    d1 = os.path.join(root, "count")
    wl = _Workload(d1, seed)
    with FPS.armed(FPS.FailPlan(crash_at=0)) as counter:
        wl.run()
    wl.kv.close()
    # the completed run must equal its oracle exactly (no crash at all)
    reopened = _open(d1, wl.budget, wl.sync)
    assert dict(reopened.scan(b"")) == _apply(wl.oracle.base,
                                              wl.oracle.wave), \
        f"seed {seed}: crash-free run diverged from oracle"
    reopened.close()
    n_ops = len(counter.hits)
    if n_ops == 0:
        return                               # schedule did no durable IO

    # pass 2: same schedule, crash injected at a seed-chosen boundary
    pick = random.Random(seed ^ 0x5EEDFA11)
    crash_at = pick.randint(1, n_ops)
    mode = pick.choice(["fail", "torn"])
    d2 = os.path.join(root, "crash")
    wl2 = _Workload(d2, seed)
    crashed = False
    try:
        with FPS.armed(FPS.FailPlan(crash_at=crash_at, mode=mode)):
            wl2.run()
    except FPS.InjectedCrash:
        crashed = True
    wl2.abandon()
    # recover and hold the oracle to byte equality
    kv = _open(d2, wl2.budget, wl2.sync)
    got = dict(kv.scan(b""))
    if crashed:
        lo, hi = wl2.oracle.allowed()
        assert got in (lo, hi), \
            (f"seed {seed} crash_at={crash_at} mode={mode}: recovered "
             f"state matches neither committed nor committed+wave")
    else:
        # the crash point landed past the schedule's end (counting pass
        # included close/reopen IO the shorter path skipped) — the run
        # completed; it must equal the full oracle
        assert got == _apply(wl2.oracle.base, wl2.oracle.wave), \
            f"seed {seed}: uncrashed pass-2 run diverged"
    _check_invariants(kv, d2, seed)
    # the recovered store keeps working: one more wave, exact readback
    base = dict(got)
    for i, k in enumerate(_POOL[:4]):
        kv.put(k, f"post{i}".encode())
        base[k] = f"post{i}".encode()
    kv.commit_epoch(100)
    while kv.compact_debt() > 0:             # drain any paused merge
        kv.commit_epoch(kv.last_epoch() + 1)
    assert dict(kv.scan(b"")) == base, f"seed {seed}: post-crash wave lost"
    _check_invariants(kv, d2, seed)
    kv.close()


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 32 - 1))
def test_storage_fuzz_seeded(tmp_path_factory, seed):
    """Tier-1 sample of the crash-schedule space (see module docstring)."""
    _fuzz_one(str(tmp_path_factory.mktemp("fuzz")), seed)


@pytest.mark.slow
def test_storage_fuzz_extended():
    """Opt-in sweep: ``REPRO_FUZZ_CASES=200`` in the CI storage-fuzz leg.
    Prints the failing seed — rerun it via ``_fuzz_one`` or by setting
    ``REPRO_FUZZ_SEED`` to pin the sweep to that one case."""
    n = int(os.environ.get("REPRO_FUZZ_CASES", "0") or "0")
    if n <= 0:
        pytest.skip("set REPRO_FUZZ_CASES=<n> to run the extended sweep")
    pinned = os.environ.get("REPRO_FUZZ_SEED")
    seeds = ([int(pinned)] if pinned else
             [(case * 2654435761 + 97) % 2 ** 32 for case in range(n)])
    for case, seed in enumerate(seeds):
        root = tempfile.mkdtemp(prefix="repro_fuzz_")
        try:
            _fuzz_one(root, seed)
        except BaseException:
            print(f"\nFUZZ FAILURE: case {case} seed={seed} — reproduce "
                  f"with REPRO_FUZZ_CASES=1 REPRO_FUZZ_SEED={seed}")
            raise
        finally:
            shutil.rmtree(root, ignore_errors=True)
